"""Shared benchmark harness: datasets, index builders (cached), timers."""

from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("JAX_ENABLE_X64", "1")

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402

from repro.api import (DeviceSnapshot, IndexConfig, LearnedIndex,  # noqa: E402
                       manual_merge_policy)
from repro.core import search as S                    # noqa: E402,F401
from repro.core.baselines import ALL_BASELINES        # noqa: E402,F401
from repro.core.dili import bulk_load                 # noqa: E402
from repro.core.flat import flatten                   # noqa: E402
from repro.data.datasets import ALL_DATASETS, generate  # noqa: E402,F401

N_KEYS = int(os.environ.get("BENCH_N_KEYS", "300000"))
N_QUERIES = int(os.environ.get("BENCH_N_QUERIES", "65536"))
DATASETS = os.environ.get("BENCH_DATASETS", "fb,wikits,logn").split(",")

_cache: dict = {}


def dataset(name: str) -> np.ndarray:
    if ("ds", name) not in _cache:
        _cache[("ds", name)] = generate(name, N_KEYS, seed=42)
    return _cache[("ds", name)]


def dili_for(name: str, **kw):
    """(keys, host DILI, FlatDILI, DeviceSnapshot) — the snapshot is the
    typed pytree every `core.search` entry point accepts directly."""
    key = ("dili", name, tuple(sorted(kw.items())))
    if key not in _cache:
        keys = dataset(name)
        d = bulk_load(keys, sample_stride=4, **kw)
        f = flatten(d)
        _cache[key] = (keys, d, f, DeviceSnapshot.from_flat(f))
    return _cache[key]


def index_for(name: str, engine: str) -> LearnedIndex:
    """A `LearnedIndex` over `dataset(name)` on the requested engine
    (manual merge policy: benchmark writes never trigger implicit folds)."""
    key = ("facade", engine, name)
    if key not in _cache:
        _cache[key] = LearnedIndex.build(
            dataset(name),
            config=IndexConfig(engine=engine, sample_stride=4,
                               merge=manual_merge_policy()))
    return _cache[key]


def baseline_for(B, name: str):
    key = ("bl", B.name, name)
    if key not in _cache:
        keys = dataset(name)
        vals = np.arange(len(keys), dtype=np.int64)
        st = B.build(keys, vals)
        _cache[key] = (st, B.device(st))
    return _cache[key]


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall seconds per call (jax block_until_ready on outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def queries_for(name: str, n: int = None, seed: int = 7) -> np.ndarray:
    keys = dataset(name)
    rng = np.random.default_rng(seed)
    return keys[rng.integers(0, len(keys), n or N_QUERIES)]


N_WORKLOAD_OPS = int(os.environ.get("BENCH_WORKLOAD_OPS", "20000"))
N_WORKLOAD_BATCH = int(os.environ.get("BENCH_WORKLOAD_BATCH", "256"))


def workload_universe(n_keys: int = N_KEYS) -> np.ndarray:
    """Loaded keys for oracle-checked workload replays: the even integers
    in [0, 2*n_keys).  Integer-valued keys are exactly representable in f64
    and (below 2^24) in f32, so the same stream drives the pallas engine
    with zero quantization divergence; the generator draws insert keys from
    the interleaved odd integers, disjoint by construction."""
    return np.arange(0, 2 * n_keys, 2, dtype=np.float64)


ROWS: list[dict] = []       # every csv_row, for machine-readable emission


def csv_row(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append(dict(name=name, value=float(us_per_call), derived=derived))
    print(f"{name},{us_per_call:.3f},{derived}")
