"""Trip-count-aware HLO analyzer.

XLA's `compiled.cost_analysis()` counts a while-loop body ONCE regardless of
trip count (empirically verified — see EXPERIMENTS.md section Dry-run), which
under-counts scan-over-layers, grad-accumulation scans, flash-attention
chunk scans and mamba chunk scans by orders of magnitude.  This module parses
the post-SPMD HLO text, builds the computation call graph, extracts while
trip counts from their condition computations, and accumulates:

  * flops            — dot/convolution ops (2*M*N*K), trip-multiplied
  * bytes            — per-fusion operand+output bytes (the HBM traffic
                       proxy: each fusion reads its operands and writes its
                       outputs once), trip-multiplied
  * collectives      — per-op-type ring-traffic bytes, trip-multiplied

All numbers are PER DEVICE (post-partitioning shapes).
"""

from __future__ import annotations

import gzip
import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_CALLED_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_CALLED_LIST_RE = re.compile(r"(?:branch_computations|called_computations)"
                             r"=\{([^}]*)\}")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+(?:\([^)]*\)\s*->|\()")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of possibly-tuple shape string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _first_shape(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclass
class Op:
    name: str
    text: str
    kind: str
    out_type: str
    operands: list = field(default_factory=list)
    called: list = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: dict = field(default_factory=dict)
    order: list = field(default_factory=list)
    is_entry: bool = False


_OP_KIND_RE = re.compile(
    r"((?:[a-z0-9]+\[[0-9,]*\][^ ]*|\([^=]*\))\s+)?([a-z][\w\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        ls = line.strip()
        if not ls or ls.startswith("//") or ls.startswith("#"):
            continue
        if (ls.startswith("HloModule") or ls.startswith("FileNames")
                or ls.startswith("FunctionNames")):
            continue
        if ls.endswith("{") and ("(" in ls) and "=" not in ls.split("(")[0]:
            m = _COMP_RE.match(ls.rstrip("{ ").strip())
            if m:
                cur = Computation(m.group(1),
                                  is_entry=ls.startswith("ENTRY"))
                comps[cur.name] = cur
            continue
        if ls == "}" or ls.startswith("}"):
            continue
        if cur is None:
            continue
        ls = re.sub(r"/\*.*?\*/", "", ls)       # strip /*index=N*/ comments
        dm = _DEF_RE.match(ls)
        if not dm:
            continue
        name, rhs = dm.groups()
        km = re.search(r"([a-z][\w\-]*)\(", rhs)
        if not km:
            continue
        kind = km.group(1)
        out_type = rhs[:km.start()].strip()
        called = list(_CALLED_RE.findall(rhs))
        for group in _CALLED_LIST_RE.findall(rhs):
            for c in group.split(","):
                c = c.strip().lstrip("%")
                if c:
                    called.append(c)
        op = Op(name=name, text=ls, kind=kind, out_type=out_type,
                called=called)
        cur.ops[name] = op
        cur.order.append(name)
    return comps


_TRIP_CONST_RE = re.compile(r"constant\((\d+)\)")
_CMP_RE = re.compile(r"compare\(")


def while_trip_count(comps, cond_name: str) -> int:
    """Extract the loop bound from a scan-style condition computation:
    it compares the induction variable against a constant."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = []
    for name in cond.order:
        op = cond.ops[name]
        if op.kind == "constant":
            m = _TRIP_CONST_RE.search(op.text)
            if m:
                consts.append(int(m.group(1)))
    # scan conditions compare i < N; take the largest plausible constant
    return max(consts) if consts else 1


_DOT_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_DOT_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")


def _dot_flops(op: Op, comp: Computation, comps) -> float:
    """2 * prod(output) * prod(lhs contracting dims)."""
    _, out_dims = _first_shape(op.out_type)
    # find lhs operand shape: first %ref in the args
    args = op.text.split(op.kind + "(", 1)[1]
    refs = _OPERAND_RE.findall(args.split(")")[0])
    lhs_shape = None
    if refs:
        d = comp.ops.get(refs[0])
        if d is not None:
            _, lhs_shape = _first_shape(d.out_type)
    cm = _DOT_CONTRACT_RE.search(op.text)
    contract = 1
    if cm and lhs_shape:
        for d in cm.group(1).split(","):
            if d and int(d) < len(lhs_shape):
                contract *= lhs_shape[int(d)]
    elif lhs_shape:
        contract = lhs_shape[-1]
    out = 1
    for d in out_dims:
        out *= d
    return 2.0 * out * max(contract, 1)


_GROUP_PAIRS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_BRACES_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _group_size(text: str) -> int:
    m = _GROUP_PAIRS_RE.search(text)
    if m:
        return int(m.group(2))          # [n_groups, group_size]
    m = _GROUP_BRACES_RE.search(text)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 8


COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")


def analyze(text: str) -> dict:
    comps = parse_hlo(text)
    entry = next((n for n, c in comps.items() if c.is_entry), None)
    if entry is None:
        called_by = {cal for c in comps.values()
                     for op in c.ops.values() for cal in op.called}
        entries = [c for c in comps if c not in called_by]
        entry = max(entries or comps.keys(),
                    key=lambda n: len(comps[n].order))

    totals = defaultdict(float)
    coll = {k: 0.0 for k in COLLECTIVE_KINDS}
    coll_counts = defaultdict(int)

    def visit(comp_name: str, mult: float, stack=()):
        comp = comps.get(comp_name)
        if comp is None or comp_name in stack:
            return
        for name in comp.order:
            op = comp.ops[name]
            k = op.kind
            if k == "while":
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", op.text)
                cm = re.search(r"condition=%?([\w.\-]+)", op.text)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                trips = while_trip_count(comps, cond) if cond else 1
                if body:
                    visit(body, mult * max(trips, 1), stack + (comp_name,))
                continue
            if k in ("fusion", "call", "map", "reduce", "reduce-window",
                     "scatter", "sort", "custom-call", "conditional"):
                for cal in op.called:
                    visit(cal, mult, stack + (comp_name,))
            if k in ("dot", "convolution"):
                totals["flops"] += mult * _dot_flops(op, comp, comps)
                totals["bytes"] += mult * _op_bytes(op, comp)
            elif k == "fusion":
                totals["bytes"] += mult * _op_bytes(op, comp)
            elif k in COLLECTIVE_KINDS:
                size = _shape_bytes(op.out_type)
                g = _group_size(op.text)
                if k == "all-reduce":
                    traffic = 2 * size * (g - 1) / max(g, 1)
                elif k == "collective-permute":
                    traffic = size
                else:
                    traffic = size * (g - 1) / max(g, 1)
                coll[k] += mult * traffic
                coll_counts[k] += 1
                totals["bytes"] += mult * _op_bytes(op, comp)

    def _op_bytes(op: Op, comp: Computation) -> float:
        # traffic model: every produced buffer is written once and read once
        # by its consumer(s).  Counting output bytes x2 avoids the systematic
        # producer/consumer double count of (operands + outputs) accounting.
        return 2.0 * _shape_bytes(op.out_type)

    visit(entry, 1.0)

    totals["collective_bytes"] = sum(coll.values())
    return dict(flops=totals["flops"], bytes=totals["bytes"],
                collectives=dict(coll), collective_counts=dict(coll_counts),
                collective_bytes=totals["collective_bytes"], entry=entry)


def analyze_file(path: str) -> dict:
    op = gzip.open if path.endswith(".gz") else open
    with op(path, "rt") as f:
        return analyze(f.read())
