"""One-shot capture of the PRE-PR2 hot-path numbers (point lookup + fig6b
range query) at the acceptance scale.  Run from the pre-PR2 tree; writes
benchmarks/baseline_pre_pr2.json which `run.py --json` compares against.

    BENCH_N_KEYS=300000 PYTHONPATH=src python benchmarks/pre_pr2_capture.py
"""

from __future__ import annotations

import json
import os
import sys

os.environ.setdefault("JAX_ENABLE_X64", "1")
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import numpy as np
import jax.numpy as jnp

from common import DATASETS, N_KEYS, N_QUERIES, dili_for, queries_for, time_fn
from repro.core import search as S


def capture(path: str) -> dict:
    out: dict = dict(n_keys=N_KEYS, n_queries=N_QUERIES, sections={})
    for name in DATASETS:
        keys, d, f, idx = dili_for(name)
        q = jnp.asarray(queries_for(name))
        md = f.max_depth + 2
        t = time_fn(lambda q: S.search_batch(idx, q, max_depth=md), q)
        out["sections"][f"point_lookup,{name}"] = dict(
            ns_per_query=t / N_QUERIES * 1e9, max_depth=f.max_depth)
        print(name, "point", t / N_QUERIES * 1e9, flush=True)
        rng = np.random.default_rng(3)
        starts = rng.integers(0, len(keys) - 101, 512)
        lo = jnp.asarray(keys[starts])
        hi = jnp.asarray(keys[starts + 100])
        tr = time_fn(lambda lo, hi: S.range_query_batch(idx, lo, hi,
                                                        max_hits=128), lo, hi)
        out["sections"][f"range_query,{name}"] = dict(
            us_per_query=tr / 512 * 1e6, n_slots=f.n_slots)
        print(name, "range", tr / 512 * 1e6, flush=True)
        with open(path, "w") as fh:     # incremental: partial runs count
            json.dump(out, fh, indent=1)
    return out


if __name__ == "__main__":
    path = (sys.argv[1] if len(sys.argv) > 1 else
            os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "baseline_pre_pr2.json"))
    rows = capture(path)
    print(json.dumps(rows, indent=1))
