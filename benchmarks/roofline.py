"""Roofline table builder (EXPERIMENTS.md section Roofline).

Per single-pod (arch x shape) cell:
  compute term    = walker_FLOPs_per_device / 197e12        [s]
  memory term     = walker_bytes_per_device / 819e9         [s]
  collective term = walker_collective_bytes_per_device / 50e9  [s]
                    (per-chip traffic charged against ONE ICI link — the
                     worst-case single-link assumption, documented)
plus MODEL_FLOPS (analytic 6*N*D / 2*N_active*D + attention terms) and the
useful-compute ratio MODEL_FLOPS / walker_FLOPs.

Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from hlo_analysis import analyze_file  # noqa: E402

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256


def param_counts(arch: str):
    """(total params, active params) via eval_shape on the real init."""
    import jax
    from repro.configs import get_config
    from repro.models import model as MDL
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: MDL.init_params(jax.random.PRNGKey(0),
                                                    cfg))
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        names = [str(getattr(k, "key", k)) for k in path]
        if "moe" in names and any(x in names[-1] for x in
                                  ("w_up", "w_gate", "w_down")):
            active += n * cfg.top_k / cfg.n_experts
        else:
            active += n
    return total, active, cfg


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful FLOPs per device per step."""
    from repro.models.config import ALL_SHAPES
    total, active, cfg = param_counts(arch)
    shape = next(s for s in ALL_SHAPES if s.name == shape_name)
    b, s = shape.global_batch, shape.seq_len
    hd = cfg.hd
    h = cfg.n_heads
    if shape.kind == "train":
        tokens = b * s
        flops = 6.0 * active * tokens
        if cfg.n_heads:          # attention score+value matmuls, fwd+bwd
            flops += 3 * 2 * 2 * b * h * s * s * hd / 2   # causal half
    elif shape.kind == "prefill":
        tokens = b * s
        flops = 2.0 * active * tokens
        if cfg.n_heads:
            flops += 2 * 2 * b * h * s * s * hd / 2
    else:                        # decode: one token, KV length = s
        flops = 2.0 * active * b
        if cfg.n_heads:
            flops += 2 * 2 * b * h * s * hd
    return flops / CHIPS


def build_table(dryrun_dir: str = "results/dryrun",
                out_json: str = "results/roofline.json",
                pattern: str = "*_single"):
    rows = []
    for jf in sorted(glob.glob(os.path.join(dryrun_dir,
                                            pattern + ".json"))):
        meta = json.load(open(jf))
        tag = os.path.basename(jf)[:-5]
        if meta.get("status") == "SKIP":
            rows.append(dict(cell=tag, arch=meta["arch"],
                             shape=meta["shape"], status="SKIP",
                             reason=meta.get("reason", "")))
            continue
        if meta.get("status") != "OK":
            rows.append(dict(cell=tag, arch=meta["arch"],
                             shape=meta["shape"], status=meta.get("status")))
            continue
        hf = jf[:-5] + ".hlo.gz"
        w = analyze_file(hf)
        t_c = w["flops"] / PEAK_FLOPS
        t_m = w["bytes"] / HBM_BW
        t_x = w["collective_bytes"] / ICI_BW
        dom = max(("compute", t_c), ("memory", t_m),
                  ("collective", t_x), key=lambda kv: kv[1])[0]
        mf = model_flops(meta["arch"], meta["shape"])
        rows.append(dict(
            cell=tag, arch=meta["arch"], shape=meta["shape"], status="OK",
            kind=meta.get("kind"),
            flops=w["flops"], bytes=w["bytes"],
            collective_bytes=w["collective_bytes"],
            collectives=w["collectives"],
            t_compute=t_c, t_memory=t_m, t_collective=t_x,
            dominant=dom,
            model_flops=mf,
            useful_ratio=mf / max(w["flops"], 1.0),
            step_time_bound=max(t_c, t_m, t_x),
            roofline_fraction=t_c / max(t_c, t_m, t_x),
            mem_peak=meta.get("mem_peak_memory_in_bytes"),
            cost_flops=meta.get("flops"),
        ))
    os.makedirs(os.path.dirname(out_json), exist_ok=True)
    with open(out_json, "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def render_markdown(rows) -> str:
    def fmt(x, d=3):
        return f"{x:.{d}g}" if isinstance(x, float) else str(x)
    out = ["| cell | t_compute (s) | t_memory (s) | t_coll (s) | dominant | "
           "MODEL_FLOPs/dev | useful ratio | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") == "SKIP":
            out.append(f"| {r['cell']} | — | — | — | SKIP "
                       f"({r.get('reason','')[:40]}) | — | — | — |")
            continue
        if r.get("status") != "OK":
            out.append(f"| {r['cell']} | — | — | — | {r.get('status')} "
                       f"| — | — | — |")
            continue
        out.append(
            f"| {r['cell']} | {fmt(r['t_compute'])} | {fmt(r['t_memory'])} "
            f"| {fmt(r['t_collective'])} | **{r['dominant']}** "
            f"| {fmt(r['model_flops'])} | {fmt(r['useful_ratio'], 2)} "
            f"| {fmt(r['roofline_fraction'], 2)} |")
    return "\n".join(out)


if __name__ == "__main__":
    rows = build_table()
    print(render_markdown(rows))
