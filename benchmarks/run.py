"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus `#` section headers).
Scaled to BENCH_N_KEYS (default 300k; the paper's 200M is one env var away).
Lookup wall-times are CPU-JAX batched timings — the relative ordering is the
claim under test; the TPU roofline story lives in benchmarks/roofline.py +
EXPERIMENTS.md.

Cost model (since PR 2, DESIGN.md section 9): point lookups are depth-exact —
the traversal trip count is the snapshot's true `max_depth` with batch-
convergence early exit, never a fixed worst-case scan — and range queries
bisect the flatten()-time key-sorted pair table, O(log n + max_hits) per
query instead of the old O(n_slots) global slot-table mask-scan.  So lookup
cost scales with tree height and range cost with hits, not with table size.

``--json PATH`` additionally writes every row machine-readably;
``--pr2-json`` emits BENCH_PR2.json — the hot-path trajectory artifact
comparing against benchmarks/baseline_pre_pr2.json (captured on the pre-PR-2
tree with the same datasets/scales), extended since the api redesign with
facade sections measured through `repro.api.LearnedIndex` on the engine
selected by ``--engine {local,pallas,sharded}``.  Every section carries its
own ``n_keys`` stamp, and ``--pr2-extend`` merges a run at a DIFFERENT
scale (e.g. BENCH_N_KEYS=10000000 with ``--scale`` and ``--workload``)
into the existing artifact under ``@n=<scale>``-suffixed keys, leaving the
original sections byte-identical.
"""

from __future__ import annotations

import os
import sys

os.environ.setdefault("JAX_ENABLE_X64", "1")   # BEFORE importing jax
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from common import (DATASETS, N_QUERIES, N_WORKLOAD_BATCH, N_WORKLOAD_OPS,
                    baseline_for, csv_row, dataset, dili_for, index_for,
                    queries_for, time_fn, workload_universe)

from repro.api import DeviceSnapshot                    # noqa: E402
from repro.core import search as S                      # noqa: E402
from repro.core.baselines import ALL_BASELINES          # noqa: E402
from repro.core.bu_tree import build_bu_tree, bu_search  # noqa: E402
from repro.core.dili import bulk_load                   # noqa: E402
from repro.core.flat import flatten                     # noqa: E402

# engine under test for the facade sections / --pr2-json (set by --engine)
ENGINE = "local"

# --metrics-json: when set, workload runs build with telemetry enabled and
# their `LearnedIndex.metrics()` snapshots collect here, one per section
METRICS_JSON = ""
METRICS_SECTIONS: dict = {}

# --trace-json: when set, the first --serve latency leg runs with causal
# tracing armed and dumps the Chrome-trace-event JSON here (Perfetto-viewable)
TRACE_JSON = ""


def _metrics_section(m: dict, n_keys: int) -> dict:
    """Stamp a `LearnedIndex.metrics()` snapshot with the section's key
    scale, mirroring the n_keys stamp every BENCH_PR2.json section
    carries (the snapshot already self-describes via its `schema` key)."""
    return {"n_keys": n_keys, **m}


def _dili_lookup_time(name: str, **kw) -> tuple[float, dict]:
    keys, d, f, idx = dili_for(name, **kw)
    q = jnp.asarray(queries_for(name))
    # serving configuration: depth-exact from the snapshot + early exit
    t = time_fn(lambda q: S.search_batch(idx, q, early_exit=True), q)
    v, fnd, nodes, probes = S.search_batch(idx, q, with_stats=True)
    assert bool(np.asarray(fnd).all())
    return t, dict(nodes=float(np.asarray(nodes).mean()),
                   probes=float(np.asarray(probes).mean()),
                   stats=d.stats())


def table4_lookup():
    """Table 4: lookup time of all methods after bulk loading."""
    print("# Table 4: lookup ns/query (batched CPU-JAX, scaled datasets)")
    for name in DATASETS:
        t, _ = _dili_lookup_time(name)
        csv_row(f"table4,{name},DILI", t / N_QUERIES * 1e9)
        tlo, _ = _dili_lookup_time(name, local_optimized=False)
        csv_row(f"table4,{name},DILI-LO", tlo / N_QUERIES * 1e9)
        q = jnp.asarray(queries_for(name))
        for B in ALL_BASELINES:
            st, dev = baseline_for(B, name)
            t = time_fn(lambda q: B.lookup(dev, q), q)
            csv_row(f"table4,{name},{B.name}", t / N_QUERIES * 1e9)


def table5_access():
    """Table 5 proxy: memory touches per query (nodes+slots gathered) —
    the TPU analogue of LL-cache misses."""
    print("# Table 5: memory touches per query")
    for name in DATASETS:
        _, st_ = _dili_lookup_time(name)
        csv_row(f"table5,{name},DILI", st_["nodes"] + st_["probes"])
        q = jnp.asarray(queries_for(name))
        for B in ALL_BASELINES:
            stb, dev = baseline_for(B, name)
            _, _, pr = B.lookup(dev, q)
            csv_row(f"table5,{name},{B.name}",
                    float(np.asarray(pr).mean()))


def table6_stats():
    """Table 6: DILI height stats + conflicts per 1K keys."""
    print("# Table 6: DILI construction statistics")
    for name in DATASETS:
        keys, d, f, idx = dili_for(name)
        s = d.stats()
        csv_row(f"table6,{name},min_h", s["min_height"])
        csv_row(f"table6,{name},max_h", s["max_height"])
        csv_row(f"table6,{name},avg_h", s["avg_height"])
        csv_row(f"table6,{name},conflicts_per_1k",
                1000.0 * s["conflicts"] / len(keys))


def fig6_memory_range():
    """Fig. 6: index sizes + short range queries (<=100 keys)."""
    print("# Fig 6a: index bytes per key")
    for name in DATASETS:
        keys, d, f, idx = dili_for(name)
        csv_row(f"fig6a,{name},DILI", f.nbytes() / len(keys))
        keys, d2, f2, _ = dili_for(name, local_optimized=False)
        csv_row(f"fig6a,{name},DILI-LO", f2.nbytes() / len(keys))
        for B in ALL_BASELINES:
            st, dev = baseline_for(B, name)
            if B.name == "LIPP":
                nb = st["flat"].nbytes()
            else:
                nb = sum(v.nbytes for v in st.values()
                         if isinstance(v, np.ndarray))
            csv_row(f"fig6a,{name},{B.name}", nb / len(keys))
    print("# Fig 6b: range query us/query (100-key ranges)")
    for name in DATASETS:
        keys, d, f, idx = dili_for(name)
        rng = np.random.default_rng(3)
        starts = rng.integers(0, len(keys) - 101, 512)
        lo = jnp.asarray(keys[starts])
        hi = jnp.asarray(keys[starts + 100])
        t = time_fn(lambda lo, hi: S.range_query_batch(idx, lo, hi,
                                                       max_hits=128), lo, hi)
        csv_row(f"fig6b,{name},DILI", t / 512 * 1e6)


def fig7_workloads():
    """Fig. 7: read-only/read-heavy/write-heavy/write-only throughput."""
    print("# Fig 7: workload throughput (us/op; derived=ops/s)")
    import time as _t
    for name in DATASETS:
        keys = dataset(name)
        half = keys[::2]
        other = np.setdiff1d(keys, half)
        rng = np.random.default_rng(4)
        for wl, n_q, n_i in (("read_only", 20000, 0),
                             ("read_heavy", 20000, 10000),
                             ("write_heavy", 10000, 20000),
                             ("write_only", 0, 20000)):
            d = bulk_load(half, sample_stride=4)
            qs = half[rng.integers(0, len(half), max(n_q, 1))]
            ins = other[rng.integers(0, len(other), max(n_i, 1))]
            t0 = _t.perf_counter()
            qi = ii = 0
            for k in range(n_q + n_i):
                if (k % 3 == 2 or qi >= n_q) and ii < n_i:
                    d.insert(float(ins[ii % len(ins)]), k)
                    ii += 1
                elif qi < n_q:
                    d.search(float(qs[qi]))
                    qi += 1
            dt = _t.perf_counter() - t0
            csv_row(f"fig7,{name},{wl}", 1e6 * dt / (n_q + n_i),
                    f"{(n_q + n_i) / dt:.0f} ops/s")


def fig8_deletions():
    """Fig. 8: read-heavy / deletion-heavy workloads with deletes."""
    print("# Fig 8: deletion workloads (us/op; derived=ops/s)")
    import time as _t
    for name in DATASETS:
        keys = dataset(name)
        rng = np.random.default_rng(5)
        for wl, n_q, n_d in (("read_heavy", 20000, 10000),
                             ("delete_heavy", 10000, 20000)):
            d = bulk_load(keys, sample_stride=4)
            dels = rng.permutation(keys)[:n_d]
            qs = keys[rng.integers(0, len(keys), n_q)]
            t0 = _t.perf_counter()
            qi = di = 0
            for k in range(n_q + n_d):
                if (k % 3 == 2 or qi >= n_q) and di < n_d:
                    d.delete(float(dels[di]))
                    di += 1
                elif qi < n_q:
                    d.search(float(qs[qi]))
                    qi += 1
            dt = _t.perf_counter() - t0
            csv_row(f"fig8,{name},{wl}", 1e6 * dt / (n_q + n_d),
                    f"{(n_q + n_d) / dt:.0f} ops/s")


def table78_hyperparams():
    """Tables 7/8: rho and lambda sweeps."""
    print("# Table 7: rho sweep")
    from repro.core.bu_tree import CostModel
    name = DATASETS[0]
    keys = dataset(name)
    q = jnp.asarray(queries_for(name))
    for rho in (0.05, 0.1, 0.2, 0.5):
        d = bulk_load(keys, cm=CostModel(rho=rho), sample_stride=4)
        f = flatten(d)
        idx = DeviceSnapshot.from_flat(f)
        t = time_fn(lambda q: S.search_batch(idx, q, early_exit=True), q)
        s = d.stats()
        csv_row(f"table7,rho={rho}", t / N_QUERIES * 1e9,
                f"avg_h={s['avg_height']:.2f};bytes/key="
                f"{f.nbytes() / len(keys):.1f}")
    print("# Table 8: lambda sweep")
    import time as _t
    half = keys[::2]
    other = np.setdiff1d(keys, half)[:30000]
    for lam in (1.5, 2.0, 4.0, 8.0):
        d = bulk_load(half, lam=lam, sample_stride=4)
        t0 = _t.perf_counter()
        for j, k in enumerate(other):
            d.insert(float(k), j)
        t_ins = (_t.perf_counter() - t0) / len(other)
        f = flatten(d)
        idx = DeviceSnapshot.from_flat(f)
        t = time_fn(lambda q: S.search_batch(idx, q, early_exit=True), q)
        s = d.stats()
        csv_row(f"table8,lambda={lam}", t / N_QUERIES * 1e9,
                f"ins_us={t_ins * 1e6:.1f};avg_h={s['avg_height']:.2f};"
                f"adj={s['adjustments']}")


def table9_breakdown():
    """Table 9: step-1/step-2 breakdown, DILI vs BU-Tree."""
    print("# Table 9: search step breakdown (probe counts)")
    for name in DATASETS:
        keys, d, f, idx = dili_for(name)
        rng = np.random.default_rng(6)
        picks = keys[rng.integers(0, len(keys), 400)]
        n1 = n2 = 0
        for k in picks:
            _, nodes, probes = d.search_stats(float(k))
            n1 += nodes
            n2 += probes
        csv_row(f"table9,{name},DILI", 0.0,
                f"step1_nodes={n1 / 400:.2f};step2_probes={n2 / 400:.2f}")
        bu = build_bu_tree(keys, sample_stride=4)
        nn = pp = 0
        for k in picks:
            _, nodes, probes = bu_search(bu, keys, float(k))
            nn += nodes
            pp += probes
        csv_row(f"table9,{name},BU-Tree", 0.0,
                f"step1_nodes={nn / 400:.2f};step2_probes={pp / 400:.2f}")


def table10_12_13_appendix():
    """Appendix: memory under write-heavy (T10), adjustment ablation (T12),
    sampled construction (T13)."""
    print("# Tables 10/12/13 (appendix)")
    import time as _t
    for name in DATASETS[:2]:
        keys = dataset(name)
        half = keys[::2]
        other = np.setdiff1d(keys, half)[:40000]
        d = bulk_load(half, sample_stride=4)
        before = d.stats()["memory_bytes"]
        for j, k in enumerate(other):
            d.insert(float(k), j)
        after = d.stats()["memory_bytes"]
        csv_row(f"table10,{name}", 0.0, f"before={before};after={after}")
        # T12: adjustments off (lambda = inf) vs on
        d2 = bulk_load(half, lam=1e18, sample_stride=4)
        t0 = _t.perf_counter()
        for j, k in enumerate(other):
            d2.insert(float(k), j)
        t_noadj = (_t.perf_counter() - t0) / len(other)
        s2 = d2.stats()
        csv_row(f"table12,{name},DILI-AD", t_noadj * 1e6,
                f"avg_h={s2['avg_height']:.2f}")
        d3 = bulk_load(half, sample_stride=4)
        t0 = _t.perf_counter()
        for j, k in enumerate(other):
            d3.insert(float(k), j)
        t_adj = (_t.perf_counter() - t0) / len(other)
        s3 = d3.stats()
        csv_row(f"table12,{name},DILI", t_adj * 1e6,
                f"avg_h={s3['avg_height']:.2f};adj={s3['adjustments']}")
        # T13: sampled construction
        t0 = _t.perf_counter()
        bulk_load(keys, sample_stride=1)
        t_full = _t.perf_counter() - t0
        t0 = _t.perf_counter()
        bulk_load(keys, sample_stride=4)
        t_samp = _t.perf_counter() - t0
        csv_row(f"table13,{name}", 0.0,
                f"build_full_s={t_full:.1f};build_sampled_s={t_samp:.1f}")


def fig9_scale():
    """Fig. 9a: lookup cost vs cardinality."""
    print("# Fig 9a: scalability (ns/query vs n)")
    from repro.data.datasets import generate
    rng = np.random.default_rng(8)
    for n in (50000, 100000, 200000, 400000):
        keys = generate("fb", n, seed=42)
        d = bulk_load(keys, sample_stride=4)
        f = flatten(d)
        idx = DeviceSnapshot.from_flat(f)
        q = jnp.asarray(keys[rng.integers(0, n, N_QUERIES)])
        t = time_fn(lambda q: S.search_batch(idx, q, early_exit=True), q)
        csv_row(f"fig9a,n={n}", t / N_QUERIES * 1e9)


def fig10_shift():
    """Fig. 9b/10: distribution shift / skewed writes."""
    print("# Fig 10: skewed inserts into an FB-built index")
    import time as _t
    fb = dataset("fb")
    logn = dataset("logn")
    span = fb[-1] - fb[0]
    shifted = fb[0] + (logn - logn[0]) / (logn[-1] - logn[0]) * span * 0.1
    shifted = np.setdiff1d(np.unique(shifted), fb)[:30000]
    d = bulk_load(fb, sample_stride=4)
    h0 = d.stats()["avg_height"]
    t0 = _t.perf_counter()
    for j, k in enumerate(shifted):
        d.insert(float(k), j)
    dt = (_t.perf_counter() - t0) / len(shifted)
    s = d.stats()
    csv_row("fig10,fb<-logn,insert_us", dt * 1e6,
            f"avg_h:{h0:.2f}->{s['avg_height']:.2f};adj={s['adjustments']}")


def online_mixed():
    """Mixed read/write workloads through the online-update subsystem:
    fused snapshot+overlay lookups, overlay writes, merge-policy publishes.
    Reports lookups/s, writes/s, and publish stalls (merge count + wall s)."""
    print("# online: mixed read/write (lookup/insert/delete) workloads")
    import time as _t
    from repro.online import MergePolicy, OnlineIndex
    for name in DATASETS:
        keys = dataset(name)
        half = keys[::2]
        other = np.setdiff1d(keys, half)
        rng = np.random.default_rng(12)
        for wl, read_frac in (("95r5w", 0.95), ("50r50w", 0.50)):
            oi = OnlineIndex(half, sample_stride=4, overlay_cap=8192,
                             policy=MergePolicy(max_fill=0.5,
                                                max_writes=16384))
            B, n_rounds = 4096, 16
            n_reads = n_writes = 0
            t_read = t_write = 0.0
            inserted: list = []
            wi = 0
            # warmup: trace/compile the fused lookup outside the timed window
            oi.lookup(jnp.asarray(half[:B]))
            for _ in range(n_rounds):
                q = jnp.asarray(half[rng.integers(0, len(half), B)])
                t0 = _t.perf_counter()
                v, f = oi.lookup(q)
                t_read += _t.perf_counter() - t0
                n_reads += B
                nw = int(round(B * (1 - read_frac) / read_frac))
                ups = other[wi % len(other): wi % len(other) + (2 * nw) // 3]
                wi += len(ups)
                dels = inserted[: nw - len(ups)]
                inserted = inserted[len(dels):]
                t0 = _t.perf_counter()
                if len(ups):
                    oi.upsert_batch(ups, 1_000_000 + np.arange(len(ups)))
                    inserted.extend(ups)
                if len(dels):
                    oi.delete_batch(np.asarray(dels))
                t_write += _t.perf_counter() - t0
                n_writes += len(ups) + len(dels)
            stall_s = sum(st.publish_s for st in oi.store.history[1:])
            csv_row(f"online,{name},{wl},lookups_per_s",
                    n_reads / max(t_read, 1e-9))
            csv_row(f"online,{name},{wl},writes_per_s",
                    n_writes / max(t_write, 1e-9))
            csv_row(f"online,{name},{wl},publish_stalls", oi.n_merges,
                    f"stall_s={stall_s:.3f};epochs={oi.epoch};"
                    f"reasons={dict(oi.merge_reasons)}")


def kernel_bench():
    """Pallas kernel (interpret) vs pure-XLA batched search + bytes/query."""
    print("# kernel: dili_search")
    from repro.kernels import ops as K
    from repro.core import search as S2
    name = DATASETS[0]
    keys = dataset(name)[:200000]
    d, keys32 = K.build_f32_index(keys)
    f = flatten(d)
    arrs = K.kernel_arrays(f)
    rng = np.random.default_rng(9)
    q = jnp.asarray(keys32[rng.integers(0, len(keys32), 16384)], jnp.float32)
    t = time_fn(lambda q: K.dili_search(arrs, q), q)
    csv_row("kernel,pallas_interpret", t / 16384 * 1e9,
            f"table_bytes={K.table_bytes(arrs)}")
    idx = K._as_search_idx(arrs)
    # depth resolves from the snapshot's own max_depth entry — no threading
    t2 = time_fn(lambda q: S2.search_batch(idx, q, early_exit=True), q)
    csv_row("kernel,xla_f32", t2 / 16384 * 1e9)
    # roofline: bytes/query on the device path (node+slot rows touched)
    v, fnd, nodes, probes = S2.search_batch(idx, q, with_stats=True)
    node_row, slot_row = 17, 9      # f32 snapshot row sizes
    bpq = float(np.asarray(nodes).mean()) * node_row \
        + float(np.asarray(probes).mean()) * slot_row
    csv_row("kernel,bytes_per_query", bpq,
            "v5e HBM roofline: 819e9/bytes_per_query lookups/s/chip")


def _facade_measure(name: str) -> tuple[float, float]:
    """One measurement recipe for the facade serving path (shared by
    facade_bench and the BENCH_PR2.json facade sections so the two can
    never drift): lookup ns/query over the standard query draw, and range
    us/query over 512 100-key windows, through `LearnedIndex` on ENGINE.
    Numbers include the host<->device boundary the facade owns."""
    ix = index_for(name, ENGINE)
    keys = dataset(name)
    q = queries_for(name)
    t = time_fn(lambda: ix.lookup(q))
    v, f = ix.lookup(q[:4096])
    assert bool(f.all()), (ENGINE, name)
    rng = np.random.default_rng(3)
    starts = rng.integers(0, len(keys) - 101, 512)
    tr = time_fn(lambda: ix.range(keys[starts], keys[starts + 100],
                                  max_hits=128))
    return t / N_QUERIES * 1e9, tr / 512 * 1e6


def facade_bench():
    """LearnedIndex end-to-end on the engine selected by --engine."""
    print(f"# facade: LearnedIndex on the '{ENGINE}' engine")
    for name in DATASETS:
        lookup_ns, range_us = _facade_measure(name)
        csv_row(f"facade,{ENGINE},{name},lookup_ns", lookup_ns,
                f"max_depth={index_for(name, ENGINE).stats()['max_depth']}")
        csv_row(f"facade,{ENGINE},{name},range_us", range_us)


def _maint_config(mode: str):
    from repro.api import MaintenanceConfig
    if mode == "off":
        return None
    if mode == "norecluster":
        # incremental maintenance with locality re-clustering disabled —
        # the ablation leg of --maintenance recluster-compare
        return MaintenanceConfig(recluster=False)
    return MaintenanceConfig(background=(mode == "background"))


def _latency_percentiles(timings: list[dict]) -> dict:
    """merge/publish wall-time percentiles (ms) over the run's merges, via
    the repo's ONE percentile recipe (`repro.obs.latency_summary`) — same
    keys/method as the runner's `latency_ms` and `metrics()` histograms."""
    from repro.obs import latency_summary
    if not timings:
        return dict(n_publishes=0)
    out: dict = dict(n_publishes=len(timings))
    for field in ("merge_s", "publish_s"):
        out.update(latency_summary((t[field] for t in timings),
                                   prefix=field[:-2]))  # merge_s -> merge
    out["dirty_row_fraction_mean"] = float(
        np.mean([t["dirty_frac"] for t in timings]))
    return out


def workload_bench(preset: str, maint_mode: str) -> dict:
    """YCSB-style mixed workload through the facade on ENGINE, oracle-
    checked batch by batch (any divergence raises -> the job fails).

    Returns BENCH_PR2.json-schema sections keyed `workload,<preset>`
    (plus `,bg` for background mode) so ``--workload X --pr2-json`` lands
    mixed-workload throughput AND merge/publish latency percentiles in
    the existing trajectory artifact.  `maint_mode` "compare" runs the
    preset twice — full-flatten baseline vs incremental maintenance — so
    the artifact records the publish-latency delta the maintenance
    subsystem buys.  Sized by BENCH_WORKLOAD_OPS / BENCH_WORKLOAD_BATCH;
    keys are the integer workload universe (see common.workload_universe),
    NOT the float datasets — popularity shape, not key shape, is what a
    mixed workload measures, and integer keys keep the oracle diff
    bit-exact on every engine including pallas/f32."""
    from repro.api import IndexConfig, LearnedIndex
    from repro.workloads import (PRESETS, WorkloadDivergence, WorkloadRunner,
                                 generate_stream)
    spec = PRESETS[preset].scaled(n_ops=N_WORKLOAD_OPS,
                                  batch_size=N_WORKLOAD_BATCH)
    keys = workload_universe()
    suffixes = {"off": "", "incremental": ",maint", "background": ",bg",
                "norecluster": ",maint,norecluster"}
    if maint_mode == "compare":
        runs = [("", "off"), (",maint", "incremental")]
    elif maint_mode == "recluster-compare":
        # the zipfian splice-locality ablation: incremental maintenance
        # with vs without heat-driven segment re-clustering — the merge
        # p50 / dirty-row-fraction delta re-clustering buys
        runs = [(",maint", "incremental"),
                (",maint,norecluster", "norecluster")]
    else:
        runs = [(suffixes[maint_mode], maint_mode)]
    sections: dict = {}
    for suffix, mode in runs:
        print(f"# workload: {preset} on the '{ENGINE}' engine "
              f"({spec.n_ops} ops, oracle-checked, maintenance={mode})")
        # default (auto) merge policy: write-heavy mixes must exercise the
        # overlay -> merge -> republish lifecycle, not pile into the overlay
        ix = LearnedIndex.build(keys, config=IndexConfig(
            engine=ENGINE, sample_stride=4, overlay_cap=8192,
            maintenance=_maint_config(mode),
            telemetry=bool(METRICS_JSON)))
        rep = WorkloadRunner(ix).run(generate_stream(spec, keys), spec=spec)
        d = rep.to_json_dict()
        d["maintenance"] = mode
        d["n_keys"] = len(keys)     # per-section scale stamp: sections at
        # different BENCH_N_KEYS coexist in one artifact self-describingly
        # flush = the synchronous barrier: folds the tail of pending
        # writes and drains any in-flight background merge, so the
        # reported counts/percentiles are deterministic and complete
        # (sampling mid-fold used to report merges=0 racily)
        st = ix.flush()
        if st.get("maint_errors"):
            # the runner's in-stream check can race an in-flight worker;
            # errors are cumulative, so re-assert after the flush barrier
            raise WorkloadDivergence(
                f"{preset}: {st['maint_errors']} background maintenance "
                f"task(s) failed\n" + "\n".join(st.get("maint_error_logs",
                                                       [])))
        d["n_merges"] = st["n_merges"]
        d["epoch"] = st["epoch"]
        d.update(_latency_percentiles(ix.maint_timings()))
        d["n_retrains"] = st["n_retrains"]
        d["n_incremental_flattens"] = st["n_incremental_flattens"]
        d["n_reclusters"] = st.get("n_reclusters", 0)
        d["n_forced_full_flattens"] = st.get("n_forced_full_flattens", 0)
        # retrace watchdog: the runner marked warm after its warmup
        # batches, so any later trace is a regression (the PR-4 bug class)
        m = ix.metrics()
        d["post_warmup_retraces"] = m["retrace"]["post_warmup_traces"]
        d["retraces_per_1k_ops"] = m["retrace"]["retraces_per_1k_ops"]
        ix.close()
        tag = f"workload,{preset}{suffix}"
        csv_row(f"{tag},{ENGINE},ops_per_s", d["ops_per_s"],
                f"n_ops={d['n_ops']};merges={d['n_merges']};"
                f"epoch={d['epoch']};divergences={d['n_divergences']};"
                f"maintenance={mode}")
        for op, n in rep.op_counts.items():
            if n:
                csv_row(f"{tag},{ENGINE},{op}_us",
                        1e6 * rep.op_seconds[op] / n, f"n={n}")
        if d.get("n_publishes"):
            csv_row(f"{tag},{ENGINE},merge_ms_p50", d["merge_ms_p50"],
                    f"p95={d['merge_ms_p95']:.1f};"
                    f"p99={d['merge_ms_p99']:.1f};max={d['merge_ms_max']:.1f}")
            csv_row(f"{tag},{ENGINE},publish_ms_p50", d["publish_ms_p50"],
                    f"p95={d['publish_ms_p95']:.1f};"
                    f"p99={d['publish_ms_p99']:.1f};"
                    f"max={d['publish_ms_max']:.1f};"
                    f"dirty={d['dirty_row_fraction_mean']:.3f}")
        sections[tag] = d
        if METRICS_JSON:
            METRICS_SECTIONS[tag] = _metrics_section(m, len(keys))
    return sections


N_RECOVERY_RECORDS = int(os.environ.get("BENCH_RECOVERY_RECORDS", "10000"))


def durability_bench() -> dict:
    """Durability sections for BENCH_PR2.json (``--durability``), same
    one-dict-per-section schema as every other extra section:

      durability,wal_overhead   ycsb_a throughput with durability off vs
                                fsync="interval" (overhead_frac: DESIGN.md
                                section 14 targets <= 0.15)
      durability,recovery       wall time to recover a checkpoint plus a
                                BENCH_RECOVERY_RECORDS-record WAL tail,
                                split into the recovery.load/replay spans
      durability,kill_recover   ycsb_a replayed halfway, index abandoned
                                (a SIGKILL's disk state), recovered, and
                                the rest of the stream continued on the
                                recovered index — oracle-checked, so any
                                divergence raises and fails the run
    """
    import shutil
    import tempfile
    import time as _t
    from repro.api import IndexConfig, LearnedIndex, manual_merge_policy
    from repro.durability import DurabilityConfig
    from repro.workloads import PRESETS, WorkloadRunner, generate_stream
    keys = workload_universe()
    spec = PRESETS["ycsb_a"].scaled(n_ops=N_WORKLOAD_OPS,
                                    batch_size=N_WORKLOAD_BATCH)
    root = tempfile.mkdtemp(prefix="dili_dur_bench_")
    sections: dict = {}
    try:
        # -- WAL-append overhead: the same stream, throughput-only runner,
        # durability off vs group-commit + interval fsync
        print(f"# durability: ycsb_a WAL overhead on the '{ENGINE}' engine "
              f"({spec.n_ops} ops, fsync=interval)")
        ops_per_s: dict = {}
        for label in ("warmup", "off", "interval"):
            # checkpoint_every_merges=8: the section isolates the per-write
            # WAL append + group-commit cost; the default every-merge
            # cadence folds full-snapshot checkpoint writes into the same
            # number (~3 merges in this stream => +40% at 300k keys),
            # which the recovery section already prices separately
            dur = None if label in ("warmup", "off") else DurabilityConfig(
                dir=os.path.join(root, "overhead"), fsync="interval",
                checkpoint_every_merges=8)
            ix = LearnedIndex.build(keys, config=IndexConfig(
                engine=ENGINE, sample_stride=4, overlay_cap=8192,
                durability=dur))
            rep = WorkloadRunner(ix, check=False).run(
                generate_stream(spec, keys), spec=spec,
                name=f"ycsb_a[durability={label}]")
            ix.flush()
            ix.close()
            # the warmup pass exists to mint every executable the stream
            # needs (process-wide jit cache) so neither timed leg pays
            # compile costs; its throughput is discarded
            ops_per_s[label] = rep.ops_per_s
        overhead = 1.0 - ops_per_s["interval"] / ops_per_s["off"]
        sections["durability,wal_overhead"] = dict(
            n_keys=len(keys),
            preset="ycsb_a", engine=ENGINE, fsync="interval",
            checkpoint_every_merges=8,
            n_ops=spec.n_ops, base_ops_per_s=ops_per_s["off"],
            durable_ops_per_s=ops_per_s["interval"],
            overhead_frac=overhead)
        csv_row(f"durability,wal_overhead,{ENGINE},ops_per_s",
                ops_per_s["interval"],
                f"base={ops_per_s['off']:.0f};"
                f"overhead_frac={overhead:.3f};fsync=interval")
        # -- recovery time: one checkpoint + an N_RECOVERY_RECORDS-record
        # tail (manual merges: no publish, so nothing truncates the WAL)
        print(f"# durability: recovery of a {N_RECOVERY_RECORDS}-record "
              f"WAL tail on the '{ENGINE}' engine")
        rdir = os.path.join(root, "recovery")
        ix = LearnedIndex.build(keys, config=IndexConfig(
            engine=ENGINE, sample_stride=4, overlay_cap=1 << 20,
            merge=manual_merge_policy(),
            durability=DurabilityConfig(dir=rdir, fsync="interval")))
        rng = np.random.default_rng(21)
        pool = keys[rng.integers(0, len(keys), 8192)]
        for i in range(N_RECOVERY_RECORDS):
            k = pool[(4 * i) % 8192: (4 * i) % 8192 + 4]
            ix.upsert(k, np.full(len(k), i, np.int64))
        ix.abandon()                 # no final fsync: a crash's disk state
        t0 = _t.perf_counter()
        rix = LearnedIndex.recover(rdir)
        recovery_s = _t.perf_counter() - t0
        m = rix.metrics()
        spans = m["spans"]
        sections["durability,recovery"] = dict(
            n_keys=len(keys),
            engine=ENGINE, tail_records=N_RECOVERY_RECORDS,
            recovery_s=recovery_s,
            replayed_records=int(m["counters"]
                                 ["recovery.replayed_records"]),
            load_ms=spans["recovery.load"]["ms_mean"],
            replay_ms=spans["recovery.replay"]["ms_mean"],
            publish_ms=spans["recovery.publish"]["ms_mean"])
        rix.close()
        csv_row(f"durability,recovery,{ENGINE},recovery_s", recovery_s,
                f"tail_records={N_RECOVERY_RECORDS};"
                f"load_ms={spans['recovery.load']['ms_mean']:.1f};"
                f"replay_ms={spans['recovery.replay']['ms_mean']:.1f}")
        # -- kill-and-recover replay: differential, strict — divergence
        # raises out of the benchmark run
        print(f"# durability: ycsb_a kill-and-recover replay on the "
              f"'{ENGINE}' engine (oracle-checked)")
        ix = LearnedIndex.build(keys, config=IndexConfig(
            engine=ENGINE, sample_stride=4, overlay_cap=8192,
            durability=DurabilityConfig(
                dir=os.path.join(root, "kill"), fsync="interval")))
        runner = WorkloadRunner(ix)
        batches = generate_stream(spec, keys)
        kr = runner.run_kill_recover(batches, kill_at=len(batches) // 2,
                                     spec=spec, name="ycsb_a")
        runner.index.close()
        sections["durability,kill_recover"] = dict(
            n_keys=len(keys),
            engine=ENGINE, preset="ycsb_a",
            kill_at_batch=kr["kill_at_batch"],
            recovery_s=kr["recovery_s"],
            replayed_records=kr["replayed_records"],
            n_divergences=kr["n_divergences"])
        csv_row(f"durability,kill_recover,{ENGINE},recovery_s",
                kr["recovery_s"],
                f"kill_at_batch={kr['kill_at_batch']};"
                f"replayed={kr['replayed_records']};"
                f"divergences={kr['n_divergences']}")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    return sections


# --serve sizing knobs: ops per load leg (capped), concurrent client
# streams, ops per client request (small on purpose — coalescing many
# small requests into pow2 facade batches is the thing under test),
# initial offered rate for the saturation ramp, and target leg seconds
N_SERVE_OPS = int(os.environ.get("BENCH_SERVE_OPS",
                                 str(min(N_WORKLOAD_OPS, 20000))))
N_SERVE_CLIENTS = int(os.environ.get("BENCH_SERVE_CLIENTS", "4"))
N_SERVE_REQ_OPS = int(os.environ.get("BENCH_SERVE_REQ_OPS", "16"))
SERVE_START_RATE = float(os.environ.get("BENCH_SERVE_START_RATE", "4000"))
SERVE_LEG_S = float(os.environ.get("BENCH_SERVE_LEG_S", "2.0"))


class _StreamTap:
    """Sequential request supply for the serving legs: one generator
    stream consumed front to back (slices must stay in order — later
    deletes name keys earlier slices inserted), regenerated with a fresh
    seed when it runs dry.  Perf legs only; the oracle leg uses its own
    single dedicated stream."""

    def __init__(self, spec, keys):
        self.spec, self.keys = spec, keys
        self.seed = spec.seed
        self._refill()

    def _refill(self):
        from repro.workloads import generate_stream
        self.batches = generate_stream(
            self.spec.scaled(seed=self.seed), self.keys)
        self.seed += 1
        self.i = 0

    def take(self, n_ops: int) -> list:
        out, got = [], 0
        while got < n_ops:
            if self.i >= len(self.batches):
                self._refill()
            b = self.batches[self.i]
            self.i += 1
            out.append(b)
            got += b.n_ops
        return out


def _serve_leg_ops(rate: float) -> int:
    return int(np.clip(rate * SERVE_LEG_S, 1000, N_SERVE_OPS))


def _serve_index(keys, *, background: bool, telemetry: bool):
    from repro.api import IndexConfig, LearnedIndex, MaintenanceConfig
    return LearnedIndex.build(keys, config=IndexConfig(
        engine=ENGINE, sample_stride=4, overlay_cap=8192,
        maintenance=MaintenanceConfig(background=background),
        telemetry=telemetry))


def _warm_serve_buckets(ix, keys, cfg) -> None:
    """Mint every read-path executable the coalescer can reach (pow2
    lane buckets from one request up to the batch cap) before the timed
    legs, then anchor the retrace watchdog."""
    k0 = float(keys[0])
    b = 64
    while b <= cfg.max_batch_ops:
        ix.lookup(np.full(b, k0))
        ix.range(np.full(b, k0), np.full(b, k0 + 4.0),
                 max_hits=cfg.max_hits)
        b *= 2
    ix.telemetry.mark_warm()


def _leg_brief(rep) -> dict:
    lat = rep.latency_ms()
    return dict(offered_ops_per_s=rep.offered_ops_per_s,
                achieved_ops_per_s=rep.achieved_ops_per_s,
                n_ops=rep.n_ops, shed_frac=rep.shed_frac,
                late_submits=rep.late_submits,
                lookup_ms_p99=lat.get("lookup", {}).get("ms_p99"))


def serve_bench(preset: str) -> dict:
    """Concurrent serving sections for BENCH_PR2.json (``--serve``): the
    open-loop throughput-latency curve of the `repro.serve` front-end on
    ENGINE (DESIGN.md section 15).

    Per preset, one `serve,<preset>` section recording

      * saturation_ops_per_s — geometric offered-rate ramp until the
        batcher stops keeping up; best achieved rate across legs;
      * latency_at — full p50/p95/p99/p999 end-to-end (scheduled arrival
        -> completion) per op at 50%/80%/95% of saturation;
      * oracle — a journaled run at 50% saturation replayed through
        `WorkloadRunner` on a fresh index: any batch-level divergence
        raises, and the fresh index's final items() must equal the
        served index's bit-exactly (n_divergences is asserted 0 here,
        not just reported);
      * maintenance_compare — the SAME offered load against background
        vs synchronous maintenance (local engine; the ROADMAP's "prove
        background merges pay off under traffic" number).

    Request granularity is N_SERVE_REQ_OPS ops (default 16): small
    requests from N_SERVE_CLIENTS concurrent client threads, coalesced
    by the batcher into pow2 facade batches."""
    from repro.serve import ServeConfig, ServeFrontend, open_loop, \
        saturation_search
    from repro.workloads import PRESETS, WorkloadRunner, generate_stream
    keys = workload_universe()
    spec = PRESETS[preset].scaled(n_ops=N_SERVE_OPS,
                                  batch_size=N_SERVE_REQ_OPS)
    scfg = ServeConfig()
    bg_main = ENGINE == "local"     # background maintenance is local-only
    tag = f"serve,{preset}"
    sec: dict = dict(engine=ENGINE, preset=preset, n_keys=len(keys),
                     n_clients=N_SERVE_CLIENTS, req_ops=N_SERVE_REQ_OPS,
                     background_maintenance=bg_main)

    # -- saturation ramp + latency legs on one served index ------------------
    print(f"# serve: {preset} on the '{ENGINE}' engine "
          f"({N_SERVE_CLIENTS} open-loop client streams, "
          f"{N_SERVE_REQ_OPS}-op requests)")
    ix = _serve_index(keys, background=bg_main,
                      telemetry=bool(METRICS_JSON) or bool(TRACE_JSON))
    _warm_serve_buckets(ix, keys, scfg)
    tap = _StreamTap(spec, keys)
    fe = ServeFrontend(ix, scfg, journal=False)

    def mk(_leg):
        # mirrors saturation_search's geometric ramp (factor=2.0 below)
        return tap.take(_serve_leg_ops(SERVE_START_RATE * (2.0 ** _leg)))

    sat, ramp = saturation_search(fe, mk, SERVE_START_RATE, factor=2.0,
                                  max_legs=7, n_clients=N_SERVE_CLIENTS)
    sec["saturation_ops_per_s"] = sat
    sec["ramp"] = [_leg_brief(l) for l in ramp]
    csv_row(f"{tag},{ENGINE},saturation_ops_per_s", sat,
            f"ramp_legs={len(ramp)};clients={N_SERVE_CLIENTS}")
    sec["latency_at"] = {}
    for li, frac in enumerate((0.5, 0.8, 0.95)):
        rate = frac * sat
        # --trace-json: arm causal tracing on the FIRST latency leg only
        # (the 50% one — comfortably under saturation, so the exported
        # queue/exec/facade/WAL/merge chains show steady-state serving,
        # not overload shedding)
        trace = TRACE_JSON if (TRACE_JSON and li == 0) else None
        rep = open_loop(fe, tap.take(_serve_leg_ops(rate)), rate,
                        n_clients=N_SERVE_CLIENTS, trace_path=trace)
        if trace:
            print(f"# serve: wrote causal trace {trace} "
                  f"(open in Perfetto / chrome://tracing)")
        d = rep.to_json_dict()
        sec["latency_at"][f"{int(frac * 100)}%"] = d
        lk = d["latency_ms"].get("lookup", {})
        csv_row(f"{tag},{ENGINE},p99_at_{int(frac * 100)}pct",
                lk.get("ms_p99", 0.0),
                f"rate={rate:.0f};achieved={rep.achieved_ops_per_s:.0f};"
                f"p50={lk.get('ms_p50', 0.0):.2f};"
                f"p999={lk.get('ms_p999', 0.0):.2f};"
                f"shed={rep.shed_frac:.3f}")
    sec["batcher"] = fe.stats()
    fe.close()
    if METRICS_JSON:
        METRICS_SECTIONS[tag] = _metrics_section(ix.metrics(), len(keys))
    ix.close()

    # -- oracle equivalence: journaled 50%-rate run, replayed ----------------
    print(f"# serve: {preset} oracle equivalence "
          f"(journal replay on a fresh index)")
    served = _serve_index(keys, background=bg_main, telemetry=False)
    _warm_serve_buckets(served, keys, scfg)
    fe = ServeFrontend(served, scfg, journal=True)
    ostream = generate_stream(spec.scaled(seed=spec.seed + 977), keys)
    orep = open_loop(fe, ostream, max(0.5 * sat, SERVE_START_RATE),
                     n_clients=N_SERVE_CLIENTS)
    journal = fe.journal_batches()
    fe.close()
    fresh = _serve_index(keys, background=False, telemetry=False)
    # strict replay: any batch-level oracle divergence raises out of the
    # bench run (CI-visible), same policy as workload_bench
    wrep = WorkloadRunner(fresh).run(journal, name=f"{tag},replay")
    k1, v1 = served.items()
    k2, v2 = fresh.items()
    bit_exact = bool(np.array_equal(k1, k2) and np.array_equal(v1, v2))
    served.close()
    fresh.close()
    if not bit_exact:
        raise AssertionError(
            f"{tag}: concurrent run's final items() diverged from its "
            f"own journal's deterministic replay")
    sec["oracle"] = dict(n_divergences=len(wrep.divergences),
                         journal_batches=len(journal),
                         checked_ops=wrep.n_ops, bit_exact=bit_exact,
                         shed_frac=orep.shed_frac)
    csv_row(f"{tag},{ENGINE},oracle_divergences", len(wrep.divergences),
            f"journal_batches={len(journal)};bit_exact={bit_exact}")

    # -- background vs sync maintenance under identical load -----------------
    sec["maintenance_compare"] = {}
    modes = (("background", True), ("sync", False)) if bg_main \
        else (("sync", False),)
    cmp_rate = 0.8 * sat
    for label, bg in modes:
        print(f"# serve: {preset} maintenance={label} at 80% saturation")
        cix = _serve_index(keys, background=bg, telemetry=False)
        _warm_serve_buckets(cix, keys, scfg)
        cfe = ServeFrontend(cix, scfg, journal=False)
        ctap = _StreamTap(spec.scaled(seed=spec.seed + 1531), keys)
        # 2x leg length: the comparison must cross the overlay-cap merge
        # threshold so maintenance actually runs inside the timed window
        crep = open_loop(cfe, ctap.take(2 * _serve_leg_ops(cmp_rate)),
                         cmp_rate, n_clients=N_SERVE_CLIENTS,
                         timeout_s=240.0)
        cfe.close()
        st = cix.stats()
        d = _leg_brief(crep)
        d["n_merges"] = st.get("n_merges")
        lat = crep.latency_ms().get("lookup", {})
        d["lookup_ms_p50"] = lat.get("ms_p50")
        d["lookup_ms_p999"] = lat.get("ms_p999")
        sec["maintenance_compare"][label] = d
        cix.close()
        csv_row(f"{tag},{ENGINE},maint_{label}_p99",
                d["lookup_ms_p99"] or 0.0,
                f"achieved={d['achieved_ops_per_s']:.0f};"
                f"merges={d['n_merges']};rate={cmp_rate:.0f}")
    if bg_main and "background" in sec["maintenance_compare"]:
        b = sec["maintenance_compare"]["background"]
        s = sec["maintenance_compare"]["sync"]
        if b["lookup_ms_p99"] and s["lookup_ms_p99"]:
            sec["maintenance_compare"]["p99_speedup_bg_over_sync"] = \
                s["lookup_ms_p99"] / b["lookup_ms_p99"]
    return {tag: sec}


def scale_bench() -> dict:
    """Scale sections for BENCH_PR2.json (``--scale``): build cost, peak
    memory footprint, and depth-resolved traversal cost at the CURRENT
    BENCH_N_KEYS, over the int64-valued workload universe (the same keys
    the oracle-checked workload legs use, so the numbers describe the
    serving configuration end to end).

      scale,build      bulk_load + flatten wall seconds, process peak RSS
                       (`peak_rss_mb` — the memory-footprint field the CI
                       scale leg asserts on), snapshot bytes/key, splice
                       segment count, and tree height stats
      scale,traversal  lookup ns/query at the REAL tree height of this
                       scale, decomposed per level (nodes walked) and per
                       memory touch (nodes + slot probes) — how lookup
                       cost actually grows with cardinality, not a
                       fixed-depth extrapolation
    """
    import resource
    import time as _t
    keys = workload_universe()
    print(f"# scale: build + traversal at n_keys={len(keys)}")
    t0 = _t.perf_counter()
    d = bulk_load(keys, sample_stride=4)
    build_s = _t.perf_counter() - t0
    t0 = _t.perf_counter()
    f = flatten(d)
    flatten_s = _t.perf_counter() - t0
    idx = DeviceSnapshot.from_flat(f)
    # ru_maxrss is KiB on Linux: the high-water mark across build+flatten
    # (host tree + snapshot both live), the number a capacity plan needs
    peak_rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    s = d.stats()
    sections: dict = {}
    sections["scale,build"] = dict(
        n_keys=len(keys), build_s=build_s, flatten_s=flatten_s,
        peak_rss_mb=peak_rss_mb, flat_mb=f.nbytes() / 2 ** 20,
        bytes_per_key=f.nbytes() / len(keys), n_segments=f.n_segments,
        max_depth=f.max_depth, avg_height=s["avg_height"],
        conflicts_per_1k=1000.0 * s["conflicts"] / len(keys))
    csv_row(f"scale,build,n={len(keys)}", build_s,
            f"flatten_s={flatten_s:.2f};peak_rss_mb={peak_rss_mb:.0f};"
            f"bytes_per_key={f.nbytes() / len(keys):.1f};"
            f"segments={f.n_segments};max_depth={f.max_depth}")
    rng = np.random.default_rng(31)
    q = jnp.asarray(keys[rng.integers(0, len(keys), N_QUERIES)])
    t = time_fn(lambda q: S.search_batch(idx, q, early_exit=True), q)
    v, fnd, nodes, probes = S.search_batch(idx, q, with_stats=True)
    assert bool(np.asarray(fnd).all())
    mean_nodes = float(np.asarray(nodes).mean())
    mean_probes = float(np.asarray(probes).mean())
    ns = t / N_QUERIES * 1e9
    sections["scale,traversal"] = dict(
        n_keys=len(keys), ns_per_query=ns, max_depth=f.max_depth,
        mean_nodes=mean_nodes, mean_probes=mean_probes,
        ns_per_level=ns / max(mean_nodes, 1.0),
        ns_per_touch=ns / max(mean_nodes + mean_probes, 1.0))
    csv_row(f"scale,traversal,n={len(keys)}", ns,
            f"max_depth={f.max_depth};nodes={mean_nodes:.2f};"
            f"probes={mean_probes:.2f};"
            f"ns_per_level={ns / max(mean_nodes, 1.0):.1f}")
    return sections


ALL = [table4_lookup, table5_access, table6_stats, fig6_memory_range,
       fig7_workloads, fig8_deletions, table78_hyperparams, table9_breakdown,
       table10_12_13_appendix, fig9_scale, fig10_shift, online_mixed,
       kernel_bench, facade_bench]


def bench_pr2(out_path: str, extra_sections: dict | None = None) -> dict:
    """Hot-path trajectory artifact (BENCH_PR2.json): re-measure the PR-2
    hot paths ALONGSIDE the pre-PR numbers (benchmarks/baseline_pre_pr2.json,
    captured on the pre-PR tree at the same scales) with derived speedups.
    Since the api redesign the same file also records the facade numbers for
    the engine selected by --engine (same schema, new `engine` field +
    `facade_*` sections) — one format, extended, per ROADMAP."""
    import json
    from common import N_KEYS
    base_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "baseline_pre_pr2.json")
    baseline = {}
    if os.path.exists(base_path):
        baseline = json.load(open(base_path))
    if baseline and baseline.get("n_keys") != N_KEYS:
        # speedups are only meaningful at the baseline's scale
        print(f"# WARNING: baseline captured at n_keys={baseline.get('n_keys')}"
              f" but this run uses {N_KEYS}; skipping speedup comparison")
        baseline = {}
    base_sec = baseline.get("sections", {})
    print("# PR2: hot-path trajectory vs pre-PR baseline")
    out: dict = dict(n_keys=N_KEYS, n_queries=N_QUERIES,
                     baseline_n_keys=baseline.get("n_keys"),
                     engine=ENGINE,
                     cost_model="depth-exact traversal + early exit; "
                                "O(log n + max_hits) sorted-pair ranges",
                     sections={})
    for name in DATASETS:
        keys, d, f, idx = dili_for(name)
        q = jnp.asarray(queries_for(name))
        t = time_fn(lambda q: S.search_batch(idx, q, early_exit=True), q)
        new_ns = t / N_QUERIES * 1e9
        old = base_sec.get(f"point_lookup,{name}", {})
        old_ns = old.get("ns_per_query")
        out["sections"][f"point_lookup,{name}"] = dict(
            n_keys=N_KEYS,
            ns_per_query=new_ns, pre_pr_ns_per_query=old_ns,
            speedup=(old_ns / new_ns) if old_ns else None,
            max_depth=f.max_depth)
        csv_row(f"pr2,point_lookup,{name}", new_ns,
                f"pre_pr={old_ns};speedup="
                f"{(old_ns / new_ns) if old_ns else float('nan'):.2f}x")
        rng = np.random.default_rng(3)
        starts = rng.integers(0, len(keys) - 101, 512)
        lo = jnp.asarray(keys[starts])
        hi = jnp.asarray(keys[starts + 100])
        tr = time_fn(lambda lo, hi: S.range_query_batch(idx, lo, hi,
                                                        max_hits=128), lo, hi)
        new_us = tr / 512 * 1e6
        oldr = base_sec.get(f"range_query,{name}", {})
        old_us = oldr.get("us_per_query")
        out["sections"][f"range_query,{name}"] = dict(
            n_keys=N_KEYS,
            us_per_query=new_us, pre_pr_us_per_query=old_us,
            speedup=(old_us / new_us) if old_us else None,
            n_pairs=f.n_pairs)
        csv_row(f"pr2,range_query,{name}", new_us,
                f"pre_pr={old_us};speedup="
                f"{(old_us / new_us) if old_us else float('nan'):.2f}x")
        # facade serving path on the selected engine (host<->device
        # included) — same recipe as `--only facade` (_facade_measure)
        lookup_ns, range_us = _facade_measure(name)
        out["sections"][f"facade_lookup,{name}"] = dict(
            n_keys=N_KEYS, ns_per_query=lookup_ns, engine=ENGINE)
        out["sections"][f"facade_range,{name}"] = dict(
            n_keys=N_KEYS, us_per_query=range_us, engine=ENGINE)
        csv_row(f"pr2,facade_lookup,{name}", lookup_ns, f"engine={ENGINE}")
        csv_row(f"pr2,facade_range,{name}", range_us, f"engine={ENGINE}")
    if extra_sections:
        # mixed-workload sections from --workload: same artifact, same
        # one-dict-per-section schema (ROADMAP: extend, don't fork)
        out["sections"].update(extra_sections)
    with open(out_path, "w") as fh:
        json.dump(out, fh, indent=1)
    print(f"# wrote {out_path}")
    return out


def bench_pr2_extend(out_path: str, extra_sections: dict) -> dict:
    """Merge this run's sections into an EXISTING BENCH_PR2.json without
    re-measuring (or perturbing a single byte of) what is already there —
    how different-scale runs accumulate in one trajectory artifact.

    Every section this run emits carries its own `n_keys` stamp; when the
    run's scale differs from the artifact's top-level `n_keys`, the new
    section keys additionally get an `@n=<scale>` suffix so a 10M
    `workload,ycsb_a,maint` lands NEXT TO the 300k section of the same
    name instead of overwriting it."""
    import json
    from common import N_KEYS
    with open(out_path) as fh:
        out = json.load(fh)
    suffix = "" if out.get("n_keys") == N_KEYS else f"@n={N_KEYS}"
    for tag, sec in extra_sections.items():
        out["sections"][tag + suffix] = sec
    with open(out_path, "w") as fh:
        json.dump(out, fh, indent=1)
    print(f"# extended {out_path} with {len(extra_sections)} section(s)"
          f"{' at n_keys=' + str(N_KEYS) if suffix else ''}")
    return out


def main() -> None:
    import argparse
    import json
    from common import ROWS
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default="",
                    help="write every CSV row (name/value/derived) here")
    ap.add_argument("--pr2-json", default="",
                    help="write the BENCH_PR2.json hot-path trajectory here "
                         "(skips the per-table sections unless --only set)")
    ap.add_argument("--engine", default="local",
                    choices=("local", "pallas", "sharded"),
                    help="LearnedIndex engine for the facade sections, "
                         "--workload, and --pr2-json")
    ap.add_argument("--workload", default="",
                    help="comma-separated workload presets (ycsb_a/b/c/e, "
                         "dili_paper, shift_fb_logn, ttl_storm) replayed "
                         "through the --engine facade with oracle "
                         "checking; one workload,<preset> section each; "
                         "BENCH_WORKLOAD_OPS sizes them")
    ap.add_argument("--serve", default="",
                    help="comma-separated workload presets driven through "
                         "the concurrent serving front-end (repro.serve) "
                         "under open-loop load on --engine: saturation "
                         "ramp, p50/p99/p999 at 50/80/95%% of saturation, "
                         "journal-replay oracle equivalence, and a "
                         "background-vs-sync maintenance comparison; one "
                         "serve,<preset> section each (BENCH_SERVE_OPS / "
                         "BENCH_SERVE_CLIENTS / BENCH_SERVE_REQ_OPS size "
                         "them)")
    ap.add_argument("--scale", action="store_true",
                    help="measure build time, peak RSS memory footprint, "
                         "and depth-resolved traversal cost at the current "
                         "BENCH_N_KEYS (scale,build + scale,traversal "
                         "sections)")
    ap.add_argument("--pr2-extend", default="",
                    help="merge this run's sections into an EXISTING "
                         "BENCH_PR2.json instead of regenerating it; "
                         "pre-existing sections stay byte-identical, and "
                         "sections measured at a different BENCH_N_KEYS "
                         "than the artifact get an @n=<scale> key suffix")
    ap.add_argument("--durability", action="store_true",
                    help="measure the durability subsystem on --engine: "
                         "ycsb_a WAL-append overhead (off vs "
                         "fsync=interval), recovery time for a "
                         "BENCH_RECOVERY_RECORDS-record WAL tail, and an "
                         "oracle-checked kill-and-recover replay; three "
                         "durability,* sections in BENCH_PR2.json")
    ap.add_argument("--trace-json", default="",
                    help="arm end-to-end causal tracing on the first "
                         "--serve latency leg and write the Chrome-trace-"
                         "event JSON here; open it in Perfetto to see each "
                         "request's queue_wait -> exec -> facade -> WAL "
                         "chain with linked merge spans")
    ap.add_argument("--metrics-json", default="",
                    help="build --workload indexes with telemetry enabled "
                         "and write their LearnedIndex.metrics() snapshots "
                         "(per-op histograms, merge-pipeline spans, retrace "
                         "watchdog) here, keyed by workload section")
    ap.add_argument("--maintenance", default="off",
                    choices=("off", "incremental", "background", "compare",
                             "norecluster", "recluster-compare"),
                    help="merge pipeline for --workload runs: legacy full "
                         "flatten (default — keeps pre-PR5 invocations at "
                         "their original cost), adaptive (splice+retrain), "
                         "background thread, 'compare' = off AND "
                         "incremental back-to-back (records the latency "
                         "delta; what BENCH_PR2.json is emitted with), "
                         "'norecluster' = adaptive with segment "
                         "re-clustering disabled, or 'recluster-compare' "
                         "= adaptive with AND without re-clustering "
                         "back-to-back (the zipfian splice-locality "
                         "ablation)")
    args = ap.parse_args()
    global ENGINE, METRICS_JSON, TRACE_JSON
    ENGINE = args.engine
    METRICS_JSON = args.metrics_json
    TRACE_JSON = args.trace_json
    if args.only or not (args.pr2_json or args.pr2_extend or args.workload
                         or args.durability or args.serve or args.scale):
        for fn in ALL:
            if args.only and args.only not in fn.__name__:
                continue
            fn()
    wl_sections: dict = {}
    if args.scale:
        wl_sections.update(scale_bench())
    if args.workload:
        for preset in args.workload.split(","):
            wl_sections.update(workload_bench(preset.strip(),
                                              args.maintenance))
    if args.serve:
        for preset in args.serve.split(","):
            wl_sections.update(serve_bench(preset.strip()))
    if args.durability:
        wl_sections.update(durability_bench())
    if args.pr2_json:
        bench_pr2(args.pr2_json, extra_sections=wl_sections)
    elif args.pr2_extend:
        bench_pr2_extend(args.pr2_extend, wl_sections)
    if args.metrics_json:
        with open(args.metrics_json, "w") as fh:
            json.dump(dict(engine=ENGINE, schema="dili.metrics/1",
                           sections=METRICS_SECTIONS), fh, indent=1)
        print(f"# wrote {args.metrics_json}")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(dict(n_queries=N_QUERIES, rows=ROWS), fh, indent=1)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
