"""Perf-regression sentinel: diff a fresh BENCH_PR2.json emission against
the checked-in artifact with per-section tolerance bands.

The artifact is the repo's hot-path trajectory (benchmarks/run.py
--pr2-json); this tool makes it a *tripwire*: CI re-emits the artifact at
the standard 300k-key scale and the sentinel flags any timing that moved
outside its band.  Metrics are classified by leaf-key pattern:

  median   p50 / mean / ns_per_query / us_per_op / wall seconds —
           stable statistics, tight band (default 1.6x);
  tail     p95 / p99 / p999 / max — noisy on shared CI runners, loose
           band (default 3.0x);
  thrpt    *ops_per_s — higher is better, judged with the ratio
           inverted (band shared with median).

Everything else (counts, n_*, booleans, strings, lists, config echoes
like offered_ops_per_s) is structural, not a timing, and is skipped.
Only sections present in BOTH files are compared, and a section whose
`n_keys` stamp differs between the two is skipped wholesale — an
@n=10000000 section has no business being judged against a 300k run.

Usage:

    python benchmarks/sentinel.py --baseline BENCH_PR2.json \
        --fresh /tmp/BENCH_PR2.fresh.json

    python benchmarks/sentinel.py --baseline BENCH_PR2.json --self-test

Exit status 0 = clean (every compared metric in band), 1 = regression(s)
flagged, 2 = usage/schema error.  `--self-test` proves the tripwire
works: the artifact must pass against itself, and an injected 2x median
regression must be caught.
"""

from __future__ import annotations

import argparse
import copy
import json
import sys
from dataclasses import dataclass

#: leaf-key substrings per class — checked in order, first match wins, so
#: p999/p99/p95 must precede the generic "max"
TAIL_PATTERNS = ("p999", "p99", "p95", "max")
MEDIAN_PATTERNS = ("p50", "mean", "ns_per_query", "us_per_query",
                   "us_per_op", "us_per_call", "overhead_frac",
                   "dirty_row_fraction", "wall_s", "build_s", "flatten_s",
                   "recover_s", "replay_s")
THROUGHPUT_PATTERNS = ("ops_per_s",)
#: keys that LOOK like timings but aren't: offered load is a config echo,
#: pre_pr values are constants replayed from the pre-PR-2 capture, and
#: max_depth is tree structure (its perf effect shows in ns_per_query)
SKIP_PATTERNS = ("offered", "pre_pr", "depth")

#: baselines at/below this are degenerate (ops that never ran) — skipped
EPS = 1e-12


@dataclass
class Delta:
    path: str           # dotted section.path of the metric
    kind: str           # median | tail | thrpt
    baseline: float
    fresh: float
    ratio: float        # regression factor, >1 means worse (direction-
    #                     normalized: thrpt ratios are inverted)
    band: float

    @property
    def ok(self) -> bool:
        return self.ratio <= self.band


def classify(leaf_key: str) -> str | None:
    """Metric class for a leaf key, or None when it is not a timing."""
    for pat in SKIP_PATTERNS:
        if pat in leaf_key:
            return None
    for pat in TAIL_PATTERNS:
        if pat in leaf_key:
            return "tail"
    for pat in MEDIAN_PATTERNS:
        if pat in leaf_key:
            return "median"
    for pat in THROUGHPUT_PATTERNS:
        if pat in leaf_key:
            return "thrpt"
    return None


def _walk(doc, path=""):
    """Yield (dotted_path, leaf_key, numeric_value) over nested dicts.
    Lists, strings, bools and None are structural — not yielded."""
    if not isinstance(doc, dict):
        return
    for k, v in doc.items():
        p = f"{path}.{k}" if path else k
        if isinstance(v, dict):
            yield from _walk(v, p)
        elif isinstance(v, bool) or not isinstance(v, (int, float)):
            continue
        else:
            yield p, k, float(v)


def compare(baseline: dict, fresh: dict, *, median_band: float = 1.6,
            tail_band: float = 3.0) -> tuple[list[Delta], list[str]]:
    """Diff two BENCH_PR2.json documents.  Returns (deltas, notes) where
    deltas covers every compared metric (in-band and out) and notes
    records sections skipped and why."""
    bands = dict(median=median_band, thrpt=median_band, tail=tail_band)
    b_secs = baseline.get("sections", {})
    f_secs = fresh.get("sections", {})
    deltas: list[Delta] = []
    notes: list[str] = []
    for tag in b_secs:
        if tag not in f_secs:
            notes.append(f"skip section {tag!r}: absent from fresh run")
            continue
        bs, fs = b_secs[tag], f_secs[tag]
        bn = bs.get("n_keys", baseline.get("n_keys"))
        fn = fs.get("n_keys", fresh.get("n_keys"))
        if bn is not None and fn is not None and bn != fn:
            notes.append(f"skip section {tag!r}: scale mismatch "
                         f"(baseline n_keys={bn}, fresh n_keys={fn})")
            continue
        flat = {p: v for p, _leaf, v in _walk(fs, tag)}
        for path, leaf, bval in _walk(bs, tag):
            kind = classify(leaf)
            if kind is None or path not in flat:
                continue
            fval = flat[path]
            if bval <= EPS or fval <= EPS:
                continue    # degenerate: op never ran on one side
            ratio = (bval / fval) if kind == "thrpt" else (fval / bval)
            deltas.append(Delta(path, kind, bval, fval, ratio,
                                bands[kind]))
    return deltas, notes


def render(deltas: list[Delta], notes: list[str], *,
           show_ok: int = 10) -> str:
    """Readable delta table: every out-of-band metric, then the worst
    `show_ok` in-band movers for context."""
    bad = sorted((d for d in deltas if not d.ok), key=lambda d: -d.ratio)
    ok = sorted((d for d in deltas if d.ok), key=lambda d: -d.ratio)
    lines = []
    w = max([len(d.path) for d in deltas] or [20])
    hdr = (f"{'metric':<{w}}  {'class':<6} {'baseline':>12} "
           f"{'fresh':>12} {'ratio':>7} {'band':>5}  status")
    lines.append(hdr)
    lines.append("-" * len(hdr))

    def row(d: Delta, status: str) -> str:
        return (f"{d.path:<{w}}  {d.kind:<6} {d.baseline:>12.4g} "
                f"{d.fresh:>12.4g} {d.ratio:>6.2f}x {d.band:>4.1f}x"
                f"  {status}")

    for d in bad:
        lines.append(row(d, "REGRESSION"))
    for d in ok[:show_ok]:
        lines.append(row(d, "ok"))
    if len(ok) > show_ok:
        lines.append(f"... and {len(ok) - show_ok} more in-band metrics")
    lines.append("")
    lines.append(f"compared {len(deltas)} metrics: "
                 f"{len(bad)} out of band, {len(ok)} in band")
    for n in notes:
        lines.append(f"note: {n}")
    return "\n".join(lines)


def self_test(baseline: dict, *, median_band: float,
              tail_band: float) -> int:
    """Prove the tripwire: the artifact passes against itself, and an
    injected 2x regression on a median-class metric is caught."""
    kw = dict(median_band=median_band, tail_band=tail_band)
    deltas, _ = compare(baseline, baseline, **kw)
    if not deltas:
        print("self-test FAIL: no comparable metrics found in artifact")
        return 1
    bad = [d for d in deltas if not d.ok]
    if bad:
        print("self-test FAIL: artifact flagged against itself:")
        for d in bad:
            print(f"  {d.path}: ratio {d.ratio:.2f}x")
        return 1
    # inject: double the first median-class metric found in the fresh copy
    mutated = copy.deepcopy(baseline)
    target = next(d for d in deltas if d.kind == "median")
    parts = target.path.split(".")
    node = mutated["sections"]
    for p in parts[:-1]:
        node = node[p]
    node[parts[-1]] *= 2.0
    deltas, _ = compare(baseline, mutated, **kw)
    caught = [d for d in deltas if not d.ok and d.path == target.path]
    if not caught:
        print(f"self-test FAIL: injected 2x regression on "
              f"{target.path!r} was NOT flagged (band {median_band}x)")
        return 1
    print(f"self-test PASS: {len(deltas)} metrics compared clean "
          f"against self; injected 2x regression on {target.path!r} "
          f"caught at ratio {caught[0].ratio:.2f}x")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--baseline", required=True,
                    help="checked-in BENCH_PR2.json")
    ap.add_argument("--fresh", default="",
                    help="freshly emitted BENCH_PR2.json to judge")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the sentinel catches an injected 2x "
                         "median regression and passes the artifact "
                         "against itself")
    ap.add_argument("--median-band", type=float, default=1.6,
                    help="max regression factor for medians/means and "
                         "throughputs (default 1.6)")
    ap.add_argument("--tail-band", type=float, default=3.0,
                    help="max regression factor for p95/p99/p999/max "
                         "(default 3.0)")
    args = ap.parse_args(argv)
    try:
        with open(args.baseline) as fh:
            baseline = json.load(fh)
    except (OSError, ValueError) as e:
        print(f"sentinel: cannot read baseline: {e}", file=sys.stderr)
        return 2
    if args.self_test:
        return self_test(baseline, median_band=args.median_band,
                         tail_band=args.tail_band)
    if not args.fresh:
        print("sentinel: --fresh PATH required (or --self-test)",
              file=sys.stderr)
        return 2
    try:
        with open(args.fresh) as fh:
            fresh = json.load(fh)
    except (OSError, ValueError) as e:
        print(f"sentinel: cannot read fresh emission: {e}",
              file=sys.stderr)
        return 2
    deltas, notes = compare(baseline, fresh,
                            median_band=args.median_band,
                            tail_band=args.tail_band)
    print(render(deltas, notes))
    if not deltas:
        print("sentinel: nothing comparable — schema drift?",
              file=sys.stderr)
        return 2
    return 1 if any(not d.ok for d in deltas) else 0


if __name__ == "__main__":
    sys.exit(main())
