"""Distributed DILI through the facade: the sharded engine range-partitions
the key space over an 8-device mesh (learned router = quantile boundaries),
with per-shard overlays for online updates — all behind the same
`LearnedIndex` API as the local engine.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_ENABLE_X64=1 \\
        PYTHONPATH=src python examples/distributed_index.py
"""
import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("JAX_ENABLE_X64", "1")

import time

import jax
import numpy as np

from repro.api import IndexConfig, LearnedIndex
from repro.data.datasets import generate


def main():
    n_dev = len(jax.devices())
    print(f"devices: {n_dev}")
    keys = generate("books", 200_000, seed=2)
    rng = np.random.default_rng(1)
    qi = rng.integers(0, len(keys), 8192)
    q = keys[qi]

    for strategy in ("gather", "a2a"):
        ix = LearnedIndex.build(
            keys, config=IndexConfig(engine="sharded", sample_stride=4,
                                     lookup_strategy=strategy))
        ix.lookup(q)                                   # compile/warm
        t0 = time.time()
        v, f = ix.lookup(q)
        dt = time.time() - t0
        correct = np.array_equal(v[f], qi[f])
        print(f"{strategy:7s}: found {int(f.sum())}/{len(f)} "
              f"correct={correct}  {len(qi) / dt / 1e3:.0f}K lookups/s")
        if strategy != "gather":
            continue

        # online updates: per-shard overlays, visible before any merge
        new = np.setdiff1d(np.unique(rng.uniform(keys[0], keys[-1], 2000)),
                           keys)[:1024]
        ix.upsert(new, 5_000_000 + np.arange(len(new)))
        ix.delete(keys[qi[:256]])
        vn, fn = ix.lookup(new)
        _, fd = ix.lookup(np.unique(keys[qi[:256]]))
        print(f"         upserts visible={bool(fn.all())}, "
              f"deletes hidden={not fd.any()}  (pre-merge)")
        ix.flush()                     # per-shard fold + republish
        print(f"         after flush: epoch={ix.epoch}  "
              f"stats={ix.stats()['pending_writes']} pending")

        # indexed range queries: per-shard bisection + psum assembly
        starts = rng.integers(0, len(keys) - 101, 4096)
        ix2 = LearnedIndex.build(keys,
                                 config=IndexConfig(engine="sharded",
                                                    sample_stride=4))
        ix2.range(keys[starts], keys[starts + 100])    # warm
        t0 = time.time()
        ks, vs, counts = ix2.range(keys[starts], keys[starts + 100],
                                   max_hits=128)
        dt = time.time() - t0
        print(f"range  : {len(starts)} x 100-key windows, "
              f"avg hits {float(counts.mean()):.1f}  "
              f"{len(starts) / dt / 1e3:.0f}K ranges/s")


if __name__ == "__main__":
    main()
