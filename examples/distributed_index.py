"""Distributed DILI: range-partitioned index over an 8-device mesh with the
learned router + all_to_all/gather lookups.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 JAX_ENABLE_X64=1 \\
        PYTHONPATH=src python examples/distributed_index.py
"""
import os
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("JAX_ENABLE_X64", "1")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import build_sharded, sharded_lookup, to_mesh
from repro.data.datasets import generate


def main():
    n_dev = len(jax.devices())
    print(f"devices: {n_dev}")
    keys = generate("books", 200_000, seed=2)
    sd = build_sharded(keys, None, n_shards=n_dev, sample_stride=4)
    mesh = jax.make_mesh((n_dev,), ("data",))
    arrs = to_mesh(sd, mesh)

    rng = np.random.default_rng(1)
    qi = rng.integers(0, len(keys), 8192)
    q = jnp.asarray(keys[qi])

    for strategy in ("gather", "a2a"):
        out = sharded_lookup(mesh, arrs, q, sd.max_depth, strategy=strategy)
        v, f = out[0], out[1]
        jax.block_until_ready(v)
        t0 = time.time()
        out = sharded_lookup(mesh, arrs, q, sd.max_depth, strategy=strategy)
        jax.block_until_ready(out[0])
        dt = time.time() - t0
        ok = np.asarray(out[1])
        correct = np.array_equal(np.asarray(out[0])[ok], qi[ok])
        print(f"{strategy:7s}: found {int(ok.sum())}/{len(ok)} "
              f"correct={correct}  {len(qi) / dt / 1e3:.0f}K lookups/s")
        if strategy == "a2a":
            print(f"         overflow dropped: {int(np.asarray(out[2]).sum())}"
                  " (capacity-bounded routing; gather path is exact)")

    # indexed range queries: per-shard sorted-pair bisection + psum assembly
    from repro.core.distributed import sharded_range_query
    starts = rng.integers(0, len(keys) - 101, 4096)
    lo = jnp.asarray(keys[starts])
    hi = jnp.asarray(keys[starts + 100])
    ks, vs, counts = sharded_range_query(mesh, arrs, lo, hi, max_hits=128)
    jax.block_until_ready(ks)
    t0 = time.time()
    ks, vs, counts = sharded_range_query(mesh, arrs, lo, hi, max_hits=128)
    jax.block_until_ready(ks)
    dt = time.time() - t0
    print(f"range  : {len(starts)} x 100-key windows, "
          f"avg hits {float(np.asarray(counts).mean()):.1f}  "
          f"{len(starts) / dt / 1e3:.0f}K ranges/s")


if __name__ == "__main__":
    main()
