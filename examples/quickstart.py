"""Quickstart: build a DILI through the `repro.api.LearnedIndex` facade,
run batched device lookups and range queries, write through the overlay,
flush, and compare against baselines.  Engine choice is one flag:

    PYTHONPATH=src python examples/quickstart.py [local|pallas|sharded]
"""
import os
os.environ.setdefault("JAX_ENABLE_X64", "1")

import sys
import time

import numpy as np

from repro.api import IndexConfig, LearnedIndex
from repro.core import search as S
from repro.core.baselines import BinS, RMI
from repro.data.datasets import generate


def main():
    engine = sys.argv[1] if len(sys.argv) > 1 else "local"
    print(f"== DILI quickstart ({engine} engine) ==")
    keys = generate("logn", 200_000, seed=1)
    vals = np.arange(len(keys), dtype=np.int64)

    t0 = time.time()
    ix = LearnedIndex.build(keys, vals,
                            config=IndexConfig(engine=engine,
                                               sample_stride=4))
    st = ix.stats()
    print(f"bulk load: {len(keys):,} keys in {time.time() - t0:.1f}s; "
          f"stats: {st}")

    rng = np.random.default_rng(0)
    q = keys[rng.integers(0, len(keys), 8192)]
    v, found = ix.lookup(q)
    assert found.all()
    print(f"batched lookup: 8192/8192 found; "
          f"device bytes {st['device_bytes'] / 1e6:.1f} MB")

    # range queries: O(log n + max_hits) sorted-pair bisection
    starts = rng.integers(0, len(keys) - 101, 1024)
    ks, vs, cnt = ix.range(keys[starts], keys[starts + 100], max_hits=128)
    print(f"range: 1024 x 100-key windows, avg hits "
          f"{float(cnt.mean()):.1f}")

    # updates (Algorithms 7/8): overlay-visible immediately, folded on flush
    new = np.setdiff1d(np.unique(rng.uniform(keys[0], keys[-1], 1000)), keys)
    ix.upsert(new, 10_000_000 + np.arange(len(new)))
    ix.delete(keys[5])
    v2, f2 = ix.lookup(new)
    _, fdel = ix.lookup(keys[5])
    print(f"after {len(new)} upserts + 1 delete (pre-flush): new keys found "
          f"= {bool(f2.all())}, deleted hidden = {not fdel[0]}")
    ix.flush()
    v2, f2 = ix.lookup(new)
    print(f"after flush: new keys found = {bool(f2.all())}; "
          f"epoch = {ix.epoch}")

    # baseline comparison (probe counts: the paper's cache-miss economy)
    import jax.numpy as jnp
    qd = jnp.asarray(q)
    for B in (BinS, RMI):
        bst = B.build(keys, vals)
        _, fb, pr = B.lookup(B.device(bst), qd)
        print(f"{B.name}: found={bool(np.asarray(fb).all())}, "
              f"avg probes={float(np.asarray(pr).mean()):.1f}")
    if ix.snapshot is not None:
        _, _, nodes, probes = S.search_batch(ix.snapshot, qd,
                                             with_stats=True)
        print(f"DILI: avg nodes={float(np.asarray(nodes).mean()):.2f}, "
              f"avg probes={float(np.asarray(probes).mean()):.2f}")


if __name__ == "__main__":
    main()
