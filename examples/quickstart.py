"""Quickstart: build a DILI over 1M lognormal keys, run batched device
lookups, insert/delete, republish, and compare against baselines.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
os.environ.setdefault("JAX_ENABLE_X64", "1")

import time

import jax.numpy as jnp
import numpy as np

from repro.core import search as S
from repro.core.baselines import BinS, RMI
from repro.core.dili import bulk_load
from repro.core.flat import flatten
from repro.data.datasets import generate


def main():
    print("== DILI quickstart ==")
    keys = generate("logn", 200_000, seed=1)
    vals = np.arange(len(keys), dtype=np.int64)

    t0 = time.time()
    dili = bulk_load(keys, vals, sample_stride=4)
    print(f"bulk load: {len(keys):,} keys in {time.time() - t0:.1f}s; "
          f"stats: {dili.stats()}")

    flat = flatten(dili)
    idx = S.device_arrays(flat)
    rng = np.random.default_rng(0)
    q = jnp.asarray(keys[rng.integers(0, len(keys), 8192)])

    v, found = S.search_batch(idx, q)   # trip count from the snapshot
    assert bool(found.all())
    print(f"batched lookup: 8192/8192 found; index {flat.nbytes()/1e6:.1f} MB")

    # updates (Algorithms 7/8)
    new = np.setdiff1d(np.unique(rng.uniform(keys[0], keys[-1], 1000)), keys)
    for i, k in enumerate(new):
        dili.insert(float(k), 10_000_000 + i)
    dili.delete(float(keys[5]))
    flat2 = flatten(dili)
    idx2 = S.device_arrays(flat2)
    v2, f2 = S.search_batch(idx2, jnp.asarray(new), early_exit=True)
    print(f"after {len(new)} inserts + 1 delete: all new keys found = "
          f"{bool(f2.all())}; adjustments={dili.n_adjustments}")

    # baseline comparison
    for B in (BinS, RMI):
        st = B.build(keys, vals)
        _, fb, pr = B.lookup(B.device(st), q)
        print(f"{B.name}: found={bool(np.asarray(fb).all())}, "
              f"avg probes={float(np.asarray(pr).mean()):.1f}")
    _, _, nodes, probes = S.search_batch(idx, q, with_stats=True)
    print(f"DILI: avg nodes={float(np.asarray(nodes).mean()):.2f}, "
          f"avg probes={float(np.asarray(probes).mean()):.2f}  "
          f"(the paper's cache-miss economy)")


if __name__ == "__main__":
    main()
