"""Serving driver: batched requests against a small model with a DILI
session table on the admission/KV-slot path (Algorithms 7/8 in serving).

    PYTHONPATH=src python examples/serve_llm.py --requests 24 --tokens 16
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as MDL
from repro.serve.sessions import SessionTable
from repro.train import step as STEP


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("granite-8b"), name="granite-serve", n_layers=4,
        d_model=256, n_heads=4, n_kv_heads=2, d_ff=512, vocab=512,
        head_dim=64, dtype="float32")
    params = MDL.init_params(jax.random.PRNGKey(0), cfg)
    prefill = jax.jit(STEP.make_prefill_step(cfg))
    decode = jax.jit(STEP.make_decode_step(cfg))

    sessions = SessionTable(n_slots=args.batch + 4)
    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.tokens + 1

    t0 = time.time()
    done = 0
    req_id = 1000.0
    while done < args.requests:
        # admit a batch of sessions (DILI insert path)
        batch_ids = []
        for _ in range(args.batch):
            req_id += 1.0
            slot = sessions.admit(req_id)
            batch_ids.append(req_id)
        slots, found = sessions.lookup_batch(batch_ids)
        assert found.all()

        prompts = rng.integers(0, cfg.vocab,
                               (args.batch, args.prompt_len)).astype(np.int32)
        cache = MDL.make_cache(cfg, args.batch, max_len)
        logits, cache = prefill(params, dict(tokens=jnp.asarray(prompts)),
                                cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        outs = [np.asarray(tok)]
        for _ in range(args.tokens - 1):
            tok, logits, cache = decode(params, tok, cache)
            outs.append(np.asarray(tok))
        gen = np.concatenate(outs, axis=1)
        assert gen.shape == (args.batch, args.tokens)

        # evict (DILI delete path; slots recycled)
        for rid in batch_ids:
            sessions.evict(rid)
        done += args.batch
    dt = time.time() - t0
    total_toks = args.requests * args.tokens
    print(f"[serve] {done} requests, {total_toks} generated tokens in "
          f"{dt:.1f}s ({total_toks / dt:.0f} tok/s incl. prefill+sessions)")
    print(f"[serve] session-table stats: {sessions.dili.stats()}")


if __name__ == "__main__":
    main()
