"""End-to-end training driver: a ~100M-param granite-style model trained for
a few hundred steps on the DILI-backed record-store pipeline, with
checkpoint/auto-resume and simulated node failure.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 200 --fail-at-step 60
    # rerun the same command: it auto-resumes from the last checkpoint

Scaled by --preset: `cpu` (default, CPU-friendly dims) or `100m` (the full
~100M-param config; same code path).
"""
import argparse
import dataclasses
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import StorePipeline, SyntheticLM
from repro.data.record_store import RecordStore
from repro.ft import checkpoint as CKPT
from repro.models import model as MDL
from repro.train import step as STEP
from repro.train.optim import adamw, cosine_schedule


def build_cfg(preset: str):
    base = get_config("granite-8b")
    if preset == "100m":
        return dataclasses.replace(
            base, name="granite-100m", n_layers=12, d_model=768, n_heads=12,
            n_kv_heads=4, d_ff=2048, vocab=32768, head_dim=64,
            dtype="float32", remat="none")
    return dataclasses.replace(
        base, name="granite-tiny", n_layers=4, d_model=256, n_heads=4,
        n_kv_heads=2, d_ff=512, vocab=512, head_dim=64, dtype="float32",
        remat="none")


def build_store(cfg, n_docs=2000, doc_len=129, seed=0):
    """Corpus in a DILI record store; documents carry the synthetic
    next-token structure so the model demonstrably learns."""
    gen = SyntheticLM(cfg.vocab, doc_len - 1, 1, seed=seed)
    rng = np.random.default_rng(seed)
    keys = np.unique(rng.uniform(0, 1e9, n_docs))
    docs = []
    for i in range(len(keys)):
        b = gen.batch_at(i)
        docs.append(np.concatenate([b["tokens"][0], b["labels"][0][-1:]])
                    .astype(np.int32))
    return RecordStore(keys, docs), keys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--preset", default="cpu")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at-step", type=int, default=0,
                    help="simulate a node failure at this step")
    args = ap.parse_args()

    cfg = build_cfg(args.preset)
    opt = adamw(lr=3e-3, schedule=cosine_schedule(3e-3, 20, args.steps))
    store, keys = build_store(cfg)
    pipe = StorePipeline(store, keys, seq_len=args.seq, batch=args.batch)

    template = jax.eval_shape(
        lambda: STEP.init_state(jax.random.PRNGKey(0), cfg, opt))
    state, manifest = CKPT.restore(args.ckpt_dir, template)
    if state is None:
        state = STEP.init_state(jax.random.PRNGKey(0), cfg, opt)
        start = 0
        print("[train] cold start")
    else:
        start = manifest["step"]
        print(f"[train] resumed from step {start}")

    train_step = jax.jit(STEP.make_train_step(cfg, opt), donate_argnums=0)
    t0 = time.time()
    for step in range(start, args.steps):
        if args.fail_at_step and step == args.fail_at_step:
            print(f"[train] SIMULATED NODE FAILURE at step {step} — "
                  "rerun to auto-resume")
            sys.exit(42)
        batch = pipe.batch_at(step)      # DILI-backed lookup path
        state, metrics = train_step(state, {k: jnp.asarray(v)
                                            for k, v in batch.items()})
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.2f} "
                  f"({(time.time() - t0):.0f}s)")
        if (step + 1) % args.ckpt_every == 0:
            CKPT.save(args.ckpt_dir, step + 1, state,
                      extra={"data_step": step + 1})
    print("[train] done; final loss should be well below the ~ "
          f"{np.log(cfg.vocab):.2f} random-guess floor")


if __name__ == "__main__":
    main()
