"""`repro.api` — the typed, engine-agnostic public API (DESIGN.md §10).

One index, many engines: `LearnedIndex` is the single entry point for
building, querying, and mutating a DILI; `IndexConfig` selects and tunes
the execution engine (`local` XLA, `pallas` kernel, `sharded` mesh); and
`DeviceSnapshot` is the typed pytree that replaced the raw snapshot dict.
`repro.core` remains importable as the low-level layer underneath.
"""

from .snapshot import DeviceSnapshot
from .config import ENGINES, IndexConfig, manual_merge_policy
from .engines import (ENGINE_CLASSES, Engine, LocalEngine, PallasEngine,
                      ShardedEngine)
from .index import LearnedIndex
from ..durability.config import DurabilityConfig
from ..maintain import MaintenanceConfig
from ..online.merge import MergePolicy

__all__ = [
    "DeviceSnapshot",
    "DurabilityConfig",
    "ENGINES",
    "ENGINE_CLASSES",
    "Engine",
    "IndexConfig",
    "LearnedIndex",
    "LocalEngine",
    "MaintenanceConfig",
    "MergePolicy",
    "PallasEngine",
    "ShardedEngine",
    "manual_merge_policy",
]
