"""`IndexConfig`: one declarative knob set for every engine.

Engine choice (`local` / `pallas` / `sharded`), key dtype, snapshot
padding, merge policy, overlay sizing, shard layout, and the Pallas kernel
budget all live here, so swapping engines is a config edit — not a code
path — and every facade method reads the same object instead of threading
six keyword arguments.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any

import jax.numpy as jnp
import numpy as np

from ..durability.config import DurabilityConfig
from ..maintain import MaintenanceConfig
from ..online.merge import MergePolicy

ENGINES = ("local", "pallas", "sharded")


def manual_merge_policy() -> MergePolicy:
    """A policy that never auto-merges: writes stay in the overlay until an
    explicit `flush()` (the overlay still doubles, so `full_fraction` can
    never reach the disabled triggers)."""
    return MergePolicy(max_fill=1.1, max_writes=1 << 62,
                       pressure_check_every=1 << 62)


@dataclass(frozen=True)
class IndexConfig:
    """Configuration for `repro.api.LearnedIndex`.

    engine            : "local" (XLA fused snapshot+overlay), "pallas"
                        (VMEM kernel dispatch with XLA fallback, f32 keys),
                        or "sharded" (mesh + per-shard overlays).
    dtype             : key/model dtype; None picks the engine default
                        (f64 for local/sharded, f32 for pallas).
    pad               : pow2-pad device tables so republishes reuse the
                        compiled search executable.
    merge             : `repro.online.MergePolicy` deciding when pending
                        writes fold through the host tree (Alg. 7/8).
    maintenance       : `repro.maintain.MaintenanceConfig` switching the
                        merge to the adaptive pipeline — incremental
                        splice-flatten, drift-triggered subtree retrains,
                        and (local engine only) background merges.  None =
                        legacy monolithic full-flatten merges.
    overlay_cap       : initial tombstone-overlay capacity (doubles).
    sample_stride     : bulk-load sampling stride (Alg. 4, Table 13).
    bulk_kw           : extra `core.dili.bulk_load` kwargs (cost model,
                        lambda, local_optimized, ...).
    n_shards          : sharded engine only; None = all visible devices.
    mesh_axis         : mesh axis name for the sharded engine.
    lookup_strategy   : sharded lookup collective: "gather" (exact) or
                        "a2a" (capacity-bounded buckets).
    interpret         : Pallas interpret mode; None = interpret off-TPU.
    vmem_budget_bytes : table-size ceiling for the kernel path; bigger
                        snapshots dispatch to the XLA fallback.
    early_exit        : batch-convergence early exit (local engine; the
                        sharded engine always runs the fixed-trip scan —
                        jax 0.4.x shard_map has no while_loop replication
                        rule — and the kernel path is fixed-trip by design).
    max_hits          : default per-query range-window bound.
    telemetry         : enable per-op latency histograms + merge-pipeline
                        trace spans (`repro.obs`, DESIGN.md section 13).
                        Off by default: the hot path then pays one flag
                        check per facade call; retrace accounting stays
                        live either way (it rides jax's compile hooks).
    durability        : `repro.durability.DurabilityConfig` arming the
                        write-ahead log + checkpoint subsystem (DESIGN.md
                        section 14): upserts/deletes append to a per-shard
                        WAL before being acknowledged, merge publishes
                        checkpoint + truncate it, `LearnedIndex.recover`
                        replays the tail after a crash.  None (default) =
                        in-memory only, no durability I/O.

    `pad` applies to the local/pallas snapshots; the sharded engine's
    stacked per-shard tables are always pow2-padded (republish without
    re-trace is structural there).
    """

    engine: str = "local"
    dtype: Any = None
    pad: bool = True
    merge: MergePolicy = field(default_factory=MergePolicy)
    maintenance: MaintenanceConfig | None = None
    overlay_cap: int = 4096
    sample_stride: int = 1
    bulk_kw: tuple = ()                      # (("lam", 4.0), ...) — hashable
    n_shards: int | None = None
    mesh_axis: str = "data"
    lookup_strategy: str = "gather"
    interpret: bool | None = None
    vmem_budget_bytes: int = 12 * 1024 * 1024
    early_exit: bool = True
    max_hits: int = 128
    telemetry: bool = False
    durability: DurabilityConfig | None = None

    def __post_init__(self):
        if self.engine not in ENGINES:
            raise ValueError(f"unknown engine {self.engine!r}; "
                             f"expected one of {ENGINES}")
        if self.lookup_strategy not in ("gather", "a2a"):
            raise ValueError(f"unknown lookup_strategy "
                             f"{self.lookup_strategy!r}")

    @property
    def resolved_dtype(self):
        if self.dtype is not None:
            return self.dtype
        return jnp.float32 if self.engine == "pallas" else jnp.float64

    def bulk_load_kw(self) -> dict:
        return dict(self.bulk_kw, sample_stride=self.sample_stride)

    def with_engine(self, engine: str) -> "IndexConfig":
        return replace(self, engine=engine)

    # -- (de)serialization for LearnedIndex.save/load ------------------------

    def to_json_dict(self) -> dict:
        return dict(
            engine=self.engine,
            dtype=(None if self.dtype is None
                   else np.dtype(self.dtype).name),
            pad=self.pad,
            merge=dict(max_fill=self.merge.max_fill,
                       max_writes=self.merge.max_writes,
                       pressure_lambda=self.merge.pressure_lambda,
                       pressure_check_every=self.merge.pressure_check_every,
                       pressure_min_pending=self.merge.pressure_min_pending),
            maintenance=(None if self.maintenance is None
                         else self.maintenance.to_json_dict()),
            overlay_cap=self.overlay_cap,
            sample_stride=self.sample_stride,
            bulk_kw=list(list(kv) for kv in self.bulk_kw),
            n_shards=self.n_shards,
            mesh_axis=self.mesh_axis,
            lookup_strategy=self.lookup_strategy,
            interpret=self.interpret,
            vmem_budget_bytes=self.vmem_budget_bytes,
            early_exit=self.early_exit,
            max_hits=self.max_hits,
            telemetry=self.telemetry,
            durability=(None if self.durability is None
                        else self.durability.to_json_dict()),
        )

    @classmethod
    def from_json_dict(cls, d: dict) -> "IndexConfig":
        d = dict(d)
        merge = MergePolicy(**d.pop("merge"))
        maint = d.pop("maintenance", None)
        if maint is not None:
            maint = MaintenanceConfig.from_json_dict(maint)
        dur = d.pop("durability", None)
        if dur is not None:
            dur = DurabilityConfig.from_json_dict(dur)
        dtype = d.pop("dtype")
        bulk_kw = tuple(tuple(kv) for kv in d.pop("bulk_kw", []))
        return cls(merge=merge, maintenance=maint, durability=dur,
                   bulk_kw=bulk_kw,
                   dtype=None if dtype is None else np.dtype(dtype), **d)
