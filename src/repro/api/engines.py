"""The three pluggable engines behind `repro.api.LearnedIndex`.

Every engine speaks the same `Engine` protocol — lookup / range / upsert /
delete / flush / items / stats — over the same logical contract (exact
results at every point in time, deletes visible before any merge), but maps
it to a different execution substrate:

  * `LocalEngine`   — single-process XLA: the fused snapshot+overlay search
    (`core.search.search_with_overlay`) over an epoch-published
    `DeviceSnapshot`, writes through `repro.online.OnlineIndex`'s
    overlay/merge lifecycle.
  * `PallasEngine`  — f32 keys, VMEM-tiled Pallas kernel dispatch with the
    XLA fallback (`kernels.ops.dili_search`); the snapshot is built under
    `placement_dtype(np.float32)` so construction and kernel arithmetic
    agree (DESIGN.md section 7).
  * `ShardedEngine` — range-partitioned mesh index (`core.distributed`):
    per-shard overlays, single-shard merges, fused in-shard overlay
    resolution, collective lookups/ranges under `shard_map`.

Range queries are overlay-exact on every engine: the device bisects the
key-sorted pair table with enough headroom to cover pending tombstones,
then the (small, sorted) overlay window is merged host-side per query.
"""

from __future__ import annotations

import time
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from ..core import search as S
from ..core.dili import bulk_load, placement_dtype
from ..core.distributed import (build_sharded, combined_overlay_arrays,
                                sharded_delete, sharded_lookup,
                                sharded_merge, sharded_range_query,
                                sharded_upsert, shard_of, to_mesh)
from ..core.flat import flatten, merge_sorted_runs
from ..maintain import (IncrementalFlattener, LeafAccounting,
                        fold_with_accounting, run_reclusters, run_retrains)
from ..obs import Telemetry, watchdog
from ..online.merge import OnlineIndex, adjust_pressure
from ..online.overlay import (TombstoneOverlay, fold_overlay,
                              overlay_device_arrays)
from .config import IndexConfig
from .snapshot import DeviceSnapshot


@runtime_checkable
class Engine(Protocol):
    """What a `LearnedIndex` backend must provide.  All key/value inputs and
    outputs are host numpy; engines own their device placement."""

    name: str

    def lookup(self, queries: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(vals, found) for a batch of point queries."""
        ...

    def range(self, lo: np.ndarray, hi: np.ndarray,
              max_hits: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """First `max_hits` live pairs in each [lo, hi), ascending:
        (keys [Q,H] +inf-padded, vals [Q,H] -1-padded, counts [Q])."""
        ...

    def upsert(self, keys: np.ndarray, vals: np.ndarray) -> None: ...

    def delete(self, keys: np.ndarray) -> None: ...

    def flush(self) -> None:
        """Fold every pending write through the host tree and republish."""
        ...

    def get(self, key: float) -> int | None: ...

    def items(self) -> tuple[np.ndarray, np.ndarray]:
        """The full live (keys, vals) set, key-sorted, overlay applied."""
        ...

    def stats(self) -> dict: ...

    def close(self) -> None:
        """Release engine resources (e.g. the background maintenance
        worker); pending writes stay readable.  Idempotent."""
        ...

    def maint_timings(self) -> list[dict]:
        """Per-merge wall times: merge_s (fold+retrain+flatten),
        publish_s (upload+flip), incremental, dirty_frac."""
        ...


# ---------------------------------------------------------------------------
# shared overlay-exact helpers
# ---------------------------------------------------------------------------


def _merged_items(snap_k: np.ndarray, snap_v: np.ndarray, ov_k: np.ndarray,
                  ov_v: np.ndarray, ov_t: np.ndarray):
    """Apply overlay entries over the key-sorted snapshot pair run and drop
    tombstones — the logical content of the index, independent of engine."""
    mk, (mv, mt) = merge_sorted_runs(
        np.asarray(snap_k, np.float64),
        (np.asarray(snap_v, np.int64), np.zeros(len(snap_k), np.int8)),
        np.asarray(ov_k, np.float64),
        (np.asarray(ov_v, np.int64), np.asarray(ov_t, np.int8)))
    live = mt == 0
    return mk[live], mv[live]


def _overlay_summary(overlays) -> dict:
    """The engine-independent overlay slice of `stats()`: every engine
    reports the same keys with the same meanings (equivalence is pinned by
    tests/test_api_engines.py).  `pending_writes` counts distinct pending
    keys (live + tombstones) across all overlays; `overlay_fill` is the
    worst single overlay's fill fraction — the number the merge policy's
    max_fill trigger actually compares against."""
    ovs = list(overlays)
    count = sum(ov.count for ov in ovs)
    tombs = sum(ov.n_tombstones for ov in ovs)
    return dict(pending_writes=count,
                overlay_live=count - tombs,
                overlay_tombstones=tombs,
                overlay_cap=sum(ov.cap for ov in ovs),
                overlay_fill=max((ov.full_fraction for ov in ovs),
                                 default=0.0))


def _maint_summary(*, n_full: int, n_incremental: int, n_retrains: int,
                   dirty_row_fraction: float, queue_depth: int = 0,
                   errors: int = 0, n_reclusters: int = 0,
                   n_forced_full: int = 0) -> dict:
    """The engine-independent maintenance slice of `stats()` (pinned by
    tests/test_api_engines.py): flatten kind counts, subtree retrains and
    locality re-clusters, the last merge's dirty-row fraction, the
    background queue depth (0 on engines without a scheduler), and the
    forced-full-flatten count (`n_forced_full_flattens`: full re-flattens
    the incremental flattener was FORCED into by an unmappable dirty id —
    distinct from intentional full flattens, nonzero means the O(dirty)
    guarantee silently degraded)."""
    return dict(n_full_flattens=n_full, n_incremental_flattens=n_incremental,
                n_retrains=n_retrains, n_reclusters=n_reclusters,
                n_forced_full_flattens=n_forced_full,
                dirty_row_fraction=dirty_row_fraction,
                maint_queue_depth=queue_depth, maint_errors=errors)


class EngineTelemetryBase:
    """Shared `stats()` / `maint_timings()` / `metrics()` for every engine.

    The three engines used to carry near-identical copies of the stats
    dict assembly; this base composes the engine-independent pieces —
    `_overlay_summary`, the `_maint_summary` maintenance counters, and
    the telemetry accounting — from five small per-engine hooks:

      _stats_extra()      engine-specific keys (snapshot sizing, shard
                          breakdowns, kernel eligibility, ...)
      _stats_overlays()   the overlay objects summarized for pending-write
                          accounting (deduped during background merges)
      _timing_rows()      per-merge wall-time rows (build publish excluded)
      _queue_depth()      background scheduler depth (0 without one)
      _maint_error_list() background task failures (empty without one)

    Engines must also expose: name, epoch, telemetry, n_flattens,
    n_merges, n_full_flattens, n_incremental_flattens, n_retrains,
    last_dirty_frac.
    """

    telemetry: Telemetry

    #: locality re-cluster count; engines with the maintenance subsystem
    #: override (property or instance counter)
    n_reclusters: int = 0

    def _n_forced_full_flattens(self) -> int:
        """Unmappable-dirty-id fallbacks across the engine's flatteners."""
        return 0

    def _stats_extra(self) -> dict:
        return {}

    def _queue_depth(self) -> int:
        return 0

    def _maint_error_list(self) -> list:
        return []

    def _maint_degraded(self) -> bool:
        """Background retries exhausted -> merges run synchronously now
        (only the local engine's scheduler path can degrade)."""
        return False

    def close(self) -> None:
        pass

    # -- durability hooks (DESIGN.md section 14) ------------------------------

    #: shards the WAL fans out over (1 everywhere but the sharded engine)
    n_wal_shards: int = 1

    def shard_ids(self, keys: np.ndarray) -> np.ndarray:
        """WAL shard routing for a write batch (all shard 0 on
        single-shard engines)."""
        return np.zeros(len(np.atleast_1d(keys)), np.int64)

    _on_publish = None

    def set_on_publish(self, cb) -> None:
        """Register a post-merge-publish callback (the durability manager
        checkpoints through it).  Runs on whichever thread published."""
        self._on_publish = cb

    def _notify_publish(self) -> None:
        if self._on_publish is not None:
            self._on_publish()

    def stats(self) -> dict:
        errors = self._maint_error_list()
        return dict(engine=self.name, epoch=self.epoch,
                    **self._stats_extra(),
                    **_overlay_summary(self._stats_overlays()),
                    n_flattens=self.n_flattens, n_merges=self.n_merges,
                    **_maint_summary(
                        n_full=self.n_full_flattens,
                        n_incremental=self.n_incremental_flattens,
                        n_retrains=self.n_retrains,
                        dirty_row_fraction=self.last_dirty_frac,
                        queue_depth=self._queue_depth(),
                        errors=len(errors),
                        n_reclusters=self.n_reclusters,
                        n_forced_full=self._n_forced_full_flattens()),
                    maint_degraded=self._maint_degraded(),
                    maint_error_logs=list(errors),
                    telemetry_enabled=self.telemetry.enabled,
                    ops_total=self.telemetry.ops_total)

    def maint_timings(self) -> list[dict]:
        """Per-merge wall times: merge_s (fold+retrain+flatten),
        publish_s (upload+flip), incremental, dirty_frac."""
        return self._timing_rows()

    def metrics(self) -> dict:
        """The stable JSON-able telemetry snapshot (same schema on every
        engine; DESIGN.md section 13)."""
        return dict(engine=self.name, **self.telemetry.snapshot())

    # -- index-health introspection (obs.inspect) -----------------------------

    def _inspect_flats(self) -> list:
        """Published FlatDILI snapshot(s), one per shard."""
        raise NotImplementedError

    def _inspect_flatteners(self) -> list:
        """Live IncrementalFlattener instances ([] = maintenance off)."""
        return []

    def _inspect_accounts(self) -> list:
        """Live LeafAccounting instances ([] = accounting off)."""
        return []

    def inspect(self) -> dict:
        """The engine-independent `dili.inspect/1` health document; the
        facade layers the WAL footprint on top."""
        from ..obs.inspect import build_inspect
        accounts = []
        for acct in self._inspect_accounts():
            accounts.extend(acct.accounts())
        ov = _overlay_summary(self._stats_overlays())
        return build_inspect(
            engine=self.name, epoch=self.epoch,
            flats=self._inspect_flats(),
            flatteners=self._inspect_flatteners(),
            accounts=accounts,
            overlay=dict(pending=ov["pending_writes"],
                         live=ov["overlay_live"],
                         tombstones=ov["overlay_tombstones"],
                         cap=ov["overlay_cap"],
                         fill=ov["overlay_fill"]))


def _reject_background(cfg: IndexConfig, engine: str) -> None:
    if cfg.maintenance is not None and cfg.maintenance.background:
        raise ValueError(
            f"background maintenance requires the local engine (its "
            f"double-buffered SnapshotStore); the {engine} engine "
            f"supports maintenance=MaintenanceConfig(background=False)")


def _merge_range_windows(ks, vs, cnt, lo, hi, ov_k, ov_v, ov_t,
                         max_hits: int):
    """Resolve overlay state over per-query snapshot range windows.

    `ks/vs/cnt` are the device results (ascending prefix per query, counts
    saturating at the fetched window size, which includes tombstone
    headroom).  Each query merges its overlay slice [lo, hi) last-write-wins
    and truncates back to `max_hits`.  O(Q * (window + overlay-slice)) on
    the host — the overlay is small by construction (it merges away)."""
    q_n = len(cnt)
    out_k = np.full((q_n, max_hits), np.inf)
    out_v = np.full((q_n, max_hits), -1, np.int64)
    out_c = np.zeros(q_n, np.int32)
    ks = np.asarray(ks, np.float64)
    vs = np.asarray(vs, np.int64)
    starts = np.searchsorted(ov_k, lo, side="left")
    ends = np.searchsorted(ov_k, hi, side="left")
    for i in range(q_n):
        mk, mv = _merged_items(ks[i][: cnt[i]], vs[i][: cnt[i]],
                               ov_k[starts[i]: ends[i]],
                               ov_v[starts[i]: ends[i]],
                               ov_t[starts[i]: ends[i]])
        c = min(len(mk), max_hits)
        out_k[i, :c] = mk[:c]
        out_v[i, :c] = mv[:c]
        out_c[i] = c
    return out_k, out_v, out_c


@jax.jit
def _pair_table_recheck(pk, pv, q, v, f):
    """Comparison-exact patch for point-lookup miss lanes.

    Compiled XLA may evaluate `a + b*q` with a SINGLE rounding (FMA-style
    contraction survives the optimization_barrier on the f32 path), while
    construction placed keys with numpy's two roundings; at key magnitudes
    where f32 ULP-safety is unattainable (DESIGN.md section 7) a boundary
    query can then mis-route by one child and miss.  Found lanes are always
    true hits (tag + key equality), so only misses need the O(log n)
    bisection of the key-sorted pair table."""
    i = jnp.clip(jnp.searchsorted(pk, q), 0, pk.shape[0] - 1)
    hit = pk[i] == q
    return jnp.where(f, v, jnp.where(hit, pv[i], v)), f | hit


watchdog.register_jit("api.pair_table_recheck", _pair_table_recheck)


def _tombstone_headroom(ov_k, ov_t, lo, hi) -> int:
    """Extra snapshot rows the device window must fetch so that dropping
    tombstoned keys still leaves `max_hits` live candidates: the maximum
    number of pending tombstones falling inside any queried window."""
    tk = ov_k[np.asarray(ov_t) > 0]
    if len(tk) == 0:
        return 0
    return int(np.max(np.searchsorted(tk, hi, side="left")
                      - np.searchsorted(tk, lo, side="left")))


def _truncate_windows(ks, vs, cnt, max_hits: int):
    """No-overlay fast path: clip device windows fetched with headroom back
    to `max_hits` without a host merge."""
    ks = np.asarray(ks, np.float64)[:, :max_hits]
    vs = np.asarray(vs, np.int64)[:, :max_hits]
    cnt = np.minimum(np.asarray(cnt, np.int32), max_hits)
    pos = np.arange(max_hits)[None, :]
    ks = np.where(pos < cnt[:, None], ks, np.inf)
    vs = np.where(pos < cnt[:, None], vs, -1)
    return ks, vs, cnt


def _overlay_exact_range(entries, lo, hi, max_hits: int, device_range):
    """The one overlay-exact range recipe every engine shares: size the
    device fetch with tombstone headroom, bisect on the device via
    `device_range(lo, hi, fetch)`, then either truncate (no pending writes)
    or merge each query's overlay slice host-side."""
    ov_k, ov_v, ov_t = entries
    fetch = max_hits + _tombstone_headroom(ov_k, ov_t, lo, hi)
    if fetch > max_hits:
        # pow2-quantize the over-fetch: headroom varies batch to batch under
        # write-heavy mixes and every distinct fetch is a fresh executable;
        # extra rows are clipped by the truncate/merge step below, so the
        # result is identical
        fetch = max_hits + (1 << (fetch - max_hits - 1).bit_length())
    ks, vs, cnt = device_range(lo, hi, fetch)
    ks, vs, cnt = np.asarray(ks), np.asarray(vs), np.asarray(cnt)
    if len(ov_k) == 0:
        return _truncate_windows(ks, vs, cnt, max_hits)
    return _merge_range_windows(ks, vs, cnt, lo, hi, ov_k, ov_v, ov_t,
                                max_hits)


# ---------------------------------------------------------------------------
# LocalEngine
# ---------------------------------------------------------------------------


class LocalEngine(EngineTelemetryBase):
    """Single-process engine over the online-update lifecycle: writes land
    in the tombstone overlay, reads are ONE fused device dispatch, merges
    follow the configured `MergePolicy` (DESIGN.md section 8-9)."""

    name = "local"

    def __init__(self, keys: np.ndarray, vals: np.ndarray, cfg: IndexConfig):
        self.cfg = cfg
        self.telemetry = Telemetry(enabled=cfg.telemetry)
        self.oi = OnlineIndex(keys, vals, policy=cfg.merge,
                              overlay_cap=cfg.overlay_cap,
                              dtype=cfg.resolved_dtype, pad=cfg.pad,
                              early_exit=cfg.early_exit,
                              maintenance=cfg.maintenance,
                              telemetry=self.telemetry,
                              **cfg.bulk_load_kw())

    # -- reads --------------------------------------------------------------

    def lookup(self, queries):
        return self.oi.lookup(queries)

    def range(self, lo, hi, max_hits):
        dt = self.oi.store.dtype
        # pending entries captured BEFORE the snapshot is read inside the
        # lambda: exact across a concurrent background publish
        return _overlay_exact_range(
            self.oi.pending_entries(), lo, hi, max_hits,
            lambda lo_, hi_, fetch: S.range_query_batch(
                self.oi.store.idx, jnp.asarray(lo_, dt),
                jnp.asarray(hi_, dt), max_hits=fetch))

    def get(self, key: float):
        return self.oi.get(key)

    @property
    def snapshot(self):
        """The current epoch's `DeviceSnapshot` (read-only composition with
        `core.search`; pending overlay writes are NOT in it)."""
        return self.oi.store.idx

    # -- writes -------------------------------------------------------------

    def upsert(self, keys, vals):
        self.oi.upsert_batch(keys, vals)

    def delete(self, keys):
        self.oi.delete_batch(keys)

    def flush(self):
        self.oi.flush()

    def close(self):
        self.oi.close()

    def set_on_publish(self, cb) -> None:
        # the OnlineIndex fires it itself at the end of every merge
        # pipeline run (writer thread or maintenance worker)
        self.oi.on_publish = cb

    def _maint_degraded(self) -> bool:
        return self.oi.maint_degraded

    def _inspect_flats(self) -> list:
        return [self.oi.store.flat]

    def _inspect_flatteners(self) -> list:
        fl = self.oi.flattener
        return [] if fl is None else [fl]

    def _inspect_accounts(self) -> list:
        acct = self.oi.accounting
        return [] if acct is None else [acct]

    # -- introspection ------------------------------------------------------

    def items(self):
        # pending entries BEFORE the flat (exact across a background flip)
        ok, ovv, ott = self.oi.pending_entries()
        f = self.oi.store.flat
        return _merged_items(f.pair_key, f.pair_val, ok, ovv, ott)

    @property
    def host(self):
        return self.oi.dili

    @property
    def epoch(self) -> int:
        return self.oi.epoch

    @property
    def n_flattens(self) -> int:
        return self.oi.n_flattens

    @property
    def n_merges(self) -> int:
        return self.oi.n_merges

    @property
    def n_full_flattens(self) -> int:
        return self.oi.n_full_flattens

    @property
    def n_incremental_flattens(self) -> int:
        return self.oi.n_incremental_flattens

    @property
    def n_retrains(self) -> int:
        return self.oi.n_retrains

    @property
    def n_reclusters(self) -> int:
        return self.oi.n_reclusters

    def _n_forced_full_flattens(self) -> int:
        fl = self.oi.flattener
        return 0 if fl is None else fl.n_fallback_full

    @property
    def last_dirty_frac(self) -> float:
        return self.oi.last_dirty_frac

    def _timing_rows(self) -> list[dict]:
        return [dict(merge_s=st.merge_s, publish_s=st.publish_s,
                     incremental=st.incremental, dirty_frac=st.dirty_frac)
                for st in self.oi.store.history[1:]]

    def _stats_overlays(self):
        # during an in-flight background merge, summarize the DEDUPED view
        # (a key rewritten after the freeze lives in both overlays but is
        # one distinct pending key — _overlay_summary's contract)
        oi = self.oi
        pend = oi._merging
        return [oi.overlay] if pend is None else [pend.merged_with(oi.overlay)]

    def _queue_depth(self) -> int:
        sched = self.oi.scheduler
        return 0 if sched is None else sched.depth

    def _maint_error_list(self) -> list:
        sched = self.oi.scheduler
        return [] if sched is None else list(sched.errors)

    def _stats_extra(self) -> dict:
        snap = self.oi.store.idx
        return dict(max_depth=snap.max_depth,
                    snapshot_keys=int(self.oi.store.flat.n_pairs),
                    merge_reasons=dict(self.oi.merge_reasons),
                    device_bytes=snap.nbytes)


# ---------------------------------------------------------------------------
# PallasEngine
# ---------------------------------------------------------------------------


class PallasEngine(EngineTelemetryBase):
    """f32 kernel engine: lookups dispatch to the Pallas kernel when the
    tables fit the configured VMEM budget (XLA fallback otherwise / for
    flagged lanes), ranges bisect an f32 `DeviceSnapshot`.  Keys are
    quantized to f32 at the boundary — duplicates after the cast collapse
    last-write-wins, the documented f32 tolerance rule."""

    name = "pallas"

    def __init__(self, keys: np.ndarray, vals: np.ndarray, cfg: IndexConfig):
        from ..kernels import ops as K
        self._K = K
        self.cfg = cfg
        self.telemetry = Telemetry(enabled=cfg.telemetry)
        _reject_background(cfg, self.name)
        m = cfg.maintenance
        self.flattener = (IncrementalFlattener()
                          if m is not None and m.incremental else None)
        self.accounting = (LeafAccounting(m)
                           if m is not None and (m.retrain or m.recluster)
                           else None)
        k32, v64 = self._quantize(keys, vals)
        with placement_dtype(np.float32):
            self.dili = bulk_load(k32, v64, **cfg.bulk_load_kw())
        self.overlay = TombstoneOverlay.empty(cfg.overlay_cap)
        self._ov_mirror = None          # device overlay, rebuilt on write
        self.epoch = 0
        self.n_flattens = 0
        self.n_full_flattens = 0
        self.n_incremental_flattens = 0
        self.n_merges = 0
        self.n_retrains = 0
        self.n_reclusters = 0
        self.last_dirty_frac = 1.0
        self._timings: list[dict] = []
        self._writes_since_publish = 0
        self._writes_since_pressure = 0
        self._publish()

    def _n_forced_full_flattens(self) -> int:
        return 0 if self.flattener is None else self.flattener.n_fallback_full

    @staticmethod
    def _check_vals_i32(vals: np.ndarray) -> np.ndarray:
        """The kernel path stores payloads as int32 (deliberately — DESIGN.md
        section 2); reject out-of-range vals instead of silently wrapping."""
        vals = np.asarray(vals, np.int64)
        if len(vals) and (vals.max() >= 2**31 or vals.min() < -(2**31)):
            raise ValueError(
                "pallas engine payloads must fit int32 (the kernel's "
                "payload width); use the local or sharded engine for "
                ">=2^31 vals")
        return vals

    def _quantize(self, keys, vals) -> tuple[np.ndarray, np.ndarray]:
        """Cast keys to f32; collapse post-cast duplicates last-write-wins.

        Build-time collisions are tolerated but no longer silent: in
        magnitude-dense regions (integer keys with |key| >= 2**24, where
        f32 spacing exceeds 1) distinct input keys alias to one f32 value
        and their payloads collapse — a lossy build the caller must be
        able to see coming before queries return "wrong" neighbors.
        Routed through the registry's rate-limited structured warning:
        the `warn.pallas_f32_collision` counter accumulates the collapsed
        count across builds while the Python warning fires once, so a
        flood of lossy rebuilds stays visible but bounded."""
        k32 = np.asarray(keys, np.float64).astype(np.float32)
        order = np.argsort(k32, kind="stable")
        k32, vals = k32[order], self._check_vals_i32(vals)[order]
        keep = np.ones(len(k32), bool)
        keep[:-1] = k32[:-1] != k32[1:]          # keep the LAST duplicate
        n_collapsed = int((~keep).sum())
        if n_collapsed:
            self.telemetry.metrics.warn(
                "pallas_f32_collision",
                f"pallas engine: {n_collapsed} of {len(k32)} build keys "
                f"collide after f32 quantization and were collapsed "
                f"last-write-wins. The kernel's f32 key domain represents "
                f"integers exactly only for |key| < 2**24 (16777216); "
                f"beyond that, adjacent keys closer than one f32 ulp alias "
                f"to the same value. Use the local or sharded engine for "
                f"full f64 key precision.", count=n_collapsed)
        return k32[keep].astype(np.float64), vals[keep]

    @property
    def _interpret(self) -> bool:
        if self.cfg.interpret is not None:
            return self.cfg.interpret
        return jax.default_backend() != "tpu"

    def _publish(self, merge_s: float = 0.0):
        t0 = time.perf_counter()
        with self.telemetry.span("merge.flatten"):
            if self.flattener is not None:
                self.flat = self.flattener.flatten(self.dili,
                                                   self.dili.take_dirty())
                incremental = self.flattener.last_incremental
                self.last_dirty_frac = (
                    self.flattener.last_dirty_rows
                    / max(self.flattener.last_total_rows, 1))
            else:
                self.flat = flatten(self.dili)
                self.dili.take_dirty()  # drain (unbounded growth otherwise)
                incremental = False
                self.last_dirty_frac = 1.0
        fl = self.flattener
        self.telemetry.sample_publish(
            n_segments=self.flat.n_segments,
            dirty_rows=(fl.last_dirty_rows if fl is not None
                        else self.flat.n_slots),
            total_rows=(fl.last_total_rows if fl is not None
                        else self.flat.n_slots))
        merge_s += time.perf_counter() - t0
        t0 = time.perf_counter()
        with self.telemetry.span("merge.publish"):
            self.arrs = self._K.kernel_arrays(self.flat)
            self.snap = DeviceSnapshot.from_flat(self.flat, dtype=jnp.float32,
                                                 pad=self.cfg.pad)
            jax.block_until_ready(self.snap.arrays)
        self.n_flattens += 1
        if incremental:
            self.n_incremental_flattens += 1
        else:
            self.n_full_flattens += 1
        if self.epoch > 0:          # the build publish is not a merge row
            self._timings.append(dict(merge_s=merge_s,
                                      publish_s=time.perf_counter() - t0,
                                      incremental=incremental,
                                      dirty_frac=self.last_dirty_frac))
        self.epoch += 1

    # -- reads --------------------------------------------------------------

    def lookup(self, queries):
        q32 = jnp.asarray(np.asarray(queries, np.float64), jnp.float32)
        v, f = self._K.dili_search(self.arrs, q32, interpret=self._interpret,
                                   vmem_budget=self.cfg.vmem_budget_bytes)
        v, f = _pair_table_recheck(self.snap.arrays["pair_key"],
                                   self.snap.arrays["pair_val"], q32, v, f)
        if self.overlay.count:
            if self._ov_mirror is None:
                self._ov_mirror = overlay_device_arrays(self.overlay,
                                                        jnp.float32)
            v, f = S.resolve_overlay(self._ov_mirror, q32, v, f)
        return np.asarray(v, np.int64), np.asarray(f, bool)

    def range(self, lo, hi, max_hits):
        lo32 = np.asarray(lo, np.float64).astype(np.float32)
        hi32 = np.asarray(hi, np.float64).astype(np.float32)
        return _overlay_exact_range(
            self.overlay.entries(), lo32, hi32, max_hits,
            lambda lo_, hi_, fetch: S.range_query_batch(
                self.snap, jnp.asarray(lo_, jnp.float32),
                jnp.asarray(hi_, jnp.float32), max_hits=fetch))

    def get(self, key: float):
        k = float(np.float32(key))
        state, v = self.overlay.get(k)
        if state == 0:                      # LIVE
            return v
        if state == 1:                      # TOMBSTONE
            return None
        # the host walk must predict in the precision the tree was placed in
        with placement_dtype(np.float32):
            return self.dili.search(k)

    # -- writes -------------------------------------------------------------

    def _quantize_keys(self, keys) -> np.ndarray:
        """f32-quantize write keys (the documented tolerance rule) — but
        REJECT integer-valued keys the cast moves.  At |key| >= 2**24 the
        f32 spacing exceeds 1, so adjacent int64 keys alias to one f32
        value and the write would silently land on a DIFFERENT logical key
        (a wrong-neighbor corruption, not a rounding tolerance).
        Fractional keys stay under the quantize-to-f32 tolerance the
        engine documents."""
        k64 = np.atleast_1d(np.asarray(keys, np.float64))
        k32 = k64.astype(np.float32).astype(np.float64)
        moved = (k32 != k64) & (np.floor(k64) == k64) & np.isfinite(k64)
        if moved.any():
            raise ValueError(
                f"pallas engine: integer key {k64[moved][0]!r} is not "
                f"exactly representable in the kernel's f32 key domain "
                f"(integers are exact only for |key| < 2**24 = 16777216; "
                f"above that f32 spacing exceeds 1 and adjacent keys "
                f"alias) — the write would land on {k32[moved][0]!r}, a "
                f"different logical key. Use the local or sharded engine "
                f"for int64 keys at this magnitude.")
        return k32

    def upsert(self, keys, vals):
        # overlay reads resolve in int64, but a merge folds these into the
        # int32 kernel tables — enforce the width before accepting the write
        vals = self._check_vals_i32(np.atleast_1d(np.asarray(vals)))
        self.overlay = self.overlay.upsert_batch(self._quantize_keys(keys),
                                                 vals)
        self._ov_mirror = None
        self._note_writes(len(np.atleast_1d(keys)))

    def delete(self, keys):
        self.overlay = self.overlay.delete_batch(self._quantize_keys(keys))
        self._ov_mirror = None
        self._note_writes(len(np.atleast_1d(keys)))

    def _note_writes(self, n: int):
        self._writes_since_publish += n
        self._writes_since_pressure += n
        p = self.cfg.merge
        trigger = (self.overlay.full_fraction >= p.max_fill
                   or self._writes_since_publish >= p.max_writes)
        if not trigger and self._writes_since_pressure >= p.pressure_check_every:
            self._writes_since_pressure = 0
            with placement_dtype(np.float32):   # leaf walk predicts in f32
                trigger = (adjust_pressure(self.dili, self.overlay,
                                           p.pressure_min_pending)
                           > p.pressure_lambda)
        if trigger:
            self.flush()

    def flush(self):
        if self.overlay.count == 0:
            return
        t0 = time.perf_counter()
        tel = self.telemetry
        # the host walk (and any retrain's bulk_load) must place slots in
        # the same f32 arithmetic the kernel searches with
        with placement_dtype(np.float32):
            if self.accounting is not None:
                with tel.span("merge.fold"):
                    fold_with_accounting(self.dili, self.overlay,
                                         self.accounting)
                with tel.span("merge.retrain"):
                    self.n_retrains += run_retrains(self.dili,
                                                    self.accounting)
                # still inside placement_dtype: split_leaf's child models
                # must place slots in the kernel's f32 arithmetic
                with tel.span("merge.recluster"):
                    r = run_reclusters(self.dili, self.accounting,
                                       self.flattener)
                if r:
                    self.n_reclusters += r
                    if tel.enabled:
                        tel.metrics.count("maint.reclusters", r)
            else:
                with tel.span("merge.fold"):
                    fold_overlay(self.dili, self.overlay)
        self.overlay = TombstoneOverlay.empty(self.cfg.overlay_cap)
        self._ov_mirror = None
        self.n_merges += 1
        self._writes_since_publish = 0
        self._writes_since_pressure = 0
        self._publish(merge_s=time.perf_counter() - t0)
        self._notify_publish()

    # -- introspection ------------------------------------------------------

    def items(self):
        ok, ovv, ott = self.overlay.entries()
        return _merged_items(self.flat.pair_key, self.flat.pair_val,
                             ok, ovv, ott)

    @property
    def host(self):
        return self.dili

    @property
    def snapshot(self):
        return self.snap

    def _timing_rows(self) -> list[dict]:
        return list(self._timings)

    def _stats_overlays(self):
        return [self.overlay]

    def _inspect_flats(self) -> list:
        return [self.flat]

    def _inspect_flatteners(self) -> list:
        return [] if self.flattener is None else [self.flattener]

    def _inspect_accounts(self) -> list:
        return [] if self.accounting is None else [self.accounting]

    def _stats_extra(self) -> dict:
        return dict(max_depth=self.flat.max_depth,
                    snapshot_keys=int(self.flat.n_pairs),
                    table_bytes=self._K.table_bytes(self.arrs),
                    kernel_eligible=(self._K.table_bytes(self.arrs)
                                     <= self.cfg.vmem_budget_bytes),
                    device_bytes=self.snap.nbytes)


# ---------------------------------------------------------------------------
# ShardedEngine
# ---------------------------------------------------------------------------


class ShardedEngine(EngineTelemetryBase):
    """Mesh engine: quantile range partitioning, per-shard tombstone
    overlays, collective lookups (gather or a2a) with in-shard overlay
    resolution, and single-shard merges + republish.  Query batches are
    padded to a shard multiple with +inf (guaranteed misses) and unpadded
    on the way out, so callers never see the mesh shape."""

    name = "sharded"

    def __init__(self, keys: np.ndarray, vals: np.ndarray, cfg: IndexConfig):
        self.cfg = cfg
        self.telemetry = Telemetry(enabled=cfg.telemetry)
        _reject_background(cfg, self.name)
        n = cfg.n_shards or len(jax.devices())
        # every shard's bulk_load needs >= 2 keys, and the mesh cannot span
        # more devices than exist; a tiny index (e.g. a freshly warmed
        # session table) clamps to fewer shards rather than crashing — it
        # grows back onto more shards at the next build
        n = max(1, min(n, len(keys) // 2, len(jax.devices())))
        self.sd = build_sharded(keys, vals, n_shards=n,
                                overlay_cap=cfg.overlay_cap, keep_host=True,
                                **cfg.bulk_load_kw())
        self.mesh = jax.make_mesh((n,), (cfg.mesh_axis,))
        m = cfg.maintenance
        self._flatteners = ([IncrementalFlattener() for _ in range(n)]
                            if m is not None and m.incremental else None)
        self._accounting = ([LeafAccounting(m) for _ in range(n)]
                            if m is not None and (m.retrain or m.recluster)
                            else None)
        self.n_flattens = n                      # build flattened every shard
        self.n_full_flattens = n
        self.n_incremental_flattens = 0
        self.n_merges = 0
        self.n_retrains = 0
        self.n_reclusters = 0
        self.last_dirty_frac = 1.0
        self.n_publishes = 1
        self._timings: list[dict] = []
        self._writes_since_publish = 0
        self._writes_since_pressure = 0
        self.arrs = to_mesh(self.sd, self.mesh, axis=cfg.mesh_axis,
                            dtype=cfg.resolved_dtype)

    def _pad(self, x) -> tuple[np.ndarray, int]:
        x = np.atleast_1d(np.asarray(x, np.float64))
        pad = (-len(x)) % self.sd.n_shards
        if pad:
            x = np.concatenate([x, np.full(pad, np.inf)])
        return x, len(x) - pad

    # -- reads --------------------------------------------------------------

    def lookup(self, queries):
        q, n = self._pad(queries)
        qd = jnp.asarray(q, self.cfg.resolved_dtype)
        ova = combined_overlay_arrays(self.sd, self.cfg.resolved_dtype)
        out = sharded_lookup(self.mesh, self.arrs, qd, self.sd.max_depth,
                             axis=self.cfg.mesh_axis,
                             strategy=self.cfg.lookup_strategy, overlay=ova,
                             has_dense=self.sd.has_dense)
        if (self.cfg.lookup_strategy == "a2a"
                and int(np.asarray(out[2]).sum()) > 0):
            # a2a buckets are capacity-bounded; overflowed lanes come back
            # found=False.  The facade's contract is exact results, so a
            # skewed batch that overflows re-resolves on the (always-exact)
            # gather path instead of silently reporting misses.
            out = sharded_lookup(self.mesh, self.arrs, qd,
                                 self.sd.max_depth, axis=self.cfg.mesh_axis,
                                 strategy="gather", overlay=ova,
                                 has_dense=self.sd.has_dense)
        v, f = out[0], out[1]
        return (np.asarray(v, np.int64)[:n], np.asarray(f, bool)[:n])

    def range(self, lo, hi, max_hits):
        lo_p, n = self._pad(lo)
        hi_p, _ = self._pad(hi)
        dt = self.cfg.resolved_dtype

        def device_range(_lo, _hi, fetch):
            # the collective needs the shard-multiple padded batch; results
            # are sliced back to the caller's n queries
            ks, vs, cnt = sharded_range_query(
                self.mesh, self.arrs, jnp.asarray(lo_p, dt),
                jnp.asarray(hi_p, dt), max_hits=fetch,
                axis=self.cfg.mesh_axis)
            return (np.asarray(ks)[:n], np.asarray(vs)[:n],
                    np.asarray(cnt)[:n])

        return _overlay_exact_range(self._overlay_entries(), lo_p[:n],
                                    hi_p[:n], max_hits, device_range)

    def _overlay_entries(self):
        """Combined overlay entries, globally sorted (disjoint shard
        ranges => shard-order concatenation IS key order)."""
        parts = [ov.entries() for ov in self.sd.overlays]
        return (np.concatenate([p[0] for p in parts]),
                np.concatenate([p[1] for p in parts]),
                np.concatenate([p[2] for p in parts]))

    def get(self, key: float):
        k = float(key)
        r = int(shard_of(self.sd, np.array([k]))[0])
        state, v = self.sd.overlays[r].get(k)
        if state == 0:
            return v
        if state == 1:
            return None
        return self.sd.dilis[r].search(k)

    # -- writes -------------------------------------------------------------

    def upsert(self, keys, vals):
        sharded_upsert(self.sd, keys, vals)
        self._note_writes(len(np.atleast_1d(keys)))

    def delete(self, keys):
        sharded_delete(self.sd, keys)
        self._note_writes(len(np.atleast_1d(keys)))

    def _note_writes(self, n: int):
        p = self.cfg.merge
        self._writes_since_publish += n
        self._writes_since_pressure += n
        trigger = (self._writes_since_publish >= p.max_writes
                   or any(ov.full_fraction >= p.max_fill
                          for ov in self.sd.overlays))
        if not trigger and self._writes_since_pressure >= p.pressure_check_every:
            self._writes_since_pressure = 0
            trigger = any(
                ov.count and (adjust_pressure(d, ov, p.pressure_min_pending)
                              > p.pressure_lambda)
                for d, ov in zip(self.sd.dilis, self.sd.overlays))
        if trigger:
            self.flush()

    def _fold_shard(self, r: int, dili, ov) -> None:
        # always the sharded_merge fold hook, so the per-shard fold (and
        # any retrains) land as per-shard merge.fold/retrain spans
        if self._accounting is None:
            with self.telemetry.span("merge.fold", shard=r):
                fold_overlay(dili, ov)
            return
        acct = self._accounting[r]
        with self.telemetry.span("merge.fold", shard=r):
            fold_with_accounting(dili, ov, acct)
        with self.telemetry.span("merge.retrain", shard=r):
            self.n_retrains += run_retrains(dili, acct)
        fl = self._flatteners[r] if self._flatteners is not None else None
        with self.telemetry.span("merge.recluster", shard=r):
            n = run_reclusters(dili, acct, fl)
        if n:
            self.n_reclusters += n
            if self.telemetry.enabled:
                self.telemetry.metrics.count("maint.reclusters", n)

    def _flatten_shard(self, r: int, dili):
        with self.telemetry.span("merge.flatten", shard=r):
            if self._flatteners is None:
                flat = flatten(dili)
                dili.take_dirty()   # drain (a full flatten supersedes it)
                self.n_full_flattens += 1
                return flat
            fl = self._flatteners[r]
            flat = fl.flatten(dili, dili.take_dirty())
        if fl.last_incremental:
            self.n_incremental_flattens += 1
        else:
            self.n_full_flattens += 1
        return flat

    def flush(self):
        """Fold every shard with pending writes and republish the mesh
        copy.  (A policy trigger folds all pending shards too — the merge
        itself is still per-shard row rewrites, no global rebuild.)"""
        t0 = time.perf_counter()
        merged = sharded_merge(self.sd, max_fill=0.0,
                               fold_fn=self._fold_shard,
                               flatten_fn=self._flatten_shard)
        if merged:
            incremental = False
            if self._flatteners is not None:
                fls = [self._flatteners[r] for r in merged]
                self.last_dirty_frac = (
                    sum(f.last_dirty_rows for f in fls)
                    / max(sum(f.last_total_rows for f in fls), 1))
                # honest labeling: a flush is incremental only if every
                # merged shard actually spliced (cold caches full-flatten)
                incremental = all(f.last_incremental for f in fls)
            total_slots = sum(f.n_slots for f in self.sd.flats)
            self.telemetry.sample_publish(
                n_segments=sum(f.n_segments for f in self.sd.flats),
                dirty_rows=(sum(f.last_dirty_rows
                                for f in self._flatteners)
                            if self._flatteners is not None
                            else total_slots),
                total_rows=(sum(f.last_total_rows
                                for f in self._flatteners)
                            if self._flatteners is not None
                            else total_slots))
            merge_s = time.perf_counter() - t0
            self.n_merges += 1
            self.n_flattens += len(merged)
            self._writes_since_publish = 0
            self._writes_since_pressure = 0
            t0 = time.perf_counter()
            with self.telemetry.span("merge.publish", shards=len(merged)):
                self.arrs = to_mesh(self.sd, self.mesh,
                                    axis=self.cfg.mesh_axis,
                                    dtype=self.cfg.resolved_dtype)
                jax.block_until_ready(list(self.arrs.values()))
            self.n_publishes += 1
            self._timings.append(dict(
                merge_s=merge_s, publish_s=time.perf_counter() - t0,
                incremental=incremental,
                dirty_frac=self.last_dirty_frac))
            self._notify_publish()

    # -- introspection ------------------------------------------------------

    def _n_forced_full_flattens(self) -> int:
        if self._flatteners is None:
            return 0
        return sum(fl.n_fallback_full for fl in self._flatteners)

    @property
    def n_wal_shards(self) -> int:
        return self.sd.n_shards

    def shard_ids(self, keys: np.ndarray) -> np.ndarray:
        return np.asarray(
            shard_of(self.sd, np.atleast_1d(np.asarray(keys, np.float64))),
            np.int64)

    def items(self):
        snap_k = np.concatenate([f.pair_key for f in self.sd.flats])
        snap_v = np.concatenate([f.pair_val for f in self.sd.flats])
        ok, ovv, ott = self._overlay_entries()
        return _merged_items(snap_k, snap_v, ok, ovv, ott)

    @property
    def host(self):
        return self.sd.dilis

    @property
    def epoch(self) -> int:
        # publish-count semantics, like the other engines (the local
        # engine's SnapshotStore and the pallas engine both count device
        # republishes, so a fresh build is epoch 1 and every effective
        # flush bumps it); `sd.epoch` (merge count) stays internal
        return self.n_publishes

    def _timing_rows(self) -> list[dict]:
        return list(self._timings)

    def _stats_overlays(self):
        return self.sd.overlays

    def _inspect_flats(self) -> list:
        return list(self.sd.flats)

    def _inspect_flatteners(self) -> list:
        return list(self._flatteners or ())

    def _inspect_accounts(self) -> list:
        return list(self._accounting or ())

    def _stats_extra(self) -> dict:
        return dict(max_depth=self.sd.max_depth,
                    n_shards=self.sd.n_shards,
                    snapshot_keys=sum(int(f.n_pairs) for f in self.sd.flats),
                    per_shard_pending=[ov.count for ov in self.sd.overlays],
                    n_publishes=self.n_publishes,
                    device_bytes=sum(int(np.prod(v.shape)) * v.dtype.itemsize
                                     for v in self.arrs.values()))


ENGINE_CLASSES = {
    "local": LocalEngine,
    "pallas": PallasEngine,
    "sharded": ShardedEngine,
}
