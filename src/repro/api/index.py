"""`LearnedIndex`: one index object, many engines.

The paper presents DILI as a single index with one contract — build,
search, range, insert, delete (Alg. 1/4/6/7/8).  This facade restores that
contract over the repo's three execution substrates: pick an engine in
`IndexConfig`, and every workload (serving session tables, record stores,
benchmarks, examples) composes with it unchanged.

    from repro.api import IndexConfig, LearnedIndex

    ix = LearnedIndex.build(keys, vals, config=IndexConfig(engine="local"))
    vals, found = ix.lookup(queries)
    ks, vs, cnt = ix.range(lo, hi, max_hits=64)
    ix.upsert(new_keys, new_vals)      # visible immediately (overlay)
    ix.delete(dead_keys)               # visible immediately (tombstones)
    ix.flush()                         # fold + republish (Alg. 7/8)
    ix.save("index.npz"); ix2 = LearnedIndex.load("index.npz")
"""

from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import replace

import numpy as np

from ..durability.wal import OP_DELETE, OP_UPSERT
from .config import IndexConfig
from .engines import ENGINE_CLASSES, Engine


class LearnedIndex:
    """Engine-agnostic DILI facade.  All inputs/outputs are host numpy;
    device placement, sharding, kernel dispatch, overlay/merge scheduling,
    and depth threading are the engine's business.

    Threading contract (DESIGN.md sections 8/15):

      * ONE logical writer: the engines' overlay/merge machinery assumes
        a single mutating caller.  The facade enforces it — `upsert`,
        `delete`, and `flush` serialize on an internal RLock, so
        accidental concurrent writers are safe (they queue) but the
        intended deployment is a single writer thread (the serving
        front-end's batcher is exactly that).  The lock also keeps the
        WAL-append -> engine-apply pair atomic, preserving the
        durability ordering contract under contention.
      * Reads (`lookup`/`range`/`get`/`items`) are lock-free: they
        resolve against the current published snapshot + a functional
        overlay reference, which engine publication swaps atomically.
      * `stats()` and `metrics()` are safe to sample from ANY thread
        while the writer runs — they read counters and copied dicts,
        never partial engine state (hammered by tests/test_serve.py).
    """

    def __init__(self, engine: Engine, config: IndexConfig):
        self._engine = engine
        self.config = config
        self._dur = None        # DurabilityManager when config.durability
        self._write_lock = threading.RLock()

    # -- construction --------------------------------------------------------

    @classmethod
    def build(cls, keys, vals=None, config: IndexConfig | None = None,
              **overrides) -> "LearnedIndex":
        """Bulk-load (Alg. 4) through the configured engine.  `overrides`
        are `IndexConfig` field replacements, e.g. `engine="pallas"`.

        With `config.durability` set, a fresh WAL + base checkpoint are
        armed under `durability.dir` (any previous durability state there
        is superseded — use `LearnedIndex.recover` to resurrect it
        instead of rebuilding)."""
        cfg = config or IndexConfig()
        if overrides:
            cfg = replace(cfg, **overrides)
        keys = np.atleast_1d(np.asarray(keys, np.float64))
        if vals is None:
            vals = np.arange(len(keys), dtype=np.int64)
        vals = np.atleast_1d(np.asarray(vals, np.int64))
        if len(keys) != len(vals):
            raise ValueError(f"{len(keys)} keys vs {len(vals)} vals")
        if len(keys) == 0:
            raise ValueError("cannot build an empty index")
        if not np.isfinite(keys).all():
            raise ValueError("keys must be finite")
        # the engines' bulk loaders require sorted unique keys; normalize at
        # the public boundary (duplicates collapse last-write-wins, matching
        # upsert semantics) so every engine sees the same contract
        order = np.argsort(keys, kind="stable")
        keys, vals = keys[order], vals[order]
        keep = np.ones(len(keys), bool)
        keep[:-1] = keys[:-1] != keys[1:]
        keys, vals = keys[keep], vals[keep]
        ix = cls(ENGINE_CLASSES[cfg.engine](keys, vals, cfg), cfg)
        if cfg.durability is not None:
            ix._attach_durability(fresh=True)
        return ix

    def _attach_durability(self, *, fresh: bool,
                           resume_lsns: dict | None = None,
                           start_step: int = 0) -> None:
        """Arm the WAL + checkpoint subsystem for this index (DESIGN.md
        section 14) and hook merge publishes to checkpointing."""
        from ..durability.manager import DurabilityManager
        self._dur = DurabilityManager.attach(
            self.config.durability, self, fresh=fresh,
            resume_lsns=resume_lsns, start_step=start_step)
        self._engine.set_on_publish(self._dur.on_merge_publish)

    @classmethod
    def recover(cls, dur_dir: str, config: IndexConfig | None = None,
                engine: str | None = None) -> "LearnedIndex":
        """Rebuild from the durability directory after a crash: newest
        valid checkpoint + WAL tail replay (`repro.durability.recover`)."""
        from ..durability.recovery import recover as _recover
        return _recover(dur_dir, config=config, engine=engine)

    # -- reads ---------------------------------------------------------------

    def _pad_batch(self, n: int) -> int:
        """pow2 lane count for a batch of n queries (0 = don't pad).

        With `config.pad` the facade pow2-pads query batches exactly like
        the engines pow2-pad their tables, and for the same reason: a
        compiled executable is keyed by shape, so serving a stream of
        arbitrary batch lengths would re-trace per new length (the retrace
        watchdog caught the runner's mixed workloads doing exactly this).
        Padded lanes repeat a real query and are sliced off the result —
        at most 2x lane work for a bounded, log-sized executable set."""
        if not self.config.pad or n == 0:
            return 0
        return 1 << max(6, (n - 1).bit_length())     # >= 64 lanes

    def lookup(self, queries) -> tuple[np.ndarray, np.ndarray]:
        """Batched point lookups -> (vals int64, found bool); vals only
        valid where found."""
        q = np.atleast_1d(np.asarray(queries, np.float64))
        if not np.isfinite(q).all():
            # engines use +/-inf internally as padding/boundary sentinels;
            # a non-finite query would match them (engine-dependently)
            raise ValueError("queries must be finite")
        n = len(q)
        lanes = self._pad_batch(n)
        if lanes > n:
            q = np.concatenate([q, np.full(lanes - n, q[0])])
        tel = self._engine.telemetry
        if tel.enabled:
            t0 = time.perf_counter()
            v, f = self._engine.lookup(q)
            dur = time.perf_counter() - t0
            tel.record_op("lookup", dur, n)
            if tel.trace.enabled:
                tel.trace.add("op.lookup", t0=t0, dur_s=dur,
                              track="facade", n_ops=n)
        else:
            tel.count_ops(n)
            v, f = self._engine.lookup(q)
        return (np.asarray(v, np.int64)[:n],
                np.asarray(f, bool)[:n])

    def range(self, lo, hi,
              max_hits: int | None = None
              ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """For each [lo, hi): the first `max_hits` live pairs ascending —
        (keys [Q,H] +inf-padded, vals [Q,H] -1-padded, counts [Q]
        saturating at `max_hits`).  Overlay-exact: pending upserts appear,
        pending deletes are hidden."""
        lo = np.atleast_1d(np.asarray(lo, np.float64))
        hi = np.atleast_1d(np.asarray(hi, np.float64))
        if lo.shape != hi.shape:
            raise ValueError(f"lo {lo.shape} vs hi {hi.shape}")
        if not (np.isfinite(lo).all() and np.isfinite(hi).all()):
            raise ValueError("range bounds must be finite")
        if max_hits is None:
            max_hits = self.config.max_hits
        if max_hits < 1:
            raise ValueError(f"max_hits must be >= 1, got {max_hits}")
        n = len(lo)
        lanes = self._pad_batch(n)
        if lanes > n:
            lo = np.concatenate([lo, np.full(lanes - n, lo[0])])
            hi = np.concatenate([hi, np.full(lanes - n, hi[0])])
        tel = self._engine.telemetry
        if tel.enabled:
            t0 = time.perf_counter()
            ks, vs, cnt = self._engine.range(lo, hi, max_hits)
            dur = time.perf_counter() - t0
            tel.record_op("range", dur, n)
            if tel.trace.enabled:
                tel.trace.add("op.range", t0=t0, dur_s=dur,
                              track="facade", n_ops=n)
        else:
            tel.count_ops(n)
            ks, vs, cnt = self._engine.range(lo, hi, max_hits)
        if lanes > n:
            ks, vs, cnt = ks[:n], vs[:n], cnt[:n]
        return ks, vs, cnt

    def get(self, key: float) -> int | None:
        """Host-side exact point read (overlay state wins)."""
        return self._engine.get(float(key))

    # -- writes --------------------------------------------------------------

    def upsert(self, keys, vals) -> None:
        """Insert-or-update (Alg. 7 at merge time); visible immediately."""
        keys = np.atleast_1d(np.asarray(keys, np.float64))
        vals = np.atleast_1d(np.asarray(vals, np.int64))
        if len(keys) != len(vals):
            raise ValueError(f"{len(keys)} keys vs {len(vals)} vals")
        if not np.isfinite(keys).all():
            raise ValueError("keys must be finite")
        tel = self._engine.telemetry
        with self._write_lock:
            if tel.enabled:
                t0 = time.perf_counter()
                self._log_write(OP_UPSERT, keys, vals)
                self._engine.upsert(keys, vals)
                dur = time.perf_counter() - t0
                tel.record_op("upsert", dur, len(keys))
                if tel.trace.enabled:
                    tel.trace.add("op.upsert", t0=t0, dur_s=dur,
                                  track="facade", n_ops=len(keys))
            else:
                tel.count_ops(len(keys))
                self._log_write(OP_UPSERT, keys, vals)
                self._engine.upsert(keys, vals)

    def delete(self, keys) -> None:
        """Delete (Alg. 8 at merge time); visible immediately."""
        keys = np.atleast_1d(np.asarray(keys, np.float64))
        if not np.isfinite(keys).all():
            raise ValueError("keys must be finite")
        tel = self._engine.telemetry
        with self._write_lock:
            if tel.enabled:
                t0 = time.perf_counter()
                self._log_write(OP_DELETE, keys, None)
                self._engine.delete(keys)
                dur = time.perf_counter() - t0
                tel.record_op("delete", dur, len(keys))
                if tel.trace.enabled:
                    tel.trace.add("op.delete", t0=t0, dur_s=dur,
                                  track="facade", n_ops=len(keys))
            else:
                tel.count_ops(len(keys))
                self._log_write(OP_DELETE, keys, None)
                self._engine.delete(keys)

    def _log_write(self, op: int, keys: np.ndarray,
                   vals: np.ndarray | None) -> None:
        """WAL-before-apply: persist the batch before the engine (and
        thus the caller) sees it as accepted.  A crash between the append
        and the in-memory apply replays a write the engine never served —
        upsert/delete replay is idempotent, so that is safe; the reverse
        order would acknowledge writes a crash could lose."""
        if self._dur is not None:
            tr = self._engine.telemetry.trace
            if tr.enabled:
                t0 = time.perf_counter()
                self._dur.log(op, keys, vals, epoch=self._engine.epoch,
                              shard_ids=self._engine.shard_ids(keys))
                tr.add("wal.append", t0=t0,
                       dur_s=time.perf_counter() - t0, track="wal",
                       n_ops=len(keys))
            else:
                self._dur.log(op, keys, vals, epoch=self._engine.epoch,
                              shard_ids=self._engine.shard_ids(keys))

    def flush(self) -> dict:
        """Fold every pending write through the host tree and republish;
        returns `stats()` afterwards.  With background maintenance this is
        the synchronous barrier (drains the worker first)."""
        tel = self._engine.telemetry
        with self._write_lock:
            if tel.enabled:
                t0 = time.perf_counter()
                self._engine.flush()
                tel.record_op("flush", time.perf_counter() - t0)
            else:
                tel.count_ops(1)
                self._engine.flush()
            if self._dur is not None:
                self._dur.sync()  # flush doubles as the durability barrier
        return self.stats()

    def close(self) -> None:
        """Release engine resources (stops the background maintenance
        worker when one is running).  Pending writes stay readable but are
        no longer folded; idempotent.  With durability armed, the WAL gets
        a final fsync AFTER the engine drains (a draining background merge
        may still publish a checkpoint through the manager)."""
        close = getattr(self._engine, "close", None)
        if close is not None:
            close()
        if self._dur is not None:
            self._dur.close()

    def abandon(self) -> None:
        """Crash simulation (tests/benchmarks): drop the index WITHOUT the
        final WAL fsync, as a SIGKILL would.  The engine's background
        worker is still stopped so the process can exit."""
        if self._dur is not None:
            self._dur.abandon()  # first: late publishes must no-op
        close = getattr(self._engine, "close", None)
        if close is not None:
            close()

    def __enter__(self) -> "LearnedIndex":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- introspection -------------------------------------------------------

    def items(self) -> tuple[np.ndarray, np.ndarray]:
        """The full live (keys, vals) content, key-sorted (O(n))."""
        return self._engine.items()

    def stats(self) -> dict:
        return self._engine.stats()

    def maint_timings(self) -> list[dict]:
        """Per-merge wall times (merge_s fold+retrain+flatten, publish_s
        upload+flip, incremental, dirty_frac) — benchmark material."""
        return self._engine.maint_timings()

    def metrics(self) -> dict:
        """The stable JSON-able telemetry snapshot (DESIGN.md section 13):
        per-op latency histograms, merge-pipeline span summaries, and the
        retrace watchdog report.  Schema is identical across engines; with
        `config.telemetry` off, histograms/spans are zero-count but op and
        retrace accounting are still live."""
        return self._engine.metrics()

    def inspect(self) -> dict:
        """The `dili.inspect/1` index-health document (DESIGN.md section
        13): depth/fanout histograms, leaf fill, per-leaf model
        prediction-error distribution, segment dirty-fraction breakdown,
        heat accounting, overlay + WAL footprint.  Computed from host-side
        columns (no device sync); the key tree is identical across
        engines.  Safe to call on a serving index."""
        doc = self._engine.inspect()
        if self._dur is not None:
            doc["wal"] = dict(doc["wal"], **self._wal_inspect())
        return doc

    def _wal_inspect(self) -> dict:
        """On-disk durability footprint (armed indexes only)."""
        def du(d):
            # recursive: WAL segments live under shard_NNNNN/ subdirs,
            # checkpoints under step_NNNNNNNN/ subdirs
            b = n = 0
            for root, _dirs, files in os.walk(d):
                for f in files:
                    try:
                        b += os.path.getsize(os.path.join(root, f))
                        n += 1
                    except OSError:
                        pass
            return b, n
        wal_b, wal_n = du(str(self._dur.wal_dir))
        ck_b, ck_n = du(str(self._dur.ckpt_dir))
        return dict(armed=True, n_shards=len(self._dur.writers),
                    wal_bytes=int(wal_b), n_wal_files=int(wal_n),
                    ckpt_bytes=int(ck_b), n_ckpt_files=int(ck_n))

    # -- causal tracing -------------------------------------------------------

    def start_trace(self) -> None:
        """Arm end-to-end causal tracing (requires `config.telemetry`):
        facade ops, WAL appends, serve spans, and merge/recovery spans are
        collected into a bounded ring, linked to the client requests that
        caused them.  Export with `dump_trace`."""
        self._engine.telemetry.start_trace()

    def stop_trace(self) -> None:
        self._engine.telemetry.stop_trace()

    def dump_trace(self, path: str) -> str:
        """Write the collected trace as Chrome-trace-event JSON (open at
        https://ui.perfetto.dev).  Returns `path`."""
        return self._engine.telemetry.trace.dump(
            path, process_name=f"dili:{self.engine}")

    @property
    def telemetry(self):
        """The engine's `repro.obs.Telemetry` bundle (e.g. for
        `mark_warm()` after a benchmark warmup phase)."""
        return self._engine.telemetry

    @property
    def engine(self) -> str:
        return self._engine.name

    @property
    def epoch(self) -> int:
        return self._engine.epoch

    @property
    def n_flattens(self) -> int:
        return self._engine.n_flattens

    @property
    def n_merges(self) -> int:
        return self._engine.n_merges

    @property
    def host(self):
        """The mutable host writer (engine-specific; introspection only)."""
        return self._engine.host

    @property
    def snapshot(self):
        """The engine's current `DeviceSnapshot` for low-level `core.search`
        composition (e.g. `with_stats` probe counting), or None when the
        engine has no single-device snapshot (sharded)."""
        return getattr(self._engine, "snapshot", None)

    # -- persistence ---------------------------------------------------------

    @staticmethod
    def _npz_path(path: str) -> str:
        # np.savez appends .npz to bare paths; normalize on both sides so
        # save(p) -> load(p) always round-trips
        return path if path.endswith(".npz") else path + ".npz"

    def save(self, path: str) -> None:
        """Persist the logical content (live keys/vals incl. pending
        writes) + config.  Load rebuilds the tree — snapshots are derived
        state, and a rebuild re-optimizes the layout for the merged
        distribution.  `config.bulk_kw` must be JSON-serializable.

        The write is atomic (tmp file + `os.replace`): a crash mid-save
        leaves either the previous file or the new one, never a torn
        npz."""
        keys, vals = self.items()
        dst = self._npz_path(path)
        tmp = dst + ".tmp"
        try:
            with open(tmp, "wb") as f:
                np.savez(f, keys=keys, vals=vals,
                         config=np.frombuffer(
                             json.dumps(self.config.to_json_dict()).encode(),
                             dtype=np.uint8))
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, dst)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    @classmethod
    def load(cls, path: str,
             config: IndexConfig | None = None) -> "LearnedIndex":
        """Rebuild from `save()` output; `config` overrides the saved one
        (e.g. load a locally-built index onto the sharded engine)."""
        with np.load(cls._npz_path(path)) as z:
            keys, vals = z["keys"], z["vals"]
            saved = json.loads(bytes(z["config"].tobytes()).decode())
        return cls.build(keys, vals,
                         config=config or IndexConfig.from_json_dict(saved))
