"""`DeviceSnapshot`: the typed, self-describing device snapshot.

PR 0-2 passed the flattened index around as a bare ``dict`` of jnp arrays
with `max_depth` smuggled in as an int32 scalar and `has_dense` as a host
bool — every call site had to know which keys were arrays, which were
static, and to thread `max_depth` by hand into anything traced.  This class
replaces that contract: the arrays are pytree children, and the traversal
statics (`max_depth`, `has_dense`, the key dtype) ride along as aux data,
so a snapshot crosses `jit`/`device_put` boundaries intact and the search
entry points (`core.search`) derive their trip counts from it without any
caller-side depth plumbing.

`core.search` accepts a `DeviceSnapshot` anywhere it accepts the raw dict
(duck-typed via `as_dict()`, so `core` never imports `api`).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..core import search as S
from ..core.flat import FlatDILI


@jax.tree_util.register_pytree_node_class
@dataclass
class DeviceSnapshot:
    """Immutable device snapshot of one flattened DILI.

    `arrays` holds every device table (`a/b/base/fo/dense/tag/key/val`,
    the sorted pair table, `root`, and the packed row mirrors when the
    dtype supports them).  `max_depth` / `has_dense` / `dtype` are static
    metadata: they parameterize the compiled search, not its operands.
    """

    arrays: dict
    max_depth: int
    has_dense: bool
    dtype: Any = jnp.float64

    # -- construction --------------------------------------------------------

    @classmethod
    def from_flat(cls, flat: FlatDILI, dtype=jnp.float64,
                  pad: bool = True) -> "DeviceSnapshot":
        """Upload a host `FlatDILI` (pow2-padded by default so republishes
        reuse the compiled executable)."""
        d = S.device_arrays(flat, dtype, pad=pad)
        has_dense = bool(d.pop("has_dense", True))
        max_depth = int(d.pop("max_depth"))
        return cls(arrays=d, max_depth=max_depth, has_dense=has_dense,
                   dtype=dtype)

    # -- interop with the dict-based low-level layer -------------------------

    def as_dict(self) -> dict:
        """The legacy `core.search` dict view (arrays + embedded statics)."""
        return dict(self.arrays, max_depth=self.max_depth,
                    has_dense=self.has_dense)

    # -- introspection -------------------------------------------------------

    @property
    def nbytes(self) -> int:
        return sum(int(np.prod(v.shape)) * v.dtype.itemsize
                   for v in self.arrays.values() if hasattr(v, "dtype"))

    def table_shape(self, name: str) -> tuple:
        return tuple(self.arrays[name].shape)

    def same_shapes(self, other: "DeviceSnapshot | None") -> bool:
        """True when a republish into these shapes would NOT re-trace."""
        if other is None:
            return False
        return (set(self.arrays) == set(other.arrays)
                and all(self.arrays[k].shape == other.arrays[k].shape
                        for k in self.arrays))

    # -- pytree protocol -----------------------------------------------------

    def tree_flatten(self):
        names = tuple(sorted(self.arrays))
        children = tuple(self.arrays[k] for k in names)
        aux = (names, self.max_depth, self.has_dense,
               np.dtype(self.dtype).name)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        names, max_depth, has_dense, dtype_name = aux
        return cls(arrays=dict(zip(names, children)), max_depth=max_depth,
                   has_dense=has_dense, dtype=np.dtype(dtype_name))
