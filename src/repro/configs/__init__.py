"""Assigned architecture configs (exact, from the public pool) + the paper's
own index-workload config.  ``get_config(arch_id)`` / ``list_archs()``."""

from __future__ import annotations

import importlib

ARCHS = [
    "falcon_mamba_7b",
    "zamba2_1p2b",
    "whisper_base",
    "command_r_plus_104b",
    "gemma2_2b",
    "granite_8b",
    "phi3_medium_14b",
    "internvl2_1b",
    "granite_moe_1b_a400m",
    "grok_1_314b",
]

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}
_ALIAS.update({
    "falcon-mamba-7b": "falcon_mamba_7b",
    "zamba2-1.2b": "zamba2_1p2b",
    "whisper-base": "whisper_base",
    "command-r-plus-104b": "command_r_plus_104b",
    "gemma2-2b": "gemma2_2b",
    "granite-8b": "granite_8b",
    "phi3-medium-14b": "phi3_medium_14b",
    "internvl2-1b": "internvl2_1b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "grok-1-314b": "grok_1_314b",
})


def get_config(arch: str):
    mod_name = _ALIAS.get(arch, arch).replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def list_archs():
    return list(ARCHS)
