"""Assigned architecture configs (exact, from the public pool) + the paper's
own index-workload config.  ``get_config(arch_id)`` / ``list_archs()``."""

from __future__ import annotations

import importlib

ARCHS = [
    "whisper_base",
    "gemma2_2b",
    "granite_8b",
    "internvl2_1b",
    "granite_moe_1b_a400m",
    "grok_1_314b",
]

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}
_ALIAS.update({
    "whisper-base": "whisper_base",
    "gemma2-2b": "gemma2_2b",
    "granite-8b": "granite_8b",
    "internvl2-1b": "internvl2_1b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "grok-1-314b": "grok_1_314b",
})


def get_config(arch: str):
    mod_name = _ALIAS.get(arch, arch).replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def list_archs():
    return list(ARCHS)
