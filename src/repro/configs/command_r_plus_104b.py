"""command-r-plus-104b [dense]: 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — GQA, no-bias, parallel attn||ffn block
[hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=33792,
    vocab=256000, parallel_block=True, act="swiglu", rope_theta=75000000.0,
    tie_embeddings=True, accum_steps=16,
)
