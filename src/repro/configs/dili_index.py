"""The paper's own workload config: index bulk-load + query serving
(dataset sizes/distributions from section 7.1, scaled by --n-keys)."""
from dataclasses import dataclass


@dataclass(frozen=True)
class IndexConfig:
    name: str = "dili-paper"
    n_keys: int = 2_000_000          # paper: 200M (FB/WikiTS/Logn), 800M (OSM/Books)
    distributions: tuple = ("fb", "wikits", "osm", "books", "logn")
    query_batch: int = 8192
    eta: float = 2.0                 # leaf enlarging ratio (Alg. 5)
    lam: float = 2.0                 # adjustment threshold (Alg. 7)
    rho: float = 0.2                 # level decay (Eq. 5)
    omega: int = 4096                # max average fanout (Alg. 3)


CONFIG = IndexConfig()
