"""falcon-mamba-7b [ssm]: 64L d_model=4096 (attn-free) vocab=65024,
ssm_state=16 — Mamba-1 architecture [arXiv:2410.05355; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=65024, ssm_state=16, ssm_version=1, expand=2, d_conv=4,
    tie_embeddings=False,
)
