"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000 —
local(4096)/global alternating attention, logit softcap 30, attn softcap 50,
GeGLU, post-norms [arXiv:2408.00118; hf]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_ff=9216,
    vocab=256000, head_dim=256, attn_type="local_global", window=4096,
    logit_softcap=30.0, attn_softcap=50.0, act="geglu", tie_embeddings=True,
)
