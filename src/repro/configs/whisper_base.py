"""whisper-base [audio]: 6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865 —
encoder-decoder; conv frontend STUBBED (input_specs feeds 1500 precomputed
frame embeddings) [arXiv:2212.04356; unverified]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab=51865, is_encdec=True, encoder_layers=6,
    frontend="audio", frontend_seq=1500, act="gelu", tie_embeddings=True,
)
