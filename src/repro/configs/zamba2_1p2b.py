"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64 — Mamba-2 backbone + shared attention blocks
[arXiv:2411.15242; hf].  The shared transformer block is applied every 6
Mamba-2 blocks with one shared set of weights (per-site KV caches)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32000, ssm_state=64, ssm_version=2, ssm_heads=32, expand=2,
    d_conv=4, shared_attn_every=6, act="gelu",
)
