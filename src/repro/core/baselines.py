"""The paper's competitor indexes, as batched JAX searches (section 7.1).

Implemented: BinS, B+Tree, RMI (2-stage), PGM (epsilon-bounded PLA), RS
(RadixSpline), LIPP, ALEX-lite (gapped arrays + power-of-2 internal fanout),
plus the BU-Tree itself (Table 9).  MassTree is a string-trie/B-tree hybrid
whose cache-craftiness has no meaning for batched f64 gathers on TPU; it is
documented as out of scope in DESIGN.md.

Each index exposes:  build(keys, vals) -> state dict (numpy),
`device(state)` -> jnp dict, and a jitted `lookup(state, queries)` returning
(vals, found, probes) where probes counts memory touches (Table 5 proxy).
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .bu_tree import least_squares
from .dili import Leaf, local_opt
from .flat import flatten as flatten_dili


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------


def _vma_zero(q):
    return (q * 0).astype(jnp.int32)


def _binary_search(keys: jnp.ndarray, q: jnp.ndarray, lo, hi, iters: int,
                   upper: bool = False):
    """Vectorized binary search in keys[lo..hi); probes counted.

    lower (default): first index with keys[i] >= q.
    upper:           first index with keys[i] >  q  (use upper-1 for
                     "which segment covers q" selections — lower-1 is wrong
                     exactly when q equals a segment-start key).
    """
    zi = _vma_zero(q)
    probes = zi

    def body(state, _):
        lo, hi, probes = state
        mid = (lo + hi) // 2
        go = lo < hi
        k = keys[jnp.clip(mid, 0, len(keys) - 1)]
        below = (k <= q) if upper else (k < q)
        lo = jnp.where(go & below, mid + 1, lo)
        hi = jnp.where(go & ~below, mid, hi)
        return (lo, hi, probes + go.astype(jnp.int32)), None

    (lo, hi, probes), _ = jax.lax.scan(body, (lo, hi, probes), None,
                                       length=iters)
    return lo, probes


# ---------------------------------------------------------------------------
# BinS: binary search over the full sorted array
# ---------------------------------------------------------------------------


class BinS:
    name = "BinS"

    @staticmethod
    def build(keys, vals):
        return dict(keys=np.asarray(keys, np.float64),
                    vals=np.asarray(vals, np.int64), n=len(keys))

    @staticmethod
    def device(st, dtype=jnp.float64):
        return dict(keys=jnp.asarray(st["keys"], dtype),
                    vals=jnp.asarray(st["vals"], jnp.int32),
                    n=st["n"])

    @staticmethod
    def lookup(st, q):
        n = st["n"]
        iters = max(int(math.ceil(math.log2(max(n, 2)))) + 1, 1)
        zi = _vma_zero(q)
        pos, probes = _binary_search(st["keys"], q, zi, zi + n, iters)
        pos = jnp.clip(pos, 0, n - 1)
        found = st["keys"][pos] == q
        return st["vals"][pos], found, probes + 1


# ---------------------------------------------------------------------------
# B+Tree: implicit structure-of-arrays multiway tree
# ---------------------------------------------------------------------------


class BTree:
    name = "B+Tree"

    @staticmethod
    def build(keys, vals, fanout: int = 32):
        keys = np.asarray(keys, np.float64)
        levels = []          # top..bottom separator arrays
        cur = keys[::1]
        # leaf level = the keys themselves (implicit); build separator levels
        sep = keys[::fanout]
        while len(sep) > 1:
            levels.append(sep)
            sep = sep[::fanout]
        levels.reverse()     # levels[0] is the root separator array
        return dict(keys=keys, vals=np.asarray(vals, np.int64),
                    levels=[l for l in levels], fanout=fanout, n=len(keys))

    @staticmethod
    def device(st, dtype=jnp.float64):
        return dict(keys=jnp.asarray(st["keys"], dtype),
                    vals=jnp.asarray(st["vals"], jnp.int32),
                    levels=tuple(jnp.asarray(l, dtype) for l in st["levels"]),
                    fanout=st["fanout"], n=st["n"])

    @staticmethod
    def lookup(st, q):
        fo = st["fanout"]
        zi = _vma_zero(q)
        node = zi           # index into current level
        probes = zi
        itb = int(math.ceil(math.log2(fo))) + 1
        for lvl in st["levels"]:
            n_l = len(lvl)
            lo = node * fo
            hi = jnp.minimum(lo + fo, n_l)
            # binary search within the node's separator window
            pos, pr = _binary_search(lvl, q, lo, hi, itb, upper=True)
            # child = (#separators <= q) - 1  (separators are child lower bounds)
            node = jnp.clip(pos - 1, 0, n_l - 1)
            probes = probes + pr + 1
        # leaf: binary search within the fanout-sized run of keys
        lo = node * fo
        hi = jnp.minimum(lo + fo, st["n"])
        pos, pr = _binary_search(st["keys"], q, lo, hi, itb)
        pos = jnp.clip(pos, 0, st["n"] - 1)
        found = st["keys"][pos] == q
        return st["vals"][pos], found, probes + pr + 1


# ---------------------------------------------------------------------------
# RMI: 2-stage recursive model index with per-model error bounds
# ---------------------------------------------------------------------------


class RMI:
    name = "RMI"

    @staticmethod
    def build(keys, vals, n_models: int = 4096):
        keys = np.asarray(keys, np.float64)
        n = len(keys)
        y = np.arange(n, dtype=np.float64)
        a1, b1 = least_squares(keys, y * (n_models / n))
        mid = np.clip(np.floor(a1 + b1 * keys).astype(np.int64), 0,
                      n_models - 1)
        a2 = np.zeros(n_models)
        b2 = np.zeros(n_models)
        err_lo = np.zeros(n_models, np.int64)
        err_hi = np.zeros(n_models, np.int64)
        starts = np.searchsorted(mid, np.arange(n_models), side="left")
        ends = np.searchsorted(mid, np.arange(n_models), side="right")
        for m in range(n_models):
            s, e = starts[m], ends[m]
            if e - s == 0:
                continue
            aa, bb = least_squares(keys[s:e], y[s:e])
            a2[m], b2[m] = aa, bb
            pred = np.floor(aa + bb * keys[s:e])
            d = pred - y[s:e]
            err_lo[m] = int(np.ceil(max(d.max(), 0))) + 1
            err_hi[m] = int(np.ceil(max(-d.min(), 0))) + 1
        return dict(keys=keys, vals=np.asarray(vals, np.int64),
                    a1=a1, b1=b1, a2=a2, b2=b2,
                    err_lo=err_lo, err_hi=err_hi, n=n, n_models=n_models)

    @staticmethod
    def device(st, dtype=jnp.float64):
        return dict(keys=jnp.asarray(st["keys"], dtype),
                    vals=jnp.asarray(st["vals"], jnp.int32),
                    a1=jnp.asarray(st["a1"], dtype), b1=jnp.asarray(st["b1"], dtype),
                    a2=jnp.asarray(st["a2"], dtype), b2=jnp.asarray(st["b2"], dtype),
                    err_lo=jnp.asarray(st["err_lo"], jnp.int32),
                    err_hi=jnp.asarray(st["err_hi"], jnp.int32),
                    n=st["n"], n_models=st["n_models"])

    @staticmethod
    def lookup(st, q):
        n = st["n"]
        m = jnp.clip(jnp.floor(st["a1"] + st["b1"] * q).astype(jnp.int32),
                     0, st["n_models"] - 1)
        pred = jnp.floor(st["a2"][m] + st["b2"][m] * q).astype(jnp.int32)
        lo = jnp.clip(pred - st["err_lo"][m], 0, n - 1)
        hi = jnp.clip(pred + st["err_hi"][m], 0, n)
        pos, probes = _binary_search(st["keys"], q, lo, hi, 22)
        pos = jnp.clip(pos, 0, n - 1)
        found = st["keys"][pos] == q
        return st["vals"][pos], found, probes + 2


# ---------------------------------------------------------------------------
# PGM: epsilon-bounded piecewise linear approximation, 2 levels
# ---------------------------------------------------------------------------


def _pla_segments(keys: np.ndarray, eps: int) -> list[tuple[int, int, float, float]]:
    """Greedy epsilon-PLA (slope-cone algorithm): maximal segments such that
    |a + b*x_i - i_local| <= eps for all covered keys."""
    n = len(keys)
    segs = []
    i = 0
    while i < n:
        x0 = keys[i]
        lo_sl, hi_sl = -math.inf, math.inf
        j = i + 1
        while j < n:
            dx = keys[j] - x0
            if dx <= 0:
                break
            y = j - i
            lo_need = (y - eps) / dx
            hi_need = (y + eps) / dx
            nlo = max(lo_sl, lo_need)
            nhi = min(hi_sl, hi_need)
            if nlo > nhi:
                break
            lo_sl, hi_sl = nlo, nhi
            j += 1
        if j == i + 1:
            b = 0.0
        else:
            b = (lo_sl + hi_sl) / 2 if math.isfinite(lo_sl + hi_sl) else 0.0
        a = i - b * x0          # maps key -> global index approx
        segs.append((i, j, a + b * 0, b))  # store (start, end, a_global, b)
        segs[-1] = (i, j, i - b * x0, b)
        i = j
    return segs


class PGM:
    name = "PGM"

    @staticmethod
    def _measured_bound(xs, idx_of, a, b, eps):
        """Verified prediction-error bound (f64 eval error on tight key
        clusters can exceed the cone's epsilon; measure, don't trust)."""
        seg = idx_of
        pred = np.floor(a[seg] + b[seg] * xs)
        return max(int(np.abs(pred - np.arange(len(xs))).max()) + 1, eps)

    @staticmethod
    def build(keys, vals, eps: int = 64):
        keys = np.asarray(keys, np.float64)
        segs = _pla_segments(keys, eps)
        seg_key = np.array([keys[s[0]] for s in segs])
        seg_a = np.array([s[2] for s in segs])
        seg_b = np.array([s[3] for s in segs])
        which = np.clip(np.searchsorted(seg_key, keys, side="right") - 1,
                        0, len(segs) - 1)
        eps1 = PGM._measured_bound(keys, which, seg_a, seg_b, eps)
        # upper level: PLA over segment start keys
        segs2 = _pla_segments(seg_key, eps)
        s2_key = np.array([seg_key[s[0]] for s in segs2])
        s2_a = np.array([s[2] for s in segs2])
        s2_b = np.array([s[3] for s in segs2])
        which2 = np.clip(np.searchsorted(s2_key, seg_key, side="right") - 1,
                         0, len(segs2) - 1)
        eps2 = PGM._measured_bound(seg_key, which2, s2_a, s2_b, eps)
        return dict(keys=keys, vals=np.asarray(vals, np.int64),
                    seg_key=seg_key, seg_a=seg_a, seg_b=seg_b,
                    s2_key=s2_key, s2_a=s2_a, s2_b=s2_b,
                    eps=eps1, eps2=eps2,
                    n=len(keys), n_seg=len(segs), n_seg2=len(segs2))

    @staticmethod
    def device(st, dtype=jnp.float64):
        out = {k: (jnp.asarray(v, dtype) if isinstance(v, np.ndarray)
                   and v.dtype == np.float64 else v) for k, v in st.items()}
        out["vals"] = jnp.asarray(st["vals"], jnp.int32)
        return out

    @staticmethod
    def lookup(st, q):
        eps1 = st["eps"]
        eps2 = st["eps2"]
        it1 = int(math.ceil(math.log2(2 * eps1 + 3))) + 1
        it2 = int(math.ceil(math.log2(2 * eps2 + 3))) + 1
        # root -> find segment-of-segments by scanning s2 (small; binary)
        zi = _vma_zero(q)
        n2 = st["n_seg2"]
        p2, pr0 = _binary_search(st["s2_key"], q, zi, zi + n2,
                                 max(int(math.ceil(math.log2(max(n2, 2)))) + 1, 1),
                                 upper=True)
        p2 = jnp.clip(p2 - 1, 0, n2 - 1)
        pred = jnp.floor(st["s2_a"][p2] + st["s2_b"][p2] * q).astype(jnp.int32)
        lo = jnp.clip(pred - eps2 - 1, 0, st["n_seg"] - 1)
        hi = jnp.clip(pred + eps2 + 2, 0, st["n_seg"])
        p1, pr1 = _binary_search(st["seg_key"], q, lo, hi, it2, upper=True)
        p1 = jnp.clip(p1 - 1, 0, st["n_seg"] - 1)
        pred = jnp.floor(st["seg_a"][p1] + st["seg_b"][p1] * q).astype(jnp.int32)
        lo = jnp.clip(pred - eps1 - 1, 0, st["n"] - 1)
        hi = jnp.clip(pred + eps1 + 2, 0, st["n"])
        pos, pr2 = _binary_search(st["keys"], q, lo, hi, it1)
        pos = jnp.clip(pos, 0, st["n"] - 1)
        found = st["keys"][pos] == q
        return st["vals"][pos], found, pr0 + pr1 + pr2 + 3


# ---------------------------------------------------------------------------
# RS: RadixSpline — radix table over key prefix + spline with maxerr
# ---------------------------------------------------------------------------


def _greedy_spline(keys: np.ndarray, eps: int) -> list[int]:
    """GreedySplineCorridor knot selection (RadixSpline)."""
    n = len(keys)
    knots = [0]
    base = 0
    lo_sl, hi_sl = -math.inf, math.inf
    for i in range(1, n):
        dx = keys[i] - keys[base]
        if dx <= 0:
            continue
        lo_need = ((i - eps) - base) / dx
        hi_need = ((i + eps) - base) / dx
        if max(lo_sl, lo_need) > min(hi_sl, hi_need):
            knots.append(i - 1)
            base = i - 1
            dx = keys[i] - keys[base]
            lo_sl = ((i - eps) - base) / dx
            hi_sl = ((i + eps) - base) / dx
        else:
            lo_sl = max(lo_sl, lo_need)
            hi_sl = min(hi_sl, hi_need)
    if knots[-1] != n - 1:
        knots.append(n - 1)
    return knots


class RS:
    name = "RS"

    @staticmethod
    def build(keys, vals, eps: int = 32, radix_bits: int = 18):
        keys = np.asarray(keys, np.float64)
        n = len(keys)
        ki = np.array(_greedy_spline(keys, eps), np.int64)
        sp_key = keys[ki]
        sp_pos = ki.astype(np.float64)
        # verify the actual interpolant error on every key; store the measured
        # bound (greedy corridor subtleties make the theoretical bound loose)
        seg = np.clip(np.searchsorted(sp_key, keys, side="right") - 1,
                      0, len(ki) - 2)
        x0, x1 = sp_key[seg], sp_key[seg + 1]
        y0, y1 = sp_pos[seg], sp_pos[seg + 1]
        t = np.where(x1 > x0, (keys - x0) / np.maximum(x1 - x0, 1e-300), 0.0)
        pred = np.floor(y0 + t * (y1 - y0))
        bound = int(np.abs(pred - np.arange(n)).max()) + 1
        # radix table over normalized key space
        k0, k1 = keys[0], keys[-1]
        r = 1 << radix_bits
        norm = ((sp_key - k0) / max(k1 - k0, 1e-300) * r).astype(np.int64)
        table = np.searchsorted(norm, np.arange(r + 1), side="left")
        return dict(keys=keys, vals=np.asarray(vals, np.int64),
                    sp_key=sp_key, sp_pos=sp_pos, table=table,
                    k0=k0, k1=k1, radix_bits=radix_bits, eps=bound, n=n,
                    n_spline=len(sp_key))

    @staticmethod
    def device(st, dtype=jnp.float64):
        out = dict(st)
        for k in ("keys", "sp_key", "sp_pos"):
            out[k] = jnp.asarray(st[k], dtype)
        out["table"] = jnp.asarray(st["table"], jnp.int32)
        out["vals"] = jnp.asarray(st["vals"], jnp.int32)
        return out

    @staticmethod
    def lookup(st, q):
        r = 1 << st["radix_bits"]
        bucket = jnp.clip(((q - st["k0"]) / (st["k1"] - st["k0"]) * r)
                          .astype(jnp.int32), 0, r - 1)
        lo = st["table"][bucket]
        hi = jnp.minimum(st["table"][bucket + 1] + 1, st["n_spline"])
        p, pr0 = _binary_search(st["sp_key"], q, lo, hi, 12)
        p = jnp.clip(p, 1, st["n_spline"] - 1)
        # linear interpolation between spline points
        x0, x1 = st["sp_key"][p - 1], st["sp_key"][p]
        y0, y1 = st["sp_pos"][p - 1], st["sp_pos"][p]
        t = jnp.where(x1 > x0, (q - x0) / (x1 - x0), 0.0)
        pred = jnp.floor(y0 + t * (y1 - y0)).astype(jnp.int32)
        eps = st["eps"]
        lo = jnp.clip(pred - eps - 1, 0, st["n"] - 1)
        hi = jnp.clip(pred + eps + 2, 0, st["n"])
        itr = max(int(math.ceil(math.log2(2 * eps + 3))) + 1, 4)
        pos, pr1 = _binary_search(st["keys"], q, lo, hi, itr)
        pos = jnp.clip(pos, 0, st["n"] - 1)
        found = st["keys"][pos] == q
        return st["vals"][pos], found, pr0 + pr1 + 2


# ---------------------------------------------------------------------------
# LIPP: one kernelized model from the root; conflicts spawn child nodes.
# Reuses DILI's local-opt machinery with a single whole-range "leaf" root.
# ---------------------------------------------------------------------------


class LIPP:
    name = "LIPP"

    @staticmethod
    def build(keys, vals, gap: float = 1.25):
        keys = np.asarray(keys, np.float64)
        n = len(keys)
        pairs = [(float(keys[i]), int(vals[i])) for i in range(n)]
        root = Leaf(lb=float(keys[0]), ub=float(keys[-1]) + 1.0)
        a, b = least_squares(keys, np.arange(n, dtype=np.float64))
        root.a, root.b = a, b
        local_opt(root, pairs, eta=gap)

        class _Shim:            # minimal DILI-like shell for flatten()
            pass
        shim = _Shim()
        shim.root = root
        flat = flatten_dili(shim)   # type: ignore[arg-type]
        return dict(flat=flat)

    @staticmethod
    def device(st, dtype=jnp.float64):
        from . import search as S
        return S.device_arrays(st["flat"], dtype)

    @staticmethod
    def lookup(st, q):
        from . import search as S
        # depth derives from the snapshot (resolve_max_depth), never a
        # hard-coded trip count
        v, f, nodes, probes = S.search_batch(st, q, with_stats=True)
        return v, f, nodes + probes


# ---------------------------------------------------------------------------
# ALEX-lite: power-of-2 equal splits + gapped-array leaves + exp. search
# ---------------------------------------------------------------------------


class ALEX:
    name = "ALEX"

    @staticmethod
    def build(keys, vals, max_leaf: int = 4096, gap: float = 1.3):
        keys = np.asarray(keys, np.float64)
        vals = np.asarray(vals, np.int64)
        n = len(keys)
        lo_k, hi_k = keys[0], keys[-1] + max(1e-9, abs(keys[-1]) * 1e-12)
        # choose k so that average leaf size <= max_leaf (power-of-2 fanout)
        k = max(int(math.ceil(math.log2(max(n / max_leaf, 1)))), 1)
        fo = 1 << k
        edges = np.linspace(lo_k, hi_k, fo + 1)
        starts = np.searchsorted(keys, edges[:-1], side="left")
        ends = np.searchsorted(keys, edges[1:], side="left")
        # gapped leaves: spread each leaf's keys over gap*size slots by model
        leaf_base = []
        gk, gv, gt = [], [], []
        cursor = 0
        leaf_a, leaf_b, leaf_fo = [], [], []
        for i in range(fo):
            s, e = int(starts[i]), int(ends[i])
            m = e - s
            cap = max(int(math.ceil(m * gap)), 1)
            slot_k = np.full(cap, np.nan)
            slot_v = np.zeros(cap, np.int64)
            slot_t = np.zeros(cap, np.int8)
            if m > 0:
                a, b = least_squares(keys[s:e],
                                     np.arange(m, dtype=np.float64) * (cap / m))
                pos = np.clip(np.floor(a + b * keys[s:e]).astype(np.int64),
                              0, cap - 1)
                # monotonic gapped placement: keep sorted order, spread per
                # model, resolve collisions by pushing right then clamping
                # from the right edge (vectorized equivalent of ALEX's
                # gapped-array bulk placement)
                ar = np.arange(m)
                p = np.maximum.accumulate(pos - ar) + ar      # strictly incr.
                p = np.minimum(p, cap - m + ar)               # right-feasible
                slot_k[p] = keys[s:e]
                slot_v[p] = vals[s:e]
                slot_t[p] = 1
            else:
                a, b = 0.0, 0.0
            leaf_a.append(a)
            leaf_b.append(b)
            leaf_fo.append(cap)
            leaf_base.append(cursor)
            gk.append(slot_k)
            gv.append(slot_v)
            gt.append(slot_t)
            cursor += cap
        # sorted view for exponential search: backward-fill gaps with next key
        slot_k = np.concatenate(gk)
        slot_v = np.concatenate(gv)
        slot_t = np.concatenate(gt)
        filled = slot_k[::-1].copy()
        mask = ~np.isnan(filled)
        idxs = np.where(mask, np.arange(len(filled)), 0)
        idxs = np.maximum.accumulate(idxs)
        filled = np.where(np.isnan(filled[idxs]), np.inf, filled[idxs])[::-1]
        return dict(slot_key=filled, slot_raw=np.nan_to_num(slot_k, nan=np.inf),
                    slot_val=slot_v, slot_tag=slot_t,
                    leaf_a=np.array(leaf_a), leaf_b=np.array(leaf_b),
                    leaf_fo=np.array(leaf_fo, np.int32),
                    leaf_base=np.array(leaf_base, np.int32),
                    k0=lo_k, k1=hi_k, fo=fo, n=n, n_slots=cursor)

    @staticmethod
    def device(st, dtype=jnp.float64):
        out = dict(st)
        for k in ("slot_key", "slot_raw", "leaf_a", "leaf_b"):
            out[k] = jnp.asarray(st[k], dtype)
        out["slot_val"] = jnp.asarray(st["slot_val"], jnp.int32)
        out["slot_tag"] = jnp.asarray(st["slot_tag"], jnp.int8)
        out["leaf_fo"] = jnp.asarray(st["leaf_fo"], jnp.int32)
        out["leaf_base"] = jnp.asarray(st["leaf_base"], jnp.int32)
        return out

    @staticmethod
    def lookup(st, q):
        fo = st["fo"]
        leaf = jnp.clip(((q - st["k0"]) / (st["k1"] - st["k0"]) * fo)
                        .astype(jnp.int32), 0, fo - 1)
        a = st["leaf_a"][leaf]
        b = st["leaf_b"][leaf]
        cap = st["leaf_fo"][leaf]
        base = st["leaf_base"][leaf]
        m1 = jnp.maximum(cap - 1, 0)
        pred = jnp.clip(jnp.floor(a + b * q).astype(jnp.int32), 0, m1)
        keys = st["slot_key"]

        def key_at(i):
            return keys[base + jnp.clip(i, 0, m1)]

        # gaps are backward-filled with the NEXT real key, so runs of equal
        # values end at the real slot: search the *upper bound* (first key
        # strictly greater than q) and probe the slot just before it.
        zi = _vma_zero(q)
        probes = zi + 1
        going_up = key_at(pred) <= q

        def exp_body(state, _):
            bound, done, probes = state
            up_i = jnp.clip(pred + bound, 0, m1)
            dn_i = jnp.clip(pred - bound, 0, m1)
            need_up = going_up & ~done & (key_at(up_i) <= q) & (pred + bound < m1)
            need_dn = ~going_up & ~done & (key_at(dn_i) > q) & (pred - bound > 0)
            probes = probes + (~done).astype(jnp.int32)
            done = done | ~(need_up | need_dn)
            bound = jnp.where(done, bound, bound * 2)
            return (bound, done, probes), None

        (bound, _, probes), _ = jax.lax.scan(
            exp_body, (zi + 1, zi > 0, probes), None, length=18)
        lo = jnp.where(going_up, pred, jnp.maximum(pred - bound, 0))
        hi = jnp.where(going_up, jnp.minimum(pred + bound + 1, m1 + 1), pred)

        def bin_body(state, _):
            lo, hi, probes = state
            mid = (lo + hi) // 2
            go = lo < hi
            below = key_at(mid) <= q
            lo = jnp.where(go & below, mid + 1, lo)
            hi = jnp.where(go & ~below, mid, hi)
            return (lo, hi, probes + go.astype(jnp.int32)), None

        (lo, hi, probes), _ = jax.lax.scan(bin_body, (lo, hi, probes), None,
                                           length=18)
        s = base + jnp.clip(lo - 1, 0, m1)
        found = (st["slot_tag"][s] == 1) & (st["slot_raw"][s] == q)
        return st["slot_val"][s], found, probes


ALL_BASELINES = [BinS, BTree, RMI, PGM, RS, LIPP, ALEX]
