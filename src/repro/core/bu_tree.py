"""BU-Tree construction (paper Algorithms 2 & 3).

The BU-Tree is the *mirror model*: a bottom-up tree whose node layout is found
by greedy piecewise-linear merging under the paper's cache-aware cost model
(Eq. 2/5/6/7).  DILI later copies the per-level node counts of this tree
(build.py) but re-divides ranges equally so internal models become exact.

Everything here is host-side numpy: bulk loading is a one-time offline stage
(exactly as in the paper, where construction takes minutes); the *search* path
is the device-side JAX/Pallas code in search.py / kernels/.

Incremental-statistics implementation notes
-------------------------------------------
Each piece I_i^k keeps sufficient statistics (n, Sx, Sy, Sxx, Sxy, Syy) so the
least-squares loss gamma(I) of a piece and of a tentative merge I_i U I_{i+1}
is O(1).  A lazy heap holds merge candidates d_i = m_i - s_i - s_{i+1}
(Alg. 3 line 9).  The estimated accumulated search cost T_ea (Eq. 7) is
maintained incrementally: only the merged piece's contribution changes per
iteration, so evaluating epsilon_k for every k costs O(piece) per merge,
O(n log n) in total -- matching the paper's complexity claim.

For internal levels the paper sums t_E over *all* N underlying keys; we weight
each boundary point by the number of underlying keys it covers (`weights`),
which computes the same sum exactly when per-piece errors are evaluated at the
boundary points (documented approximation in DESIGN.md section 7).
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field

import numpy as np

# ---------------------------------------------------------------------------
# Cost-model constants (paper section 7.1).  Units: CPU cycles in the paper; on
# TPU we keep the *ratios* (they shape the layout) and expose them as knobs.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CostModel:
    theta_n: float = 130.0   # load a node (one cache line / one HBM gather)
    theta_c: float = 130.0   # fetch child pointer
    theta_e: float = 130.0   # access a pair during local search
    eta_lin: float = 25.0    # execute a linear function
    mu_l: float = 5.0        # misc ops, linear search
    mu_e: float = 17.0       # misc ops, exponential search iteration
    rho: float = 0.2         # decay of higher levels' impact on leaf layout (Eq. 5)
    omega: int = 4096        # max average fanout (Alg. 3); paper uses 2048-4096

    def t_exp_search(self, log2_err: np.ndarray) -> np.ndarray:
        """t_E: exponential-search cost given log2 of prediction error (Eq. 2)."""
        return 2.0 * log2_err * (self.mu_e + self.theta_e)


DEFAULT_COST = CostModel()


# ---------------------------------------------------------------------------
# Sufficient statistics for least squares on (x, y) with integer y = index.
# ---------------------------------------------------------------------------


@dataclass
class SegStats:
    n: float = 0.0
    sx: float = 0.0
    sy: float = 0.0
    sxx: float = 0.0
    sxy: float = 0.0
    syy: float = 0.0

    @staticmethod
    def of(x: np.ndarray, y: np.ndarray, w: np.ndarray | None = None) -> "SegStats":
        if w is None:
            w = np.ones_like(x)
        return SegStats(
            n=float(w.sum()),
            sx=float((w * x).sum()),
            sy=float((w * y).sum()),
            sxx=float((w * x * x).sum()),
            sxy=float((w * x * y).sum()),
            syy=float((w * y * y).sum()),
        )

    def merge(self, o: "SegStats") -> "SegStats":
        return SegStats(self.n + o.n, self.sx + o.sx, self.sy + o.sy,
                        self.sxx + o.sxx, self.sxy + o.sxy, self.syy + o.syy)

    def fit(self) -> tuple[float, float]:
        """Return (a, b) minimizing sum w*(y - (a + b x))^2."""
        if self.n <= 1:
            return (self.sy / max(self.n, 1.0), 0.0)
        den = self.n * self.sxx - self.sx * self.sx
        if den <= 0 or not math.isfinite(den):
            return (self.sy / self.n, 0.0)
        b = (self.n * self.sxy - self.sx * self.sy) / den
        a = (self.sy - b * self.sx) / self.n
        return (a, b)

    def sse(self) -> float:
        """Sum of squared errors of the least-squares fit (O(1))."""
        a, b = self.fit()
        # sum (y - a - b x)^2 expanded over sufficient statistics
        v = (self.syy + self.n * a * a + b * b * self.sxx
             - 2 * a * self.sy - 2 * b * self.sxy + 2 * a * b * self.sx)
        return max(v, 0.0)

    def rmse(self) -> float:
        return math.sqrt(self.sse() / max(self.n, 1.0))


def least_squares(x: np.ndarray, y: np.ndarray) -> tuple[float, float]:
    """LEASTSQUARES(X, Y) -> (a, b) with y ~ a + b*x (paper Definition 2).

    Centered computation: `n*Sxx - Sx^2` cancels catastrophically for tightly
    clustered keys (e.g. two keys 1e-9 apart), which would return b=0 and make
    conflict leaves unable to separate their keys.
    """
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    if len(x) == 0:
        return (0.0, 0.0)
    mx = float(x.mean())
    my = float(y.mean())
    dx = x - mx
    den = float((dx * dx).sum())
    if den <= 0.0 or not math.isfinite(den):
        return (my, 0.0)
    b = float((dx * (y - my)).sum()) / den
    return (my - b * mx, b)


# ---------------------------------------------------------------------------
# BU nodes
# ---------------------------------------------------------------------------


@dataclass
class BUNode:
    lb: float
    ub: float
    a: float
    b: float
    height: int
    # internal: children + boundary array B (paper section 4.1)
    children: list["BUNode"] = field(default_factory=list)
    boundaries: np.ndarray | None = None
    # leaf: the slice [lo, hi) of the global sorted pair array it covers
    lo: int = 0
    hi: int = 0

    @property
    def fanout(self) -> int:
        return len(self.children)

    @property
    def is_leaf(self) -> bool:
        return not self.children


@dataclass
class BUTree:
    root: BUNode
    levels: list[list[BUNode]]          # levels[0] = leaves ... levels[-1] = [root]
    keys: np.ndarray                    # the full sorted key array

    @property
    def height(self) -> int:
        return len(self.levels)


# ---------------------------------------------------------------------------
# Greedy merging (Algorithm 3)
# ---------------------------------------------------------------------------


def _piece_cost(stats: SegStats, xs: np.ndarray, ys: np.ndarray,
                ws: np.ndarray, cm: CostModel) -> float:
    """Sum over keys in the piece of t_E-style local-search cost (weighted).

    t_E ~ 2*log2(eps) * (mu_E + theta_E); eps clamped to >= 1 so a perfect
    model contributes 0.
    """
    a, b = stats.fit()
    err = np.abs(a + b * xs - ys)
    log2e = np.log2(np.maximum(err, 1.0))
    return float((ws * cm.t_exp_search(log2e)).sum())


def greedy_merging(
    x: np.ndarray,
    weights: np.ndarray | None,
    n_total_keys: int,
    cm: CostModel = DEFAULT_COST,
    sample_stride: int = 1,
) -> tuple[int, np.ndarray, list[tuple[int, int, float, float]]]:
    """Algorithm 3: find the best piece count n_h and break points X_h.

    Parameters
    ----------
    x: sorted inputs at this level (all keys for h=0, node lower bounds above).
    weights: #underlying keys per element (None -> 1 each).
    n_total_keys: N, for averaging the accumulated cost.
    sample_stride: appendix A.7 sampling -- evaluate piece costs on every
        `sample_stride`-th element of large pieces.

    Returns (n_h, break_points, pieces) where pieces is a list of
    (lo, hi, a, b) covering [lo, hi) of `x` with the fitted model.
    """
    n = len(x)
    if n <= 2:
        a, b = least_squares(x, np.arange(n, dtype=np.float64))
        return 1, np.array([x[0]]), [(0, n, a, b)]
    x = np.asarray(x, np.float64)
    y = np.arange(n, dtype=np.float64)
    w = np.ones(n) if weights is None else np.asarray(weights, np.float64)

    # --- initial pieces of 2 (last may take 3) -----------------------------
    k0 = n // 2
    starts = list(range(0, 2 * k0, 2))
    ends = [s + 2 for s in starts]
    ends[-1] = n
    pieces: list[list[int]] = [[s, e] for s, e in zip(starts, ends)]

    def seg(i: int) -> SegStats:
        s, e = pieces[i]
        sl = slice(s, e, sample_stride if (e - s) > 8 else 1)
        return SegStats.of(x[sl], y[sl], w[sl])

    stats = [seg(i) for i in range(len(pieces))]
    # s_i = loss of piece i (Alg.3 line 5); local-search cost contribution c_i
    s_loss = [st.sse() for st in stats]

    def contrib(i: int) -> float:
        s, e = pieces[i]
        sl = slice(s, e, sample_stride if (e - s) > 8 else 1)
        sub = w[sl].sum()
        c = _piece_cost(stats[i], x[sl], y[sl], w[sl], cm)
        # rescale sampled cost to the full piece weight
        full = w[s:e].sum()
        return c * (full / max(sub, 1e-12))

    c_contrib = [contrib(i) for i in range(len(pieces))]
    total_te = float(sum(c_contrib))

    # merge candidate heap: (delta_loss, version, left_index)
    alive = [True] * len(pieces)
    right = {i: i + 1 for i in range(len(pieces) - 1)}   # neighbor links
    left = {i + 1: i for i in range(len(pieces) - 1)}
    version = [0] * len(pieces)

    heap: list[tuple[float, int, int]] = []

    max_piece = 2 * cm.omega

    def push(i: int) -> None:
        j = right.get(i)
        if j is None:
            return
        si, sj = pieces[i], pieces[j]
        if (sj[1] - si[0]) > max_piece:      # cap piece size (Alg.3 remark)
            return
        m = stats[i].merge(stats[j]).sse()
        d = m - s_loss[i] - s_loss[j]
        heapq.heappush(heap, (d, version[i], i))

    for i in range(len(pieces)):
        push(i)

    k = len(pieces)
    k_min = max(1, int(math.ceil(n / cm.omega)))

    theta = cm.theta_n + cm.eta_lin   # per-level constant of T_ns (Eq. 5)

    def eval_eps(k_now: int) -> float:
        """T_ea(B_k, X) (Eq. 7) with the same-fanout assumption."""
        if k_now <= 1:
            depth = 1.0
        else:
            ratio = n / k_now           # avg fanout below this level
            if ratio <= 1.0 + 1e-9:
                depth = 1.0
            else:
                depth = math.log(n, ratio) if n > 1 else 1.0
        depth = max(depth, 1.0)
        # sum_{h'=0..ceil(depth)} min(1, depth+1-h') * (theta + rho^h' * tE_avg)
        te_avg = total_te / max(n_total_keys, 1)
        acc = 0.0
        hmax = int(math.ceil(depth))
        for hp in range(0, hmax + 1):
            f = min(1.0, depth + 1.0 - hp)
            acc += f * (theta + (cm.rho ** hp) * te_avg)
        return acc

    best = (eval_eps(k), k)
    snapshots: dict[int, float] = {k: best[0]}

    while k > k_min and heap:
        d, ver, i = heapq.heappop(heap)
        if not alive[i] or version[i] != ver or right.get(i) is None:
            continue
        j = right[i]
        if not alive[j]:
            continue
        # ---- merge j into i -------------------------------------------------
        total_te -= c_contrib[i] + c_contrib[j]
        pieces[i] = [pieces[i][0], pieces[j][1]]
        stats[i] = seg(i)
        s_loss[i] = stats[i].sse()
        c_contrib[i] = contrib(i)
        total_te += c_contrib[i]
        alive[j] = False
        version[i] += 1
        rj = right.pop(j, None)
        if rj is not None:
            right[i] = rj
            left[rj] = i
        else:
            right.pop(i, None)
        li = left.get(i)
        if li is not None:
            version[li] += 1
            push(li)
        push(i)
        k -= 1
        eps = eval_eps(k)
        snapshots[k] = eps
        if eps < best[0]:
            best = (eps, k)

    # rebuild the best partition: we kept only the final pieces, so rerun the
    # deterministic merge to the recorded best k if it differs from final k.
    target_k = best[1]
    if target_k != k:
        return _greedy_to_k(x, y, w, target_k, cm, sample_stride, n_total_keys)

    out_pieces = []
    i = 0
    order = [idx for idx in range(len(alive)) if alive[idx]]
    order.sort(key=lambda idx: pieces[idx][0])
    bps = []
    for idx in order:
        s, e = pieces[idx]
        a, b = stats[idx].fit()
        out_pieces.append((s, e, a, b))
        bps.append(x[s])
    return len(out_pieces), np.asarray(bps), out_pieces


def _greedy_to_k(x, y, w, target_k, cm, sample_stride, n_total_keys):
    """Re-run the merge deterministically down to exactly target_k pieces."""
    n = len(x)
    k0 = n // 2
    starts = list(range(0, 2 * k0, 2))
    ends = [s + 2 for s in starts]
    ends[-1] = n
    pieces = [[s, e] for s, e in zip(starts, ends)]

    def seg_of(s, e):
        sl = slice(s, e, sample_stride if (e - s) > 8 else 1)
        return SegStats.of(x[sl], y[sl], w[sl])

    stats = [seg_of(s, e) for s, e in pieces]
    s_loss = [st.sse() for st in stats]
    alive = [True] * len(pieces)
    right = {i: i + 1 for i in range(len(pieces) - 1)}
    left = {i + 1: i for i in range(len(pieces) - 1)}
    version = [0] * len(pieces)
    heap = []
    max_piece = 2 * cm.omega

    def push(i):
        j = right.get(i)
        if j is None:
            return
        if (pieces[j][1] - pieces[i][0]) > max_piece:
            return
        m = stats[i].merge(stats[j]).sse()
        heapq.heappush(heap, (m - s_loss[i] - s_loss[j], version[i], i))

    for i in range(len(pieces)):
        push(i)
    k = len(pieces)
    while k > target_k and heap:
        d, ver, i = heapq.heappop(heap)
        if not alive[i] or version[i] != ver or right.get(i) is None:
            continue
        j = right[i]
        if not alive[j]:
            continue
        pieces[i] = [pieces[i][0], pieces[j][1]]
        stats[i] = seg_of(*pieces[i])
        s_loss[i] = stats[i].sse()
        alive[j] = False
        version[i] += 1
        rj = right.pop(j, None)
        if rj is not None:
            right[i] = rj
            left[rj] = i
        else:
            right.pop(i, None)
        li = left.get(i)
        if li is not None:
            version[li] += 1
            push(li)
        push(i)
        k -= 1
    order = [idx for idx in range(len(alive)) if alive[idx]]
    order.sort(key=lambda idx: pieces[idx][0])
    out, bps = [], []
    for idx in order:
        s, e = pieces[idx]
        a, b = stats[idx].fit()
        out.append((s, e, a, b))
        bps.append(x[s])
    return len(out), np.asarray(bps), out


# ---------------------------------------------------------------------------
# BuildBUTree (Algorithm 2)
# ---------------------------------------------------------------------------


def build_bu_tree(keys: np.ndarray, cm: CostModel = DEFAULT_COST,
                  sample_stride: int = 1, max_height: int = 12) -> BUTree:
    keys = np.asarray(keys, np.float64)
    n_total = len(keys)
    assert n_total >= 2, "need at least 2 keys"
    assert bool(np.all(np.diff(keys) > 0)), "keys must be sorted and unique"

    # --- leaves (h = 0) ------------------------------------------------------
    n0, bps0, pieces0 = greedy_merging(keys, None, n_total, cm, sample_stride)
    key_sup = float(keys[-1]) + max(1.0, abs(float(keys[-1])) * 1e-9)
    leaves: list[BUNode] = []
    for idx, (lo, hi, a, b) in enumerate(pieces0):
        lb = float(keys[lo])
        ub = float(keys[hi]) if hi < n_total else key_sup
        # leaf model maps keys -> local indices (Eq. 3: F(x) - l)
        leaves.append(BUNode(lb=lb, ub=ub, a=a - lo, b=b, height=0, lo=lo, hi=hi))
    # stretch first leaf's lb down to the true range start
    leaves[0].lb = float(keys[0])

    levels = [leaves]
    weights = np.array([lf.hi - lf.lo for lf in leaves], np.float64)

    h = 0
    while len(levels[-1]) > 1 and h < max_height:
        cur = levels[-1]
        xs = np.array([nd.lb for nd in cur], np.float64)
        n_cur = len(cur)

        # Option A: immediate root over the current level (generateRoot)
        a_r, b_r = least_squares(xs, np.arange(n_cur, dtype=np.float64))
        pred = a_r + b_r * xs
        err = np.abs(pred - np.arange(n_cur))
        te = float((weights * (cm.rho ** (h + 1))
                    * cm.t_exp_search(np.log2(np.maximum(err, 1.0)))).sum())
        eps_root = (cm.theta_n + cm.eta_lin) + te / n_total

        if n_cur <= 2:
            eps_grow = math.inf
            merged = None
        else:
            # Option B: grow one more level via greedy merging
            n_h, bps, pieces = greedy_merging(xs, weights, n_total, cm, sample_stride)
            merged = (n_h, bps, pieces)
            # cost of this extra level per key + estimated remaining depth
            ratio = max(n_cur / max(n_h, 1), 1.0 + 1e-9)
            depth_above = max(math.log(max(n_h, 2), ratio), 1.0)
            eps_grow = (depth_above + 1.0) * (cm.theta_n + cm.eta_lin)
            if n_h >= n_cur:          # merging made no progress -> must root
                eps_grow = math.inf

        if eps_root <= eps_grow or merged is None or merged[0] <= 1:
            root = BUNode(lb=float(levels[0][0].lb), ub=float(levels[0][-1].ub),
                          a=a_r, b=b_r, height=h + 1,
                          children=list(cur),
                          boundaries=xs.copy())
            levels.append([root])
            return BUTree(root=root, levels=levels, keys=keys)

        n_h, bps, pieces = merged
        nxt: list[BUNode] = []
        new_w = []
        for (lo, hi, a, b) in pieces:
            lb = float(xs[lo])
            ub = float(xs[hi]) if hi < n_cur else float(levels[0][-1].ub)
            node = BUNode(lb=lb, ub=ub, a=a - lo, b=b, height=h + 1,
                          children=cur[lo:hi],
                          boundaries=xs[lo:hi].copy())
            nxt.append(node)
            new_w.append(float(weights[lo:hi].sum()))
        nxt[0].lb = float(levels[0][0].lb)
        levels.append(nxt)
        weights = np.asarray(new_w)
        h += 1

    if len(levels[-1]) > 1:   # max height reached: force a root
        cur = levels[-1]
        xs = np.array([nd.lb for nd in cur], np.float64)
        a_r, b_r = least_squares(xs, np.arange(len(cur), dtype=np.float64))
        root = BUNode(lb=float(levels[0][0].lb), ub=float(levels[0][-1].ub),
                      a=a_r, b=b_r, height=len(levels), children=list(cur),
                      boundaries=xs.copy())
        levels.append([root])
    return BUTree(root=levels[-1][0], levels=levels, keys=keys)


# ---------------------------------------------------------------------------
# Reference search in the BU-Tree (used by Table 9 benchmark)
# ---------------------------------------------------------------------------


def bu_search(tree: BUTree, pairs_keys: np.ndarray, x: float) -> tuple[int, int, int]:
    """Search key x.  Returns (position or -1, nodes_visited, probe_steps)."""
    node = tree.root
    nodes = 0
    probes = 0
    while not node.is_leaf:
        nodes += 1
        b = node.boundaries
        j = int(np.clip(math.floor(node.a + node.b * x), 0, len(b) - 1))
        # local search in boundary array from predicted j (binary fallback)
        i = int(np.searchsorted(b, x, side="right") - 1)
        probes += int(np.ceil(np.log2(max(abs(i - j), 1) + 1)))
        i = max(i, 0)
        node = node.children[i]
    nodes += 1
    lo, hi = node.lo, node.hi
    j = int(np.clip(math.floor(node.a + node.b * x), lo, hi - 1))
    i = int(np.searchsorted(pairs_keys[lo:hi], x)) + lo
    probes += int(np.ceil(np.log2(max(abs(i - j), 1) + 1)))
    if i < hi and pairs_keys[i] == x:
        return i, nodes, probes
    return -1, nodes, probes
