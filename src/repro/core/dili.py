"""Host-side DILI structure: bulk loading (Alg. 4), local optimization (Alg. 5),
search (Alg. 1 & 6), insertion (Alg. 7), deletion (Alg. 8).

This is the *writer* side of the writer/reader split (DESIGN.md section 2): a
faithful, mutable implementation of the paper's algorithms.  `flat.py`
publishes immutable device snapshots for the batched JAX/Pallas reader path.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from .bu_tree import BUTree, CostModel, DEFAULT_COST, build_bu_tree, least_squares

# Enlarging ratio eta (Alg. 5 line 2); adjustment threshold lambda (Alg. 7);
# phi(alpha) = min(eta + 0.1 * alpha, 4) (section 6.1).
ETA = 2.0
LAMBDA = 2.0


def phi(alpha: int, eta: float = ETA) -> float:
    return min(eta + 0.1 * alpha, 4.0)


# ULP safety margin for slot predictions.  XLA/Mosaic may contract a + b*x
# into an FMA whose single rounding differs from numpy's mul-then-add when the
# exact value sits on an integer boundary — the *slot assignment* would then
# differ between construction (host) and search (device).  We therefore nudge
# every model's intercept until each covered key's prediction is at least
# SAFE_ULPS ulps away from an integer, making floor() invariant to any
# evaluation order with <= a-few-ulp error.  See DESIGN.md section 7.
SAFE_ULPS = 32.0

# Placement dtype: the arithmetic precision in which slot predictions are
# evaluated (host construction AND device search must match).  float64 for the
# pure-JAX x64 path; float32 for the Pallas TPU kernel path (TPU has no f64) —
# set via `placement_dtype(np.float32)` around bulk_load.
PLACE_DTYPE = np.float64


class placement_dtype:
    def __init__(self, dtype):
        self.dtype = np.dtype(dtype).type

    def __enter__(self):
        global PLACE_DTYPE
        self._old = PLACE_DTYPE
        PLACE_DTYPE = self.dtype
        return self

    def __exit__(self, *exc):
        global PLACE_DTYPE
        PLACE_DTYPE = self._old


def nudge_boundary_safe(a: float, b: float,
                        xs: np.ndarray) -> tuple[float, bool]:
    """Return (a', ok) with a' close to a such that floor(a' + b*xs) is
    robust to any <=few-ulp evaluation-order difference (FMA contraction).

    The error scale of evaluating a + b*x is ulp(max(|a|, |b*x|)) — NOT
    ulp(y): when a ~ -b*x the sum cancels and y is tiny while the roundoff
    stays at product magnitude.  A good least-squares leaf fit maps keys to
    near-exact integers *by design*, so without this nudge boundary hits are
    systematic, not rare.
    """
    if len(xs) == 0 or b == 0.0:
        return a, True
    dt = PLACE_DTYPE
    a = float(dt(a))
    bq = dt(b)
    xq = np.asarray(xs, dt)
    p = bq * xq
    scale = np.maximum(np.maximum(np.abs(p), abs(a)), dt(1.0)).astype(dt)
    ulp = np.spacing(scale)
    if float(ulp.max()) * SAFE_ULPS >= 0.125:
        return a, False          # slots unresolvable at this precision
    for _ in range(40):
        y = dt(a) + p
        d = np.abs(y - np.rint(y))
        bad = d <= SAFE_ULPS * ulp
        if not bad.any():
            return a, True
        a = float(dt(a + 4.0 * SAFE_ULPS * float(ulp[bad].max())))
    return a, False


def predict_np(a: float, b: float, xs: np.ndarray) -> np.ndarray:
    """Host-side slot prediction: mul-then-add, floor, in PLACE_DTYPE —
    the canonical layout arithmetic that device search must reproduce."""
    dt = PLACE_DTYPE
    return np.floor(dt(a) + dt(b) * np.asarray(xs, dt)).astype(np.float64)


def _ulp_safe(a: float, b: float, x: float) -> bool:
    dt = PLACE_DTYPE
    p = dt(b) * dt(x)
    y = dt(a) + p
    scale = dt(max(abs(float(p)), abs(a), 1.0))
    return abs(float(y) - round(float(y))) > SAFE_ULPS * float(np.spacing(scale))


# ---------------------------------------------------------------------------
# Nodes
# ---------------------------------------------------------------------------


@dataclass
class Internal:
    lb: float
    ub: float
    a: float
    b: float
    children: list = field(default_factory=list)

    @property
    def fanout(self) -> int:
        return len(self.children)

    def child_index(self, x: float) -> int:
        dt = PLACE_DTYPE
        y = math.floor(float(dt(self.a) + dt(self.b) * dt(x)))
        return int(np.clip(y, 0, self.fanout - 1))


@dataclass
class Leaf:
    lb: float
    ub: float
    a: float = 0.0
    b: float = 0.0
    fo: int = 0
    slots: list = field(default_factory=list)   # None | (key, val) | Leaf
    omega: int = 0      # Omega: #pairs covered
    delta: int = 0      # Delta: total probe count to reach every pair
    kappa: float = 1.0  # avg probes/pair at last local optimization
    alpha: int = 0      # #adjustments so far
    dense: bool = False  # DILI-LO variant: tightly packed pairs, no local opt

    def predict(self, x: float) -> int:
        dt = PLACE_DTYPE
        y = math.floor(float(dt(self.a) + dt(self.b) * dt(x)))
        return int(np.clip(y, 0, max(self.fo - 1, 0)))


Node = Internal | Leaf


# ---------------------------------------------------------------------------
# Local optimization (Algorithm 5)
# ---------------------------------------------------------------------------


def local_opt(leaf: Leaf, pairs: list[tuple[float, int]], eta: float = ETA,
              fo: int | None = None, depth: int = 0) -> None:
    """LOCALOPT(N_D, P_D): place pairs at predicted slots; conflicts spawn
    child leaves.  `leaf.a/b` must already map keys -> [0, len(pairs)); we
    scale by eta here (consistent with Alg. 7 line 24)."""
    m = len(pairs)
    leaf.omega = m
    leaf.delta = 0
    if m == 0:
        leaf.fo = 1
        leaf.slots = [None]
        leaf.kappa = 1.0
        return
    if fo is None:
        fo = max(int(math.ceil(eta * m)), 1)
        leaf.a *= (fo / m)
        leaf.b *= (fo / m)
    leaf.fo = fo
    leaf.dense = False

    keys = np.array([p[0] for p in pairs], np.float64)
    leaf.b = float(PLACE_DTYPE(leaf.b))
    leaf.a, ok = nudge_boundary_safe(leaf.a, leaf.b, keys)
    if not ok:
        # slots unresolvable at f64 precision: fall back to a dense leaf
        # (comparison-based search needs no floor consistency)
        dense = make_dense_leaf(leaf.lb, leaf.ub, sorted(pairs))
        leaf.__dict__.update(dense.__dict__)
        return
    pos = np.clip(predict_np(leaf.a, leaf.b, keys).astype(np.int64), 0, fo - 1)
    slots: list = [None] * fo
    order = np.argsort(pos, kind="stable")
    i = 0
    n = m
    while i < n:
        j = i
        t = pos[order[i]]
        while j < n and pos[order[j]] == t:
            j += 1
        group = [pairs[order[g]] for g in range(i, j)]
        if len(group) == 1:
            slots[t] = group[0]
            leaf.delta += 1
        else:
            child = _make_conflict_leaf(group, eta, depth + 1)
            slots[t] = child
            leaf.delta += len(group) + child.delta
        i = j
    leaf.slots = slots
    leaf.kappa = leaf.delta / max(leaf.omega, 1)


def _make_conflict_leaf(group: list[tuple[float, int]], eta: float,
                        depth: int) -> Leaf:
    ks = np.array([p[0] for p in group], np.float64)
    lb, ub = float(ks[0]), float(ks[-1])
    child = Leaf(lb=lb, ub=ub)
    # Cap conflict-chain depth: beyond it (or for unseparable clusters where
    # a+b*x can no longer resolve slots in f64) fall back to a tiny dense leaf
    # — bounds tree height like the paper's adjustment strategy does.
    span = ks[-1] - ks[0]
    if depth > 8 or span <= 0 or not np.isfinite(span) or \
            span <= abs(ks[0]) * 1e-13 * len(group):
        # degenerate cluster: fall back to a dense leaf with exact slots
        child.a, child.b = 0.0, 0.0
        child.fo = len(group)
        child.slots = list(group)
        child.omega = len(group)
        child.delta = len(group)
        child.kappa = 1.0
        child.dense = True
        return child
    a, b = least_squares(ks, np.arange(len(group), dtype=np.float64))
    child.a, child.b = a, b
    local_opt(child, group, eta, depth=depth)
    return child


def make_dense_leaf(lb: float, ub: float, pairs: list[tuple[float, int]]) -> Leaf:
    """DILI-LO variant leaf: tightly packed array + model (Alg. 1 search)."""
    leaf = Leaf(lb=lb, ub=ub, dense=True)
    m = len(pairs)
    leaf.omega = m
    leaf.fo = max(m, 1)
    leaf.slots = list(pairs) if m else [None]
    if m >= 2:
        ks = np.array([p[0] for p in pairs], np.float64)
        leaf.a, leaf.b = least_squares(ks, np.arange(m, dtype=np.float64))
    leaf.delta = m
    leaf.kappa = 1.0
    return leaf


# ---------------------------------------------------------------------------
# DILI tree
# ---------------------------------------------------------------------------


@dataclass
class DILI:
    root: Node
    n_keys: int
    cm: CostModel
    eta: float = ETA
    lam: float = LAMBDA
    local_optimized: bool = True
    sample_stride: int = 1     # retained so subtree rebuilds match the build
    # statistics
    n_conflicts: int = 0
    n_adjustments: int = 0
    # ids of leaves located by mutation entry points since the last
    # `take_dirty()` — the dirty plumbing of the incremental flattener
    # (repro.maintain.flattener); cheap enough to keep always-on
    dirty_ids: set = field(default_factory=set, repr=False)

    # -- search ------------------------------------------------------------

    def locate_leaf(self, x: float) -> tuple[Leaf, int]:
        node = self.root
        depth = 1
        while isinstance(node, Internal):
            node = node.children[node.child_index(x)]
            depth += 1
        return node, depth

    def search(self, x: float) -> int | None:
        """Algorithm 6 (Algorithm 1 for dense leaves). Returns payload or None."""
        node, _ = self.locate_leaf(x)
        while True:
            if node.dense:
                return _dense_leaf_search(node, x)
            pos = node.predict(x)
            p = node.slots[pos] if node.fo else None
            if isinstance(p, Leaf):
                node = p
            elif p is not None and p[0] == x:
                return p[1]
            else:
                return None

    def search_stats(self, x: float) -> tuple[int | None, int, int]:
        """Search returning (payload, nodes_visited, entry_probes)."""
        node = self.root
        nodes = 1
        while isinstance(node, Internal):
            node = node.children[node.child_index(x)]
            nodes += 1
        probes = 0
        while True:
            if node.dense:
                v, pr = _dense_leaf_search_stats(node, x)
                return v, nodes, probes + pr
            pos = node.predict(x)
            p = node.slots[pos] if node.fo else None
            probes += 1
            if isinstance(p, Leaf):
                node = p
                nodes += 1
            elif p is not None and p[0] == x:
                return p[1], nodes, probes
            else:
                return None, nodes, probes

    def range_query(self, lo: float, hi: float) -> list[tuple[float, int]]:
        """Scan pairs with lo <= key < hi (section 7.2, Fig. 6b)."""
        out: list[tuple[float, int]] = []
        _range_collect(self.root, lo, hi, out)
        out.sort()
        return out

    # -- updates -------------------------------------------------------------

    def take_dirty(self) -> set:
        """Drain the dirty-leaf id set (mutations since the last call)."""
        d, self.dirty_ids = self.dirty_ids, set()
        return d

    def insert(self, key: float, val: int) -> bool:
        """Algorithm 7. Returns True if the key was newly inserted."""
        leaf, _ = self.locate_leaf(key)
        self.dirty_ids.add(id(leaf))
        return self._insert_to_leaf(leaf, key, val)

    def _insert_to_leaf(self, leaf: Leaf, key: float, val: int) -> bool:
        if leaf.dense:
            # returns False on a duplicate so upsert() knows to _set_payload
            return _dense_leaf_insert(leaf, key, val)
        pos = leaf.predict(key)
        p = leaf.slots[pos]
        not_exist = True
        if p is None:
            if _ulp_safe(leaf.a, leaf.b, key):
                leaf.slots[pos] = (key, val)
                leaf.delta += 1
            else:
                # the new key's prediction sits on an integer boundary: wrap it
                # in a single-pair child leaf so device-side FMA evaluation
                # cannot land it in the wrong slot (DESIGN.md section 7)
                child = Leaf(lb=key, ub=key, a=0.0, b=0.0, fo=1,
                             slots=[(key, val)], omega=1, delta=1, kappa=1.0)
                leaf.slots[pos] = child
                leaf.delta += 2
        elif isinstance(p, Leaf):
            d0 = p.delta
            not_exist = self._insert_to_leaf(p, key, val)
            leaf.delta += 1 + p.delta - d0
        elif p[0] == key:
            not_exist = False
        else:  # conflict: new leaf covering p and (key, val) (lines 15-18)
            self.n_conflicts += 1
            group = sorted([p, (key, val)])
            child = Leaf(lb=group[0][0], ub=group[1][0])
            ks = np.array([g[0] for g in group])
            child.a, child.b = least_squares(ks, np.arange(2, dtype=np.float64))
            local_opt(child, group, self.eta)   # sets omega=2, delta (>=2)
            leaf.slots[pos] = child
            leaf.delta += 1 + child.delta
        if not_exist:
            leaf.omega += 1
            self.n_keys += 1
        # -- node adjustment (lines 20-26) ----------------------------------
        if not_exist and leaf.omega > 0 and \
                leaf.delta / leaf.omega > self.lam * leaf.kappa:
            self.adjust_leaf(leaf)
        return not_exist

    def upsert(self, key: float, val: int) -> bool:
        """Insert (Alg. 7) or, when the key already exists, replace its
        payload in place.  Returns True if the key was newly inserted."""
        if self.insert(key, val):
            return True
        self._set_payload(key, val)
        return False

    def _set_payload(self, x: float, val: int) -> bool:
        node, _ = self.locate_leaf(x)
        self.dirty_ids.add(id(node))
        return self._set_payload_at(node, x, val)

    def _set_payload_at(self, node: Leaf, x: float, val: int) -> bool:
        """Replace x's payload within an already-located leaf subtree
        (callers that located the leaf themselves skip the second walk)."""
        while True:
            if node.dense:
                for i, s in enumerate(node.slots[: node.omega]):
                    if s is not None and s[0] == x:
                        node.slots[i] = (x, val)
                        return True
                return False
            pos = node.predict(x)
            p = node.slots[pos] if node.fo else None
            if isinstance(p, Leaf):
                node = p
            elif p is not None and p[0] == x:
                node.slots[pos] = (x, val)
                return True
            else:
                return False

    def adjust_leaf(self, leaf: Leaf) -> None:
        self.n_adjustments += 1
        pairs = collect_pairs(leaf)
        r = phi(leaf.alpha, self.eta)
        leaf.alpha += 1
        m = len(pairs)
        ks = np.array([p[0] for p in pairs], np.float64)
        a, b = least_squares(ks, np.arange(m, dtype=np.float64))
        leaf.a, leaf.b = a * r, b * r          # Alg. 7 line 24
        fo = max(int(math.ceil(m * r)), 1)
        local_opt(leaf, pairs, self.eta, fo=fo)
        leaf.kappa = leaf.delta / max(leaf.omega, 1)

    def delete(self, key: float) -> bool:
        """Algorithm 8. Returns True if the key existed."""
        leaf, _ = self.locate_leaf(key)
        self.dirty_ids.add(id(leaf))
        return self._delete_from_leaf(leaf, key)

    def _delete_from_leaf(self, leaf: Leaf, key: float) -> bool:
        if leaf.dense:
            return _dense_leaf_delete(leaf, key)
        pos = leaf.predict(key)
        p = leaf.slots[pos]
        exist = True
        if p is None:
            return False
        if isinstance(p, Leaf):
            d0 = p.delta
            exist = self._delete_from_leaf(p, key)
            leaf.delta -= 1 + d0 - p.delta
            if exist and p.omega == 1:       # trim single-pair leaf (lines 13-15)
                rem = collect_pairs(p)
                if rem and not _ulp_safe(leaf.a, leaf.b, rem[0][0]):
                    pass                     # keep the wrapper: unsafe boundary
                else:
                    leaf.slots[pos] = rem[0] if rem else None
                    leaf.delta -= 1
        elif p[0] == key:
            leaf.slots[pos] = None
            leaf.delta -= 1
        else:
            return False
        if exist:
            leaf.omega -= 1
            self.n_keys -= 1
            leaf.kappa = leaf.delta / max(leaf.omega, 1)
        return exist

    # -- introspection -------------------------------------------------------

    def stats(self) -> dict:
        depths: list[int] = []
        n_internal = n_leaf = n_slots = n_pairs = 0
        stack: list[tuple[Node, int]] = [(self.root, 1)]
        while stack:
            node, d = stack.pop()
            if isinstance(node, Internal):
                n_internal += 1
                for c in node.children:
                    stack.append((c, d + 1))
            else:
                n_leaf += 1
                n_slots += node.fo
                for s in node.slots:
                    if isinstance(s, Leaf):
                        stack.append((s, d + 1))
                    elif s is not None:
                        n_pairs += 1
                        depths.append(d)
        depths_a = np.asarray(depths if depths else [1])
        return dict(
            n_internal=n_internal, n_leaf=n_leaf, n_slots=n_slots,
            n_pairs=n_pairs, min_height=int(depths_a.min()),
            max_height=int(depths_a.max()), avg_height=float(depths_a.mean()),
            conflicts=self.n_conflicts, adjustments=self.n_adjustments,
            memory_bytes=self.memory_bytes(n_internal, n_leaf, n_slots),
        )

    @staticmethod
    def memory_bytes(n_internal: int, n_leaf: int, n_slots: int) -> int:
        # flat-snapshot accounting: node row = a,b (f64) + base,fo (i32) + tag
        node_row = 8 + 8 + 4 + 4 + 1
        slot_row = 8 + 8 + 1          # key f64 + val i64 + tag
        return (n_internal + n_leaf) * node_row + n_slots * slot_row


# ---------------------------------------------------------------------------
# dense-leaf (DILI-LO) helpers: model + exponential search (Algorithm 1)
# ---------------------------------------------------------------------------


def _dense_keys(leaf: Leaf) -> np.ndarray:
    return np.array([s[0] for s in leaf.slots if s is not None], np.float64)


def _dense_leaf_search(leaf: Leaf, x: float):
    v, _ = _dense_leaf_search_stats(leaf, x)
    return v


def _dense_leaf_search_stats(leaf: Leaf, x: float):
    m = leaf.omega
    if m == 0:
        return None, 0
    pred = int(np.clip(math.floor(leaf.a + leaf.b * x), 0, m - 1))
    # exponential search outward from pred (2*log2(err) probes, Eq. 2)
    keys = [s[0] for s in leaf.slots[:m]]
    lo, hi, probes = pred, pred, 1
    step = 1
    if keys[pred] < x:
        while hi < m - 1 and keys[min(hi + step, m - 1)] < x:
            hi = min(hi + step, m - 1)
            step *= 2
            probes += 1
        lo, hi = hi, min(hi + step, m - 1)
    elif keys[pred] > x:
        while lo > 0 and keys[max(lo - step, 0)] > x:
            lo = max(lo - step, 0)
            step *= 2
            probes += 1
        lo, hi = max(lo - step, 0), lo
    while lo < hi:
        mid = (lo + hi) // 2
        probes += 1
        if keys[mid] < x:
            lo = mid + 1
        else:
            hi = mid
    if keys[lo] == x:
        return leaf.slots[lo][1], probes
    return None, probes


def _dense_leaf_insert(leaf: Leaf, key: float, val: int) -> bool:
    """B+Tree-style shifted insert (what DILI *avoids*; kept for DILI-LO).
    Returns True iff the key was newly inserted."""
    pairs = [s for s in leaf.slots[:leaf.omega] if s is not None]
    import bisect
    i = bisect.bisect_left([p[0] for p in pairs], key)
    if i < len(pairs) and pairs[i][0] == key:
        return False
    pairs.insert(i, (key, val))
    leaf.slots = pairs
    leaf.omega = len(pairs)
    leaf.fo = len(pairs)
    ks = np.array([p[0] for p in pairs], np.float64)
    if len(pairs) >= 2:
        leaf.a, leaf.b = least_squares(ks, np.arange(len(pairs), dtype=np.float64))
    return True


def _dense_leaf_delete(leaf: Leaf, key: float) -> bool:
    pairs = [s for s in leaf.slots[:leaf.omega] if s is not None]
    ks = [p[0] for p in pairs]
    import bisect
    i = bisect.bisect_left(ks, key)
    if i >= len(pairs) or pairs[i][0] != key:
        return False
    pairs.pop(i)
    leaf.slots = pairs if pairs else [None]
    leaf.omega = len(pairs)
    leaf.fo = max(len(pairs), 1)
    return True


def collect_pairs(leaf: Leaf) -> list[tuple[float, int]]:
    out: list[tuple[float, int]] = []
    stack = [leaf]
    while stack:
        nd = stack.pop()
        for s in nd.slots:
            if isinstance(s, Leaf):
                stack.append(s)
            elif s is not None:
                out.append(s)
    out.sort()
    return out


def _range_collect(node: Node, lo: float, hi: float, out: list) -> None:
    if isinstance(node, Internal):
        i0 = node.child_index(lo)
        i1 = node.child_index(min(hi, node.ub - 1e-300))
        for i in range(i0, min(i1 + 1, node.fanout)):
            _range_collect(node.children[i], lo, hi, out)
    else:
        for s in node.slots:
            if isinstance(s, Leaf):
                if s.ub >= lo and s.lb <= hi:
                    _range_collect(s, lo, hi, out)
            elif s is not None and lo <= s[0] < hi:
                out.append(s)


# ---------------------------------------------------------------------------
# Bulk loading (Algorithm 4)
# ---------------------------------------------------------------------------


def bulk_load(keys: np.ndarray, vals: np.ndarray | None = None,
              cm: CostModel = DEFAULT_COST, eta: float = ETA,
              lam: float = LAMBDA, local_optimized: bool = True,
              sample_stride: int = 1,
              bu: BUTree | None = None) -> DILI:
    """BulkLoading(P): build the BU-Tree, then grow DILI top-down copying the
    BU-Tree's per-level node counts with equal-width children (Alg. 4)."""
    keys = np.asarray(keys, np.float64)
    n = len(keys)
    if vals is None:
        vals = np.arange(n, dtype=np.int64)
    if bu is None:
        bu = build_bu_tree(keys, cm, sample_stride)

    # theta^i = lower bounds of BU nodes at height i (Alg. 4 lines 4-5)
    thetas = [np.array([nd.lb for nd in level], np.float64)
              for level in bu.levels[:-1]]   # exclude root level
    height = len(bu.levels)                  # leaf level .. root level

    root_lb = float(bu.root.lb)
    root_ub = float(bu.root.ub)

    dili = DILI(root=None, n_keys=n, cm=cm, eta=eta, lam=lam,  # type: ignore
                local_optimized=local_optimized,
                sample_stride=sample_stride)

    def create_leaf(lb: float, ub: float, lo: int, hi: int) -> Leaf:
        pd = [(float(keys[i]), int(vals[i])) for i in range(lo, hi)]
        if not local_optimized:
            return make_dense_leaf(lb, ub, pd)
        leaf = Leaf(lb=lb, ub=ub)
        m = len(pd)
        if m >= 2:
            a, b = least_squares(keys[lo:hi], np.arange(m, dtype=np.float64))
            leaf.a, leaf.b = a, b
        elif m == 1:
            leaf.a, leaf.b = 0.0, 0.0
        before = _count_conflicts_estimate(leaf, pd, eta)
        dili.n_conflicts += before
        local_opt(leaf, pd, eta)
        return leaf

    def create_internal(lb: float, ub: float, h: int, lo: int, hi: int) -> Node:
        theta = thetas[h - 1]
        fo = int(np.searchsorted(theta, ub, side="left")
                 - np.searchsorted(theta, lb, side="left"))
        fo = max(fo, 1)
        if fo == 1 and h == 1:
            # degenerate internal with a single leaf child: collapse one level
            return create_leaf(lb, ub, lo, hi)
        node = Internal(lb=lb, ub=ub, a=0.0, b=0.0)
        node.b = float(PLACE_DTYPE(fo / (ub - lb)))   # Eq. 1
        node.a = -node.b * lb
        # Partition the covered keys BY the (nudged) floor function itself so
        # construction and any-device search agree on child assignment.
        node.a, _ = nudge_boundary_safe(node.a, node.b, keys[lo:hi])
        pos = np.clip(predict_np(node.a, node.b, keys[lo:hi]).astype(np.int64),
                      0, fo - 1)
        starts = lo + np.searchsorted(pos, np.arange(fo), side="left")
        ends = lo + np.searchsorted(pos, np.arange(fo), side="right")
        for i in range(fo):
            l = lb + i * (ub - lb) / fo
            u = lb + (i + 1) * (ub - lb) / fo
            clo, chi = int(starts[i]), int(ends[i])
            if h == 1:
                node.children.append(create_leaf(l, u, clo, chi))
            else:
                node.children.append(create_internal(l, u, h - 1, clo, chi))
        return node

    if height <= 1:
        dili.root = create_leaf(root_lb, root_ub, 0, n)
    else:
        dili.root = create_internal(root_lb, root_ub, height - 1, 0, n)
    return dili


def rebuild_subtree(dili: DILI, leaf: Leaf) -> Node | None:
    """Local retrain: re-run the paper's top-down fanout individualization
    (Alg. 4/5) on ONE leaf subtree and splice the result back in place.

    Alg. 7's per-leaf adjustment re-spreads a region with `phi(alpha)`
    growth, but under sustained drift the repeated local fixes degrade the
    region globally (deep conflict chains, sparse slots).  Rebuilding the
    subtree from its live pairs — exactly the bulk-loading machinery, over
    just this key range — restores the build-time layout quality without
    touching the rest of the tree.  Returns the new subtree root (possibly
    an `Internal` — callers route through it transparently), or None when
    the leaf holds too few pairs to be worth rebuilding or can no longer
    be located from the root (already replaced).

    The replacement preserves the leaf's routing region bounds (widened to
    cover any out-of-region keys the parent's clipping routed here), keeps
    `dili.n_keys` unchanged, and marks nothing: the caller's flattener
    sees a new object where the old leaf was — a cache miss, hence dirty
    by identity.
    """
    pairs = collect_pairs(leaf)
    if len(pairs) < 2:
        return None
    # find the splice point FIRST — if the leaf is no longer reachable
    # (already replaced), bail before paying the bulk_load (and before
    # polluting n_conflicts with a rebuild that never lands).  The walk
    # follows a key the leaf owns: pairs live where the static routing
    # puts them, so this reaches the leaf when it is still in the tree.
    rep = float(pairs[len(pairs) // 2][0])
    parent: Internal | None = None
    child_i = -1
    if dili.root is not leaf:
        cur: Node = dili.root
        while isinstance(cur, Internal):
            i = cur.child_index(rep)
            child = cur.children[i]
            if child is leaf:
                parent, child_i = cur, i
                break
            cur = child
        if parent is None:
            return None

    keys = np.array([p[0] for p in pairs], np.float64)
    vals = np.array([p[1] for p in pairs], np.int64)
    sub = bulk_load(keys, vals, cm=dili.cm, eta=dili.eta, lam=dili.lam,
                    local_optimized=dili.local_optimized,
                    sample_stride=dili.sample_stride)
    node = sub.root
    node.lb = min(float(leaf.lb), float(keys[0]))
    node.ub = max(float(leaf.ub), float(keys[-1]))
    dili.n_conflicts += sub.n_conflicts

    if parent is None:
        dili.root = node
    else:
        parent.children[child_i] = node
    return node


def split_leaf(dili: DILI, leaf: Leaf, n_children: int) -> Internal | None:
    """Locality re-clustering primitive: replace ONE write-hot leaf with an
    equal-width `Internal` of `n_children` freshly-fit leaf children.

    `rebuild_subtree` restores model quality but lets the BU-tree cost
    model pick the layout — which happily keeps a large region as one big
    leaf, i.e. ONE incremental-flatten segment whose every row re-flattens
    whenever any key in it is written.  Under zipfian skew with hashed
    rank-scatter that makes nearly every merge O(n).  This splits the
    region into `n_children` leaves, each its own splice segment, so
    subsequent writes dirty only the small child they land in.

    The mutation is the same shape `rebuild_subtree` performs — one parent
    child-pointer swap; no existing Internal's children list is touched —
    so the incremental flattener's contract is preserved: the old leaf is
    a cache miss by identity and everything else splices from cache,
    bit-identical to a full `flatten()`.  Construction mirrors Alg. 4's
    `create_internal`/`create_leaf` (Eq. 1 equal-division model, boundary
    nudge, clip-partition, least-squares + LOCALOPT per child) so routing
    agrees between host construction and device search.  Returns the new
    Internal, or None when the leaf is too small, spans no key range, or
    can no longer be located from the root (already replaced)."""
    pairs = collect_pairs(leaf)
    if len(pairs) < 2 or n_children < 2:
        return None
    # locate the splice point FIRST (same bail-before-building discipline
    # as rebuild_subtree)
    rep = float(pairs[len(pairs) // 2][0])
    parent: Internal | None = None
    child_i = -1
    if dili.root is not leaf:
        cur: Node = dili.root
        while isinstance(cur, Internal):
            i = cur.child_index(rep)
            child = cur.children[i]
            if child is leaf:
                parent, child_i = cur, i
                break
            cur = child
        if parent is None:
            return None

    keys = np.array([p[0] for p in pairs], np.float64)
    vals = np.array([p[1] for p in pairs], np.int64)
    lb = min(float(leaf.lb), float(keys[0]))
    ub = max(float(leaf.ub), float(keys[-1]))
    if not (ub > lb) or not np.isfinite(ub - lb):
        return None
    fo = int(n_children)
    node = Internal(lb=lb, ub=ub, a=0.0, b=0.0)
    node.b = float(PLACE_DTYPE(fo / (ub - lb)))          # Eq. 1
    node.a = -node.b * lb
    node.a, _ = nudge_boundary_safe(node.a, node.b, keys)
    pos = np.clip(predict_np(node.a, node.b, keys).astype(np.int64),
                  0, fo - 1)
    starts = np.searchsorted(pos, np.arange(fo), side="left")
    ends = np.searchsorted(pos, np.arange(fo), side="right")
    eta = dili.eta
    for i in range(fo):
        clo, chi = int(starts[i]), int(ends[i])
        l = lb + i * (ub - lb) / fo
        u = lb + (i + 1) * (ub - lb) / fo
        pd = [(float(keys[j]), int(vals[j])) for j in range(clo, chi)]
        if not dili.local_optimized:
            node.children.append(make_dense_leaf(l, u, pd))
            continue
        child = Leaf(lb=l, ub=u)
        m = len(pd)
        if m >= 2:
            child.a, child.b = least_squares(
                keys[clo:chi], np.arange(m, dtype=np.float64))
        dili.n_conflicts += _count_conflicts_estimate(child, pd, eta)
        local_opt(child, pd, eta)
        node.children.append(child)

    if parent is None:
        dili.root = node
    else:
        parent.children[child_i] = node
    return node


def _count_conflicts_estimate(leaf: Leaf, pd: list, eta: float) -> int:
    m = len(pd)
    if m < 2:
        return 0
    fo = max(int(math.ceil(eta * m)), 1)
    ks = np.array([p[0] for p in pd])
    pos = np.clip(np.floor((leaf.a + leaf.b * ks) * (fo / m)).astype(np.int64),
                  0, fo - 1)
    uniq, counts = np.unique(pos, return_counts=True)
    return int((counts > 1).sum())
