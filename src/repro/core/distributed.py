"""Range-partitioned distributed DILI over a device mesh (shard_map).

The paper's equal-division trick (Eq. 1) *is* the router: partition boundaries
are chosen from key quantiles, and a query's shard comes from a searchsorted
over the (tiny, replicated) boundary array — one more "internal node" whose
children live on different chips.

Two lookup strategies:
  * ``gather``  (default, always correct): all_gather the query batch, search
    locally, psum_scatter masked results back.  Collective bytes:
    Q*8 gathered + Q*8 reduced per chip — bandwidth-roofline analyzed in
    benchmarks/roofline.py.
  * ``a2a``     (optimized, capacity-bounded): bucket queries by shard,
    all_to_all fixed-capacity buckets, search, all_to_all back.  Bytes:
    2*C*R*8 per chip with C = capacity per (src, dst) pair.  Falls back to
    `gather` results for overflowed queries (counted, asserted in tests).

Shard snapshots are padded to identical shapes so the whole index stacks into
leading-axis-sharded arrays -- republish never re-traces.

Online updates (DESIGN.md section 8): each shard owns a private tombstone
overlay absorbing the writes routed to its key range.  A merge folds ONE
shard's overlay through that shard's host DILI (Alg. 7/8), re-flattens only
that shard, and rewrites its rows of the stacked tables in place — no global
rebuild; the stack only re-pads when a shard outgrows the shared pow2 shape.
Reads between merges resolve the (globally sorted, because shard ranges are
disjoint) combined overlay on top of the sharded snapshot lookup.
"""

from __future__ import annotations

import math
from collections import OrderedDict
from dataclasses import dataclass, field
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..obs import watchdog
from .bu_tree import CostModel, DEFAULT_COST
from .dili import bulk_load
from .flat import FlatDILI, flatten
from . import search as S


@dataclass
class ShardedDILI:
    idx: dict              # stacked host arrays, leading dim = shard
    boundaries: np.ndarray  # [R+1] range boundaries (replicated)
    n_shards: int
    max_depth: int
    has_dense: bool = True  # any shard has dense (DILI-LO) leaves
    # online-update state (None when built with keep_host=False)
    flats: list | None = None      # per-shard FlatDILI (current epoch)
    dilis: list | None = None      # per-shard host DILI writers
    overlays: list | None = None   # per-shard TombstoneOverlay
    epoch: int = 0
    # device mirror of the combined overlay, keyed by dtype name;
    # invalidated by every write/merge
    _ov_cache: dict = field(default_factory=dict, repr=False)


def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    out = np.full((n,) + x.shape[1:], fill, dtype=x.dtype)
    out[: len(x)] = x
    return out


def _stack_flats(flats: list[FlatDILI]) -> dict:
    n_nodes = 1 << max(1, math.ceil(math.log2(max(f.n_nodes for f in flats))))
    n_slots = 1 << max(1, math.ceil(math.log2(max(f.n_slots for f in flats))))
    n_pairs = 1 << max(1, math.ceil(math.log2(max(max(f.n_pairs, 1)
                                                  for f in flats))))
    return dict(
        a=np.stack([_pad_to(f.a, n_nodes, 0.0) for f in flats]),
        b=np.stack([_pad_to(f.b, n_nodes, 0.0) for f in flats]),
        base=np.stack([_pad_to(f.base, n_nodes, 0) for f in flats]),
        fo=np.stack([_pad_to(f.fo, n_nodes, 1) for f in flats]),
        dense=np.stack([_pad_to(f.dense, n_nodes, 0) for f in flats]),
        tag=np.stack([_pad_to(f.tag, n_slots, 0) for f in flats]),
        key=np.stack([_pad_to(f.key, n_slots, 0.0) for f in flats]),
        # int64 payloads end-to-end (int32 wrapped payloads above 2^31)
        val=np.stack([_pad_to(f.val, n_slots, -1) for f in flats]),
        # key-sorted pair table per shard (range queries); +inf pads keep the
        # searchsorted window inside the populated prefix
        pair_key=np.stack([_pad_to(f.pair_key, n_pairs, np.inf)
                           for f in flats]),
        pair_val=np.stack([_pad_to(f.pair_val, n_pairs, -1) for f in flats]),
        root=np.array([f.root for f in flats], np.int32),
    )


def build_sharded(keys: np.ndarray, vals: np.ndarray | None, n_shards: int,
                  cm: CostModel = DEFAULT_COST, sample_stride: int = 1,
                  keep_host: bool = True, overlay_cap: int = 4096,
                  **kw) -> ShardedDILI:
    from ..online.overlay import TombstoneOverlay
    keys = np.asarray(keys, np.float64)
    n = len(keys)
    if vals is None:
        vals = np.arange(n, dtype=np.int64)
    # quantile partitioning: equal #keys per shard (balanced memory/work)
    cuts = [0] + [round(n * (i + 1) / n_shards) for i in range(n_shards)]
    flats: list[FlatDILI] = []
    dilis: list = []
    for r in range(n_shards):
        lo, hi = cuts[r], cuts[r + 1]
        d = bulk_load(keys[lo:hi], vals[lo:hi], cm=cm,
                      sample_stride=sample_stride, **kw)
        dilis.append(d)
        flats.append(flatten(d))
    boundaries = np.concatenate([[ -np.inf ],
                                 [keys[cuts[r]] for r in range(1, n_shards)],
                                 [np.inf]])
    stack = _stack_flats(flats)
    # depth-exact: the deepest shard's true height IS the trip count (padding
    # never deepens a tree, and off-range queries miss before going deeper)
    max_depth = max(f.max_depth for f in flats)
    sd = ShardedDILI(idx=stack, boundaries=boundaries, n_shards=n_shards,
                     max_depth=max_depth,
                     has_dense=any(bool(f.dense.any()) for f in flats))
    if keep_host:
        sd.flats = flats
        sd.dilis = dilis
        sd.overlays = [TombstoneOverlay.empty(overlay_cap)
                       for _ in range(n_shards)]
    return sd


def to_mesh(sd: ShardedDILI, mesh: Mesh, axis: str = "data",
            dtype=jnp.float64) -> dict:
    """Place each shard's arrays on its devices (leading dim sharded)."""
    sharding = NamedSharding(mesh, P(axis))
    out = {}
    for k, v in sd.idx.items():
        if k == "root":
            arr = jnp.asarray(v, jnp.int32)
        elif v.dtype == np.float64:
            arr = jnp.asarray(v, dtype)
        else:
            arr = jnp.asarray(v)
        out[k] = jax.device_put(arr, sharding)
    out["boundaries"] = jnp.asarray(sd.boundaries, dtype)  # replicated
    return out


def _local_search(local_idx: dict, q: jnp.ndarray, max_depth: int,
                  has_dense: bool = True):
    idx = {k: v[0] for k, v in local_idx.items() if k != "boundaries"}
    idx["root"] = local_idx["root"][0]
    idx["max_depth"] = max_depth
    idx["has_dense"] = has_dense       # static: skips the dense probe phases
    # depth-exact fixed-trip scan: shard_map has no replication rule for
    # while_loop (jax 0.4.x), so the early-exit variant stays host-side
    return S.search_batch(idx, q, max_depth=max_depth)


def _empty_overlay(dtype) -> dict:
    """Replicated no-op overlay: lets one shard_map trace serve both the
    plain and the overlay read path."""
    return dict(keys=jnp.full(1, np.inf, dtype),
                vals=jnp.zeros(1, jnp.int64),
                tomb=jnp.zeros(1, jnp.int8))


# trace cache for the collective entry points: the shard_map body is a
# fresh closure per call, so without this every batch would re-trace (and
# on CPU re-tracing dominates the dispatch by orders of magnitude).  Keys
# are the static closure parameters; Mesh hashes by device assignment +
# axis names, so equivalent meshes share entries.  jax.jit adds the
# per-shape executable cache on top.  LRU-bounded: a long-lived server
# with varying a2a batch sizes mints one entry per padded length, and
# each entry pins its compiled executables for the life of the process.
_TRACE_CACHE: "OrderedDict" = OrderedDict()
_TRACE_CACHE_MAX = 64


def _cached_collective(key, make):
    fn = _TRACE_CACHE.get(key)
    if fn is None:
        fn = _TRACE_CACHE[key] = jax.jit(make())
        if len(_TRACE_CACHE) > _TRACE_CACHE_MAX:
            _TRACE_CACHE.popitem(last=False)
    else:
        _TRACE_CACHE.move_to_end(key)
    return fn


def _collective_cache_sizes() -> dict:
    """Watchdog view of the collective trace cache: entry count plus total
    traced executables across entries.  A per-batch growth here is exactly
    the PR-4 bug class (fresh shard_map closure per call => re-trace)."""
    total = 0
    for fn in _TRACE_CACHE.values():
        try:
            total += fn._cache_size()
        except Exception:
            pass
    return {"distributed.collective_cache_entries": len(_TRACE_CACHE),
            "distributed.collective_executables": total}


watchdog.register_jit_provider("distributed.collectives",
                               _collective_cache_sizes)


def sharded_lookup(mesh: Mesh, sd_arrays: dict, queries: jnp.ndarray,
                   max_depth: int, axis: str = "data",
                   strategy: str = "gather", overlay: dict | None = None,
                   has_dense: bool = True):
    """Batched lookup across the mesh.  `queries` sharded over `axis`.

    `overlay` (a replicated combined-overlay dict) is resolved INSIDE the
    shard_map body — snapshot traversal + overlay searchsorted are one fused
    device dispatch, with no host round-trip between them.  Each query's
    overlay state is applied by the one shard that owns its key range."""
    from jax.experimental.shard_map import shard_map

    n_shards = mesh.shape[axis]
    bounds = sd_arrays["boundaries"]
    ov = overlay if overlay is not None else _empty_overlay(bounds.dtype)

    in_specs = ({k: P(axis) for k in sd_arrays if k != "boundaries"}
                | {"boundaries": P()})
    ov_specs = {k: P() for k in ov}
    cache_key = (mesh, axis, strategy, max_depth, has_dense,
                 tuple(sorted(sd_arrays)), tuple(sorted(ov)),
                 queries.shape[0] if strategy == "a2a" else None)

    if strategy == "gather":
        def body(local, bnd, ovr, q):
            r = jax.lax.axis_index(axis)
            q_all = jax.lax.all_gather(q, axis, tiled=True)       # [Q_total]
            v, f = _local_search(local, q_all, max_depth, has_dense)
            v, f = S.resolve_overlay(ovr, q_all, v, f)
            # mask to own range: boundaries[r] <= q < boundaries[r+1]
            own = (q_all >= bnd[r]) & (q_all < bnd[r + 1])
            v = jnp.where(own & f, v, 0)
            f = own & f
            # sum across shards, scatter back each device's slice
            v = jax.lax.psum_scatter(v, axis, scatter_dimension=0, tiled=True)
            f = jax.lax.psum_scatter(f.astype(jnp.int32), axis,
                                     scatter_dimension=0, tiled=True)
            return v, f > 0

        fn = _cached_collective(cache_key, lambda: shard_map(
            body, mesh=mesh,
            in_specs=(in_specs, P(), ov_specs, P(axis)),
            out_specs=(P(axis), P(axis))))
        return fn(sd_arrays, bounds, ov, queries)

    elif strategy == "a2a":
        qn = queries.shape[0] // n_shards          # per-device query count
        cap = int(2 * math.ceil(qn / n_shards))    # capacity slack 2x

        def body(local, bnd, ovr, q):
            r = jax.lax.axis_index(axis)
            dest = jnp.clip(jnp.searchsorted(bnd, q, side="right") - 1,
                            0, n_shards - 1)                     # [qn]
            # bucket into [R, cap] with overflow detection
            order = jnp.argsort(dest)
            q_sorted, d_sorted = q[order], dest[order]
            # position within bucket
            onehot = jax.nn.one_hot(d_sorted, n_shards, dtype=jnp.int32)
            within = jnp.cumsum(onehot, axis=0)[jnp.arange(qn), d_sorted] - 1
            ok = within < cap
            buckets = jnp.full((n_shards, cap), jnp.inf, q.dtype)
            buckets = buckets.at[d_sorted, jnp.clip(within, 0, cap - 1)].set(
                jnp.where(ok, q_sorted, jnp.inf))
            recv = jax.lax.all_to_all(buckets, axis, split_axis=0,
                                      concat_axis=0, tiled=True)  # [R*cap]
            v, f = _local_search(local, recv.reshape(-1), max_depth,
                                 has_dense)
            v, f = S.resolve_overlay(ovr, recv.reshape(-1), v, f)
            v = v.reshape(n_shards, cap)
            f = f.reshape(n_shards, cap)
            vb = jax.lax.all_to_all(v, axis, split_axis=0, concat_axis=0,
                                    tiled=True).reshape(n_shards, cap)
            fb = jax.lax.all_to_all(f, axis, split_axis=0, concat_axis=0,
                                    tiled=True).reshape(n_shards, cap)
            # unbucket: gather each sorted query's result, unsort
            vs = vb[d_sorted, jnp.clip(within, 0, cap - 1)]
            fs = fb[d_sorted, jnp.clip(within, 0, cap - 1)] & ok
            inv = jnp.argsort(order)
            return vs[inv], fs[inv], jnp.sum(~ok).astype(jnp.int32)[None]

        fn = _cached_collective(cache_key, lambda: shard_map(
            body, mesh=mesh,
            in_specs=(in_specs, P(), ov_specs, P(axis)),
            out_specs=(P(axis), P(axis), P(axis))))
        return fn(sd_arrays, bounds, ov, queries)
    raise ValueError(strategy)


def sharded_range_query(mesh: Mesh, sd_arrays: dict, lo: jnp.ndarray,
                        hi: jnp.ndarray, max_hits: int = 128,
                        axis: str = "data"):
    """Range queries across the mesh: for each (lo, hi) return the first
    `max_hits` pairs in [lo, hi) ascending plus the count (saturating).

    Each shard bisects ITS key-sorted pair table over the window clipped to
    its own key range — O(log n_shard + max_hits) per query per shard — then
    writes its run into the global answer at the offset given by the
    exclusive prefix of per-shard counts (shard ranges are disjoint and
    ordered, so shard-order concatenation IS key order).  One psum_scatter
    assembles and returns each device's query slice.
    """
    from jax.experimental.shard_map import shard_map

    n_shards = mesh.shape[axis]
    bounds = sd_arrays["boundaries"]
    in_specs = ({k: P(axis) for k in sd_arrays if k != "boundaries"}
                | {"boundaries": P()})

    def body(local, bnd, lo, hi):
        r = jax.lax.axis_index(axis)
        lo_all = jax.lax.all_gather(lo, axis, tiled=True)        # [Q]
        hi_all = jax.lax.all_gather(hi, axis, tiled=True)
        pk = local["pair_key"][0]
        pv = local["pair_val"][0]
        # clip the window to this shard's key range
        slo = jnp.maximum(lo_all, bnd[r])
        shi = jnp.maximum(jnp.minimum(hi_all, bnd[r + 1]), slo)
        start = jnp.searchsorted(pk, slo, side="left")
        cnt = jnp.searchsorted(pk, shi, side="left") - start     # [Q]
        # exclusive prefix of counts over earlier shards = this run's offset
        cnt_all = jax.lax.all_gather(cnt, axis)                  # [R, Q]
        before = jnp.sum(
            jnp.where(jnp.arange(n_shards)[:, None] < r, cnt_all, 0), axis=0)
        posn = jnp.arange(max_hits)[None, :]                     # [1, H]
        rel = posn - before[:, None]                             # [Q, H]
        mine = (rel >= 0) & (rel < cnt[:, None])
        g = jnp.clip(start[:, None] + rel, 0, pk.shape[0] - 1)
        # additive assembly: exactly one shard owns each (query, position)
        ks = jnp.where(mine, pk[g], 0.0)
        vs = jnp.where(mine, pv[g], 0)
        ks = jax.lax.psum_scatter(ks, axis, scatter_dimension=0, tiled=True)
        vs = jax.lax.psum_scatter(vs, axis, scatter_dimension=0, tiled=True)
        total = jax.lax.psum_scatter(cnt, axis, scatter_dimension=0,
                                     tiled=True)                 # [Q/R]
        filled = posn < jnp.minimum(total, max_hits)[:, None]
        ks = jnp.where(filled, ks, jnp.inf)
        vs = jnp.where(filled, vs, -1)
        return ks, vs, jnp.minimum(total, max_hits).astype(jnp.int32)

    fn = _cached_collective(
        (mesh, axis, "range", max_hits, tuple(sorted(sd_arrays))),
        lambda: shard_map(
            body, mesh=mesh,
            in_specs=(in_specs, P(), P(axis), P(axis)),
            out_specs=(P(axis, None), P(axis, None), P(axis))))
    return fn(sd_arrays, bounds, lo, hi)


# ---------------------------------------------------------------------------
# Online updates: per-shard overlays, single-shard merge, fused read path
# ---------------------------------------------------------------------------


def shard_of(sd: ShardedDILI, keys: np.ndarray) -> np.ndarray:
    """Route keys to shards: the boundary array is the root 'internal node'."""
    return np.clip(np.searchsorted(sd.boundaries, keys, side="right") - 1,
                   0, sd.n_shards - 1)


def _require_host(sd: ShardedDILI) -> None:
    if sd.overlays is None:
        raise ValueError("build_sharded(..., keep_host=True) required for "
                         "online updates")


def sharded_upsert(sd: ShardedDILI, keys, vals) -> None:
    _require_host(sd)
    keys = np.atleast_1d(np.asarray(keys, np.float64))
    vals = np.atleast_1d(np.asarray(vals, np.int64))
    dest = shard_of(sd, keys)
    for r in np.unique(dest):
        m = dest == r
        sd.overlays[r] = sd.overlays[r].upsert_batch(keys[m], vals[m])
    sd._ov_cache.clear()


def sharded_delete(sd: ShardedDILI, keys) -> None:
    _require_host(sd)
    keys = np.atleast_1d(np.asarray(keys, np.float64))
    dest = shard_of(sd, keys)
    for r in np.unique(dest):
        m = dest == r
        sd.overlays[r] = sd.overlays[r].delete_batch(keys[m])
    sd._ov_cache.clear()


def sharded_merge(sd: ShardedDILI, max_fill: float = 0.0,
                  fold_fn=None, flatten_fn=None) -> list[int]:
    """Fold each shard whose overlay full_fraction exceeds `max_fill` through
    its host DILI (Alg. 7/8), re-flatten ONLY those shards, and rewrite their
    rows of the stacked tables in place.  The stack is re-padded (bigger pow2)
    only when a merged shard outgrows the shared shape.  Returns merged shard
    ids; bumps `sd.epoch` when any merged.

    `fold_fn(r, dili, overlay)` / `flatten_fn(r, dili) -> FlatDILI` override
    the per-shard fold and flatten — the maintenance hooks the sharded
    engine uses to route through accounting/retrains and the incremental
    flattener (defaults: plain `fold_overlay` / full `flatten`).

    NOTE: only the HOST stack (`sd.idx`) is rewritten, and the merged
    overlays are cleared — device copies from a prior `to_mesh()` no longer
    see the folded writes.  Callers must republish (`to_mesh(sd, mesh)`)
    before serving lookups whenever this returns a non-empty list."""
    from ..online.overlay import TombstoneOverlay, fold_overlay
    _require_host(sd)
    if fold_fn is None:
        fold_fn = lambda r, d, ov: fold_overlay(d, ov)   # noqa: E731
    if flatten_fn is None:
        # drain the dirty-id set a full flatten supersedes (it would
        # otherwise grow for the lifetime of a maintenance-less shard)
        def flatten_fn(r, d):
            f = flatten(d)
            d.take_dirty()
            return f
    merged: list[int] = []
    for r, ov in enumerate(sd.overlays):
        if ov.count == 0 or ov.full_fraction < max_fill:
            continue
        fold_fn(r, sd.dilis[r], ov)
        sd.flats[r] = flatten_fn(r, sd.dilis[r])
        sd.overlays[r] = TombstoneOverlay.empty(ov.cap)
        merged.append(r)
    if not merged:
        return merged
    sd._ov_cache.clear()
    n_nodes = sd.idx["a"].shape[1]
    n_slots = sd.idx["tag"].shape[1]
    n_pairs = sd.idx["pair_key"].shape[1]
    if any(sd.flats[r].n_nodes > n_nodes or sd.flats[r].n_slots > n_slots
           or sd.flats[r].n_pairs > n_pairs for r in merged):
        sd.idx = _stack_flats(sd.flats)      # grow: re-pad every shard
    else:
        for r in merged:                     # steady state: row rewrite only
            f = sd.flats[r]
            sd.idx["a"][r] = _pad_to(f.a, n_nodes, 0.0)
            sd.idx["b"][r] = _pad_to(f.b, n_nodes, 0.0)
            sd.idx["base"][r] = _pad_to(f.base, n_nodes, 0)
            sd.idx["fo"][r] = _pad_to(f.fo, n_nodes, 1)
            sd.idx["dense"][r] = _pad_to(f.dense, n_nodes, 0)
            sd.idx["tag"][r] = _pad_to(f.tag, n_slots, 0)
            sd.idx["key"][r] = _pad_to(f.key, n_slots, 0.0)
            sd.idx["val"][r] = _pad_to(f.val, n_slots, -1)
            sd.idx["pair_key"][r] = _pad_to(f.pair_key, n_pairs, np.inf)
            sd.idx["pair_val"][r] = _pad_to(f.pair_val, n_pairs, -1)
            sd.idx["root"][r] = f.root
    sd.max_depth = max(f.max_depth for f in sd.flats)
    sd.has_dense = any(bool(f.dense.any()) for f in sd.flats)
    sd.epoch += 1
    return merged


def combined_overlay_arrays(sd: ShardedDILI, dtype=jnp.float64) -> dict:
    """One globally sorted overlay view: shard key ranges are disjoint, so
    concatenating per-shard populated prefixes in shard order IS sorted.
    Cached per dtype; writes and merges invalidate."""
    _require_host(sd)
    ckey = np.dtype(dtype).name
    hit = sd._ov_cache.get(ckey)
    if hit is not None:
        return hit
    parts = [ov.entries() for ov in sd.overlays]
    ks = np.concatenate([p[0] for p in parts])
    vs = np.concatenate([p[1] for p in parts])
    tb = np.concatenate([p[2] for p in parts])
    # pad to (at least) the summed per-shard capacities, not the populated
    # count: caps start at the configured overlay_cap and only grow by
    # doubling, so the replicated mirror keeps ONE shape from idle through
    # write-heavy periods and the fused collective re-traces only when a
    # shard's overlay doubles — the exact policy of the local engine's
    # cap-sized mirror (overlay_device_arrays).  Pow2-of-count padding
    # instead re-traced at every pow2 crossing.  The mirror is rebuilt only
    # when the _ov_cache was invalidated by a write or merge, never on the
    # read path, so the cap-sized concat is off the serving hot loop.
    floor = sum(ov.cap for ov in sd.overlays)
    cap = 1 << max(1, math.ceil(math.log2(max(len(ks), floor, 1))))
    out = dict(keys=jnp.asarray(_pad_to(ks, cap, np.inf), dtype),
               vals=jnp.asarray(_pad_to(vs, cap, 0), jnp.int64),
               tomb=jnp.asarray(_pad_to(tb, cap, 0), jnp.int8))
    sd._ov_cache[ckey] = out
    return out


def sharded_lookup_with_overlay(mesh: Mesh, sd_arrays: dict,
                                sd: ShardedDILI, queries: jnp.ndarray,
                                max_depth: int, axis: str = "data",
                                strategy: str = "gather"):
    """Sharded snapshot lookup with the (replicated) combined overlay
    resolved inside the shard_map body — ONE fused device dispatch per query
    batch, no extra host round-trip for the overlay pass."""
    ova = combined_overlay_arrays(sd, sd_arrays["boundaries"].dtype)
    return sharded_lookup(mesh, sd_arrays, queries, max_depth, axis=axis,
                          strategy=strategy, overlay=ova,
                          has_dense=sd.has_dense)
