"""Range-partitioned distributed DILI over a device mesh (shard_map).

The paper's equal-division trick (Eq. 1) *is* the router: partition boundaries
are chosen from key quantiles, and a query's shard comes from a searchsorted
over the (tiny, replicated) boundary array — one more "internal node" whose
children live on different chips.

Two lookup strategies:
  * ``gather``  (default, always correct): all_gather the query batch, search
    locally, psum_scatter masked results back.  Collective bytes:
    Q*8 gathered + Q*8 reduced per chip — bandwidth-roofline analyzed in
    benchmarks/roofline.py.
  * ``a2a``     (optimized, capacity-bounded): bucket queries by shard,
    all_to_all fixed-capacity buckets, search, all_to_all back.  Bytes:
    2*C*R*8 per chip with C = capacity per (src, dst) pair.  Falls back to
    `gather` results for overflowed queries (counted, asserted in tests).

Shard snapshots are padded to identical shapes so the whole index stacks into
leading-axis-sharded arrays -- republish never re-traces.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .bu_tree import CostModel, DEFAULT_COST
from .dili import bulk_load
from .flat import FlatDILI, flatten
from . import search as S


@dataclass
class ShardedDILI:
    idx: dict              # stacked device arrays, leading dim = shard
    boundaries: np.ndarray  # [R+1] range boundaries (replicated)
    n_shards: int
    max_depth: int


def _pad_to(x: np.ndarray, n: int, fill) -> np.ndarray:
    out = np.full((n,) + x.shape[1:], fill, dtype=x.dtype)
    out[: len(x)] = x
    return out


def build_sharded(keys: np.ndarray, vals: np.ndarray | None, n_shards: int,
                  cm: CostModel = DEFAULT_COST, sample_stride: int = 1,
                  **kw) -> ShardedDILI:
    keys = np.asarray(keys, np.float64)
    n = len(keys)
    if vals is None:
        vals = np.arange(n, dtype=np.int64)
    # quantile partitioning: equal #keys per shard (balanced memory/work)
    cuts = [0] + [round(n * (i + 1) / n_shards) for i in range(n_shards)]
    flats: list[FlatDILI] = []
    for r in range(n_shards):
        lo, hi = cuts[r], cuts[r + 1]
        d = bulk_load(keys[lo:hi], vals[lo:hi], cm=cm,
                      sample_stride=sample_stride, **kw)
        flats.append(flatten(d))
    boundaries = np.concatenate([[ -np.inf ],
                                 [keys[cuts[r]] for r in range(1, n_shards)],
                                 [np.inf]])
    n_nodes = 1 << max(1, math.ceil(math.log2(max(f.n_nodes for f in flats))))
    n_slots = 1 << max(1, math.ceil(math.log2(max(f.n_slots for f in flats))))
    stack = dict(
        a=np.stack([_pad_to(f.a, n_nodes, 0.0) for f in flats]),
        b=np.stack([_pad_to(f.b, n_nodes, 0.0) for f in flats]),
        base=np.stack([_pad_to(f.base, n_nodes, 0) for f in flats]),
        fo=np.stack([_pad_to(f.fo, n_nodes, 1) for f in flats]),
        dense=np.stack([_pad_to(f.dense, n_nodes, 0) for f in flats]),
        tag=np.stack([_pad_to(f.tag, n_slots, 0) for f in flats]),
        key=np.stack([_pad_to(f.key, n_slots, 0.0) for f in flats]),
        val=np.stack([_pad_to(f.val.astype(np.int32), n_slots, -1)
                      for f in flats]),
        root=np.array([f.root for f in flats], np.int32),
    )
    max_depth = max(f.max_depth for f in flats) + 2
    return ShardedDILI(idx=stack, boundaries=boundaries, n_shards=n_shards,
                       max_depth=max_depth)


def to_mesh(sd: ShardedDILI, mesh: Mesh, axis: str = "data",
            dtype=jnp.float64) -> dict:
    """Place each shard's arrays on its devices (leading dim sharded)."""
    sharding = NamedSharding(mesh, P(axis))
    out = {}
    for k, v in sd.idx.items():
        if k == "root":
            arr = jnp.asarray(v, jnp.int32)
        elif v.dtype == np.float64:
            arr = jnp.asarray(v, dtype)
        else:
            arr = jnp.asarray(v)
        out[k] = jax.device_put(arr, sharding)
    out["boundaries"] = jnp.asarray(sd.boundaries, dtype)  # replicated
    return out


def _local_search(local_idx: dict, q: jnp.ndarray, max_depth: int):
    idx = {k: v[0] for k, v in local_idx.items() if k != "boundaries"}
    idx["root"] = local_idx["root"][0]
    idx["max_depth"] = max_depth
    return S.search_batch(idx, q, max_depth=max_depth)


def sharded_lookup(mesh: Mesh, sd_arrays: dict, queries: jnp.ndarray,
                   max_depth: int, axis: str = "data",
                   strategy: str = "gather"):
    """Batched lookup across the mesh.  `queries` sharded over `axis`."""
    from jax.experimental.shard_map import shard_map

    n_shards = mesh.shape[axis]
    bounds = sd_arrays["boundaries"]

    in_specs = ({k: P(axis) for k in sd_arrays if k != "boundaries"}
                | {"boundaries": P()})

    if strategy == "gather":
        def body(local, bnd, q):
            r = jax.lax.axis_index(axis)
            q_all = jax.lax.all_gather(q, axis, tiled=True)       # [Q_total]
            v, f = _local_search(local, q_all, max_depth)
            # mask to own range: boundaries[r] <= q < boundaries[r+1]
            own = (q_all >= bnd[r]) & (q_all < bnd[r + 1])
            v = jnp.where(own & f, v, 0)
            f = own & f
            # sum across shards, scatter back each device's slice
            v = jax.lax.psum_scatter(v, axis, scatter_dimension=0, tiled=True)
            f = jax.lax.psum_scatter(f.astype(jnp.int32), axis,
                                     scatter_dimension=0, tiled=True)
            return v, f > 0

        fn = shard_map(body, mesh=mesh,
                       in_specs=(in_specs, P(), P(axis)),
                       out_specs=(P(axis), P(axis)))
        return fn(sd_arrays, bounds, queries)

    elif strategy == "a2a":
        qn = queries.shape[0] // n_shards          # per-device query count
        cap = int(2 * math.ceil(qn / n_shards))    # capacity slack 2x

        def body(local, bnd, q):
            r = jax.lax.axis_index(axis)
            dest = jnp.clip(jnp.searchsorted(bnd, q, side="right") - 1,
                            0, n_shards - 1)                     # [qn]
            # bucket into [R, cap] with overflow detection
            order = jnp.argsort(dest)
            q_sorted, d_sorted = q[order], dest[order]
            # position within bucket
            onehot = jax.nn.one_hot(d_sorted, n_shards, dtype=jnp.int32)
            within = jnp.cumsum(onehot, axis=0)[jnp.arange(qn), d_sorted] - 1
            ok = within < cap
            buckets = jnp.full((n_shards, cap), jnp.inf, q.dtype)
            buckets = buckets.at[d_sorted, jnp.clip(within, 0, cap - 1)].set(
                jnp.where(ok, q_sorted, jnp.inf))
            recv = jax.lax.all_to_all(buckets, axis, split_axis=0,
                                      concat_axis=0, tiled=True)  # [R*cap]
            v, f = _local_search(local, recv.reshape(-1), max_depth)
            v = v.reshape(n_shards, cap)
            f = f.reshape(n_shards, cap)
            vb = jax.lax.all_to_all(v, axis, split_axis=0, concat_axis=0,
                                    tiled=True).reshape(n_shards, cap)
            fb = jax.lax.all_to_all(f, axis, split_axis=0, concat_axis=0,
                                    tiled=True).reshape(n_shards, cap)
            # unbucket: gather each sorted query's result, unsort
            vs = vb[d_sorted, jnp.clip(within, 0, cap - 1)]
            fs = fb[d_sorted, jnp.clip(within, 0, cap - 1)] & ok
            inv = jnp.argsort(order)
            return vs[inv], fs[inv], jnp.sum(~ok).astype(jnp.int32)[None]

        fn = shard_map(body, mesh=mesh,
                       in_specs=(in_specs, P(), P(axis)),
                       out_specs=(P(axis), P(axis), P(axis)))
        return fn(sd_arrays, bounds, queries)
    raise ValueError(strategy)
