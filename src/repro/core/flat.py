"""Flattening: host DILI -> immutable structure-of-arrays device snapshot.

TPU-native layout (DESIGN.md section 2): the whole tree becomes three parallel
tables so traversal is a chain of `gather; fma; floor; clamp` — no pointers.

Node table (one row per internal OR leaf node):
    a, b      : linear model (key -> slot offset), float
    base      : first slot of this node in the slot table, int32
    fo        : number of slots, int32
    dense     : 1 if this is a DILI-LO dense leaf (exponential-search exit)

Slot table (one row per slot of every node, concatenated):
    tag       : 0 = EMPTY, 1 = PAIR, 2 = CHILD
    key       : pair key (valid when tag == PAIR)
    val       : pair payload (tag == PAIR) or child node id (tag == CHILD)

Internal nodes are just nodes whose slots are all CHILD — search over the
whole tree (Alg. 6) collapses into ONE loop (search.py).

Pair table (key-sorted auxiliary view of every PAIR slot, built once per
flatten; DESIGN.md section 9):
    pair_key  : sorted pair keys
    pair_val  : payloads, aligned with pair_key
    pair_slot : slot-table rank of each pair (its row in the slot table)

Range queries bisect the pair table (two searchsorted) and gather one bounded
window — O(log n + max_hits) per query — instead of scanning the slot table.

The live write path is `repro.online`'s tombstone-capable overlay +
epoch/merge lifecycle (DESIGN.md section 8).  `DeltaOverlay` below is the
legacy insert-only buffer, kept for the single-process convenience path and
its tests; it is NOT what serving uses.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .dili import DILI, Internal, Leaf

TAG_EMPTY, TAG_PAIR, TAG_CHILD = 0, 1, 2


@dataclass
class FlatDILI:
    # node table
    a: np.ndarray        # f64 [n_nodes]
    b: np.ndarray        # f64 [n_nodes]
    base: np.ndarray     # i32 [n_nodes]
    fo: np.ndarray       # i32 [n_nodes]
    dense: np.ndarray    # i8  [n_nodes]
    # slot table
    tag: np.ndarray      # i8  [n_slots]
    key: np.ndarray      # f64 [n_slots]
    val: np.ndarray      # i64 [n_slots]
    # pair table (key-sorted auxiliary view of the PAIR slots)
    pair_key: np.ndarray   # f64 [n_pairs], sorted ascending
    pair_val: np.ndarray   # i64 [n_pairs]
    pair_slot: np.ndarray  # i32 [n_pairs], slot-table rank of each pair
    root: int
    max_depth: int
    key_lo: float
    key_hi: float
    # segment metadata: number of splice units (top-level leaf subtrees) the
    # incremental flattener would cache for this tree — the denominator of
    # the dirty-segment fraction and the re-clustering layout signal
    n_segments: int = 0

    @property
    def n_nodes(self) -> int:
        return len(self.a)

    @property
    def n_slots(self) -> int:
        return len(self.tag)

    @property
    def n_pairs(self) -> int:
        return len(self.pair_key)

    def nbytes(self) -> int:
        return sum(x.nbytes for x in
                   (self.a, self.b, self.base, self.fo, self.dense,
                    self.tag, self.key, self.val,
                    self.pair_key, self.pair_val, self.pair_slot))

    def astype(self, dtype) -> "FlatDILI":
        """Cast key/model dtype (f32 for the Pallas TPU kernel path)."""
        return FlatDILI(self.a.astype(dtype), self.b.astype(dtype),
                        self.base, self.fo, self.dense, self.tag,
                        self.key.astype(dtype), self.val,
                        self.pair_key.astype(dtype), self.pair_val,
                        self.pair_slot, self.root,
                        self.max_depth, self.key_lo, self.key_hi,
                        self.n_segments)


def preorder(root) -> list:
    """DFS preorder over the host tree.  This is the canonical flatten
    order (since the maintenance subsystem, DESIGN.md section 12): every
    subtree occupies one CONTIGUOUS run of node ids and slot rows, so the
    incremental flattener (`repro.maintain.flattener`) can splice a dirty
    subtree's re-flattened rows without renumbering interleaved levels —
    BFS interleaves subtrees across levels and has no such property.
    (Lookup cost is unaffected: an interleaved same-process A/B of the two
    orders on the 300k fb/wikits/logn snapshots measured DFS at 0.84x /
    0.28x / 0.93x of the BFS wall time — the former BFS comment's
    "parents get smaller ids" locality hope does not show up on the
    batched gather path.)
    Children are visited in key order, so (with the equal-division routing
    being monotone in the key) the PAIR slots of consecutive subtrees are
    consecutive key ranges too."""
    order: list = []
    stack = [root]
    while stack:
        nd = stack.pop()
        order.append(nd)
        if isinstance(nd, Internal):
            stack.extend(reversed(nd.children))
        else:
            stack.extend(reversed([s for s in nd.slots
                                   if isinstance(s, Leaf)]))
    return order


def node_tables(nodes: list, ids: dict[int, int]):
    """Materialize the node + slot tables for `nodes` (a preorder run) with
    node ids taken from `ids`.  Shared by the whole-tree `flatten()` and the
    per-subtree blocks of `repro.maintain.flattener` (which passes
    subtree-local ids), so the two can never drift."""
    n_nodes = len(nodes)
    a = np.zeros(n_nodes)
    b = np.zeros(n_nodes)
    base = np.zeros(n_nodes, np.int32)
    fo = np.zeros(n_nodes, np.int32)
    dense = np.zeros(n_nodes, np.int8)

    tags: list[np.ndarray] = []
    keys: list[np.ndarray] = []
    vals: list[np.ndarray] = []
    cursor = 0
    for i, nd in enumerate(nodes):
        if isinstance(nd, Internal):
            m = nd.fanout
            a[i], b[i], base[i], fo[i] = nd.a, nd.b, cursor, m
            tags.append(np.full(m, TAG_CHILD, np.int8))
            keys.append(np.zeros(m))
            vals.append(np.array([ids[id(c)] for c in nd.children], np.int64))
            cursor += m
        else:
            m = max(nd.fo, 1)
            a[i], b[i], base[i], fo[i] = nd.a, nd.b, cursor, m
            dense[i] = 1 if nd.dense else 0
            t = np.zeros(m, np.int8)
            k = np.zeros(m)
            v = np.zeros(m, np.int64)
            for j, s in enumerate(nd.slots[:m]):
                if s is None:
                    continue
                if isinstance(s, Leaf):
                    t[j] = TAG_CHILD
                    v[j] = ids[id(s)]
                else:
                    t[j] = TAG_PAIR
                    k[j] = s[0]
                    v[j] = s[1]
            tags.append(t)
            keys.append(k)
            vals.append(v)
            cursor += m

    tag_all = np.concatenate(tags) if tags else np.zeros(0, np.int8)
    key_all = np.concatenate(keys) if keys else np.zeros(0)
    val_all = np.concatenate(vals) if vals else np.zeros(0, np.int64)
    return a, b, base, fo, dense, tag_all, key_all, val_all


def flatten(dili: DILI) -> FlatDILI:
    """DFS preorder over the host tree, assigning node ids and slot ranges
    (see `preorder` for why preorder is the canonical order)."""
    nodes = preorder(dili.root)
    ids = {id(nd): i for i, nd in enumerate(nodes)}
    a, b, base, fo, dense, tag_all, key_all, val_all = node_tables(nodes, ids)

    # pair table: key-sorted view of the PAIR slots.  Slots are id-ordered,
    # not key-ordered, so one argsort here buys O(log n + k) range queries
    # (two searchsorted + a bounded window gather) on the device.
    slots = np.nonzero(tag_all == TAG_PAIR)[0].astype(np.int32)
    order = np.argsort(key_all[slots], kind="stable")
    pair_slot = slots[order]

    return FlatDILI(
        a=a, b=b, base=base, fo=fo, dense=dense,
        tag=tag_all, key=key_all, val=val_all,
        pair_key=key_all[pair_slot], pair_val=val_all[pair_slot],
        pair_slot=pair_slot,
        root=ids[id(dili.root)], max_depth=_max_depth(dili.root),
        key_lo=float(dili.root.lb), key_hi=float(dili.root.ub),
        n_segments=_n_segments(dili.root),
    )


def _n_segments(root) -> int:
    """Count the splice units (`maintain.flattener._units`'s 'seg' entries):
    top-level leaf subtrees hanging off Internals, or the root itself when
    it is a leaf.  O(#internals + #segments), no per-slot work."""
    n = 0
    stack = [root]
    while stack:
        nd = stack.pop()
        if isinstance(nd, Internal):
            stack.extend(nd.children)
        else:
            n += 1
    return n


def _max_depth(root) -> int:
    best = 1
    stack = [(root, 1)]
    while stack:
        nd, d = stack.pop()
        best = max(best, d)
        if isinstance(nd, Internal):
            for c in nd.children:
                stack.append((c, d + 1))
        else:
            for s in nd.slots:
                if isinstance(s, Leaf):
                    stack.append((s, d + 1))
    return best


# ---------------------------------------------------------------------------
# Delta overlay: sorted buffer for inserts between snapshot publishes
# ---------------------------------------------------------------------------


def merge_sorted_runs(old_k: np.ndarray, old_cols: tuple,
                      new_k: np.ndarray, new_cols: tuple):
    """Merge an already-sorted run with an (unsorted) write batch.

    Last-write-wins: a new key displaces an old entry with the same key, and
    within the batch the later duplicate wins.  Cost is O(n + k log n): the
    batch is sorted (k log k), binary-searched against the old run, and both
    runs are scattered straight into their merged positions — the old run is
    never re-sorted.  Returns (keys, cols) with cols aligned to keys.
    """
    new_k = np.asarray(new_k, old_k.dtype)
    order = np.argsort(new_k, kind="stable")
    new_k = new_k[order]
    new_cols = tuple(np.asarray(c)[order] for c in new_cols)
    keep = np.ones(len(new_k), bool)                 # in-batch dedupe (last)
    keep[:-1] = np.diff(new_k) != 0
    new_k = new_k[keep]
    new_cols = tuple(c[keep] for c in new_cols)

    if len(new_k):
        # drop old entries shadowed by the batch
        pos = np.minimum(np.searchsorted(new_k, old_k), len(new_k) - 1)
        live = new_k[pos] != old_k
        old_k = old_k[live]
        old_cols = tuple(c[live] for c in old_cols)

    # interleave: each run's rank among the other gives its merged position
    n = len(old_k) + len(new_k)
    at_old = np.searchsorted(new_k, old_k) + np.arange(len(old_k))
    at_new = np.searchsorted(old_k, new_k) + np.arange(len(new_k))
    mk = np.empty(n, old_k.dtype)
    mk[at_old] = old_k
    mk[at_new] = new_k
    cols = []
    for oc, nc in zip(old_cols, new_cols):
        mc = np.empty(n, oc.dtype)
        mc[at_old] = oc
        mc[at_new] = nc
        cols.append(mc)
    return mk, tuple(cols)


@dataclass
class DeltaOverlay:
    keys: np.ndarray     # f64 [cap], padded with +inf
    vals: np.ndarray     # i64 [cap]
    count: int
    cap: int

    @staticmethod
    def empty(cap: int = 65536) -> "DeltaOverlay":
        return DeltaOverlay(np.full(cap, np.inf), np.zeros(cap, np.int64), 0, cap)

    def insert_batch(self, k: np.ndarray, v: np.ndarray) -> "DeltaOverlay":
        # the buffer is already sorted: merge two runs instead of re-sorting
        # the whole thing — absorption is O(n + k log n), not O((n+k) log(n+k))
        nk, (nv,) = merge_sorted_runs(
            self.keys[: self.count], (self.vals[: self.count],),
            np.asarray(k, np.float64), (np.asarray(v, np.int64),))
        cap = self.cap
        while len(nk) > cap:
            cap *= 2
        keys = np.full(cap, np.inf)
        vals = np.zeros(cap, np.int64)
        keys[: len(nk)] = nk
        vals[: len(nk)] = nv
        return DeltaOverlay(keys, vals, len(nk), cap)

    @property
    def full_fraction(self) -> float:
        return self.count / max(self.cap, 1)
