"""Flattening: host DILI -> immutable structure-of-arrays device snapshot.

TPU-native layout (DESIGN.md section 2): the whole tree becomes three parallel
tables so traversal is a chain of `gather; fma; floor; clamp` — no pointers.

Node table (one row per internal OR leaf node):
    a, b      : linear model (key -> slot offset), float
    base      : first slot of this node in the slot table, int32
    fo        : number of slots, int32
    dense     : 1 if this is a DILI-LO dense leaf (exponential-search exit)

Slot table (one row per slot of every node, concatenated):
    tag       : 0 = EMPTY, 1 = PAIR, 2 = CHILD
    key       : pair key (valid when tag == PAIR)
    val       : pair payload (tag == PAIR) or child node id (tag == CHILD)

Internal nodes are just nodes whose slots are all CHILD — search over the
whole tree (Alg. 6) collapses into ONE loop (search.py).

A sorted *delta overlay* (LSM-style) absorbs freshly inserted keys between
snapshot publishes.  `DeltaOverlay` below is the insert-only sketch; the full
tombstone-capable overlay + epoch/merge lifecycle lives in `repro.online`
(DESIGN.md section 8).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .dili import DILI, Internal, Leaf

TAG_EMPTY, TAG_PAIR, TAG_CHILD = 0, 1, 2


@dataclass
class FlatDILI:
    # node table
    a: np.ndarray        # f64 [n_nodes]
    b: np.ndarray        # f64 [n_nodes]
    base: np.ndarray     # i32 [n_nodes]
    fo: np.ndarray       # i32 [n_nodes]
    dense: np.ndarray    # i8  [n_nodes]
    # slot table
    tag: np.ndarray      # i8  [n_slots]
    key: np.ndarray      # f64 [n_slots]
    val: np.ndarray      # i64 [n_slots]
    root: int
    max_depth: int
    key_lo: float
    key_hi: float

    @property
    def n_nodes(self) -> int:
        return len(self.a)

    @property
    def n_slots(self) -> int:
        return len(self.tag)

    def nbytes(self) -> int:
        return sum(x.nbytes for x in
                   (self.a, self.b, self.base, self.fo, self.dense,
                    self.tag, self.key, self.val))

    def astype(self, dtype) -> "FlatDILI":
        """Cast key/model dtype (f32 for the Pallas TPU kernel path)."""
        return FlatDILI(self.a.astype(dtype), self.b.astype(dtype),
                        self.base, self.fo, self.dense, self.tag,
                        self.key.astype(dtype), self.val, self.root,
                        self.max_depth, self.key_lo, self.key_hi)


def flatten(dili: DILI) -> FlatDILI:
    """BFS over the host tree, assigning node ids and slot ranges."""
    nodes: list = []
    stack = [dili.root]
    ids: dict[int, int] = {}
    # BFS so parents get smaller ids than children (nice for cache locality of
    # the hot top levels when the table is VMEM-tiled).
    from collections import deque
    q = deque([dili.root])
    while q:
        nd = q.popleft()
        ids[id(nd)] = len(nodes)
        nodes.append(nd)
        if isinstance(nd, Internal):
            for c in nd.children:
                q.append(c)
        else:
            for s in nd.slots:
                if isinstance(s, Leaf):
                    q.append(s)

    n_nodes = len(nodes)
    a = np.zeros(n_nodes)
    b = np.zeros(n_nodes)
    base = np.zeros(n_nodes, np.int32)
    fo = np.zeros(n_nodes, np.int32)
    dense = np.zeros(n_nodes, np.int8)

    tags: list[np.ndarray] = []
    keys: list[np.ndarray] = []
    vals: list[np.ndarray] = []
    cursor = 0
    for i, nd in enumerate(nodes):
        if isinstance(nd, Internal):
            m = nd.fanout
            a[i], b[i], base[i], fo[i] = nd.a, nd.b, cursor, m
            tags.append(np.full(m, TAG_CHILD, np.int8))
            keys.append(np.zeros(m))
            vals.append(np.array([ids[id(c)] for c in nd.children], np.int64))
            cursor += m
        else:
            m = max(nd.fo, 1)
            a[i], b[i], base[i], fo[i] = nd.a, nd.b, cursor, m
            dense[i] = 1 if nd.dense else 0
            t = np.zeros(m, np.int8)
            k = np.zeros(m)
            v = np.zeros(m, np.int64)
            for j, s in enumerate(nd.slots[:m]):
                if s is None:
                    continue
                if isinstance(s, Leaf):
                    t[j] = TAG_CHILD
                    v[j] = ids[id(s)]
                else:
                    t[j] = TAG_PAIR
                    k[j] = s[0]
                    v[j] = s[1]
            tags.append(t)
            keys.append(k)
            vals.append(v)
            cursor += m

    depth = _max_depth(dili.root)
    st = dili.root
    return FlatDILI(
        a=a, b=b, base=base, fo=fo, dense=dense,
        tag=np.concatenate(tags) if tags else np.zeros(0, np.int8),
        key=np.concatenate(keys) if keys else np.zeros(0),
        val=np.concatenate(vals) if vals else np.zeros(0, np.int64),
        root=ids[id(dili.root)], max_depth=depth,
        key_lo=float(st.lb), key_hi=float(st.ub),
    )


def _max_depth(root) -> int:
    best = 1
    stack = [(root, 1)]
    while stack:
        nd, d = stack.pop()
        best = max(best, d)
        if isinstance(nd, Internal):
            for c in nd.children:
                stack.append((c, d + 1))
        else:
            for s in nd.slots:
                if isinstance(s, Leaf):
                    stack.append((s, d + 1))
    return best


# ---------------------------------------------------------------------------
# Delta overlay: sorted buffer for inserts between snapshot publishes
# ---------------------------------------------------------------------------


@dataclass
class DeltaOverlay:
    keys: np.ndarray     # f64 [cap], padded with +inf
    vals: np.ndarray     # i64 [cap]
    count: int
    cap: int

    @staticmethod
    def empty(cap: int = 65536) -> "DeltaOverlay":
        return DeltaOverlay(np.full(cap, np.inf), np.zeros(cap, np.int64), 0, cap)

    def insert_batch(self, k: np.ndarray, v: np.ndarray) -> "DeltaOverlay":
        nk = np.concatenate([self.keys[: self.count], np.asarray(k, np.float64)])
        nv = np.concatenate([self.vals[: self.count], np.asarray(v, np.int64)])
        order = np.argsort(nk, kind="stable")
        nk, nv = nk[order], nv[order]
        # dedupe, keep last write
        keep = np.append(np.diff(nk) != 0, True)
        nk, nv = nk[keep], nv[keep]
        cap = self.cap
        while len(nk) > cap:
            cap *= 2
        keys = np.full(cap, np.inf)
        vals = np.zeros(cap, np.int64)
        keys[: len(nk)] = nk
        vals[: len(nk)] = nv
        return DeltaOverlay(keys, vals, len(nk), cap)

    @property
    def full_fraction(self) -> float:
        return self.count / max(self.cap, 1)
