"""Batched device-side DILI search (pure JAX reference path).

Level-synchronous traversal: a batch of Q queries advances together through
the unified node/slot tables (flat.py).  Each round costs one FMA + floor +
clamp + two gathers per query — the TPU adaptation of Algorithm 6's pointer
chase.  Dense (DILI-LO) leaves exit the loop and run the paper's exponential
search (Algorithm 1) as a bounded vectorized probe sequence.

Cost model (DESIGN.md section 9): traversal work is *depth-exact* — the trip
count is the snapshot's true `max_depth` (derived via `resolve_max_depth`,
never hard-coded), and the `early_exit` variant stops the whole batch as soon
as every lane is done, so a batch whose lanes all bottom out at height 3 pays
3 rounds of gathers, not a fixed worst-case scan.  Range queries bisect the
key-sorted pair table built at flatten() time — O(log n + max_hits) per
query — instead of mask-scanning the global slot table.

All functions take the snapshot as a dict of jnp arrays (see `device_arrays`)
so they can be jitted/donated and fed to shard_map without re-tracing on every
publish (shapes are padded to powers of two).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import watchdog
from .flat import TAG_CHILD, TAG_EMPTY, TAG_PAIR, DeltaOverlay, FlatDILI

def predict_slot(a, b, q, fo):
    """floor(a + b*q) clipped to [0, fo).

    CRITICAL: XLA fuses `a + b*q` into an FMA whose single rounding differs
    from numpy's mul-then-add at exact-integer boundaries (e.g. 2.0 vs
    1.999...), sending a query to the wrong slot.  Construction places pairs
    with numpy semantics, so the search MUST evaluate mul-then-add with two
    IEEE roundings — the optimization_barrier blocks the FMA fusion.
    (Found the hard way; regression test: tests/test_search.py::test_fma_consistency.)
    """
    bq = jax.lax.optimization_barrier(b * q)
    return jnp.clip(jnp.floor(a + bq).astype(jnp.int32), 0, fo - 1)


def _pad_pow2(x: np.ndarray, fill) -> np.ndarray:
    n = len(x)
    m = 1 << max(int(math.ceil(math.log2(max(n, 1)))), 0)
    if m == n:
        return x
    out = np.full(m, fill, dtype=x.dtype)
    out[:n] = x
    return out


def device_arrays(flat: FlatDILI, dtype=jnp.float64, pad: bool = True) -> dict:
    """Upload the snapshot; pads table lengths to powers of two so republishes
    reuse the compiled search executable.

    Besides the column tables, the hot traversal reads two row-packed
    mirrors: `node_pack` [n_nodes, 4] = (a, b, base, fo*±1 with the sign
    carrying the dense flag) and `slot_pack` [n_slots, 2] = (key, tag).  One
    level of the walk is then 3 gathers (node row, slot row, payload) instead
    of 8 — each gather is a full memory pass over the batch, so this is the
    single biggest lever on lookup cost.  base/fo are exact in the float
    mantissa (<2^53 at f64; the f32 path keeps tables under 2^24 slots by
    the VMEM-budget dispatch).
    """
    f = flat
    conv = (lambda x, fill: _pad_pow2(x, fill)) if pad else (lambda x, fill: x)
    av = conv(np.asarray(f.a), 0.0)
    bv = conv(np.asarray(f.b), 0.0)
    basev = conv(f.base, 0)
    fov = conv(f.fo, 1)
    densev = conv(f.dense, 0)
    tagv = conv(f.tag, TAG_EMPTY)
    keyv = conv(f.key, 0.0)
    out = dict(
        a=jnp.asarray(av, dtype),
        b=jnp.asarray(bv, dtype),
        base=jnp.asarray(basev, jnp.int32),
        fo=jnp.asarray(fov, jnp.int32),
        dense=jnp.asarray(densev, jnp.int8),
        tag=jnp.asarray(tagv, jnp.int8),
        key=jnp.asarray(keyv, dtype),
        # payloads keep the snapshot's int64 width — serving payloads (KV slot
        # ids, document offsets) may exceed 2^31 (requires x64; under x32 jax
        # silently narrows, matching the f32 kernel path)
        val=jnp.asarray(conv(f.val, -1), jnp.int64),
        # key-sorted pair table (range queries); +inf pads keep searchsorted
        # honest past the populated prefix.  pair_slot (slot ranks) stays
        # host-side on FlatDILI — no device path reads it.
        pair_key=jnp.asarray(conv(f.pair_key, np.inf), dtype),
        pair_val=jnp.asarray(conv(f.pair_val, -1), jnp.int64),
        root=jnp.int32(f.root),
        max_depth=jnp.int32(f.max_depth),
        # static metadata (host Python bool, stripped before jit): standard
        # DILI builds have no dense leaves at all, so the whole Alg.-1 dense
        # probe (32 fixed gather trips) is skipped unless one exists
        has_dense=bool(np.asarray(f.dense).any()),
    )
    # packed mirrors need slot indices exact in the float mantissa; a narrow
    # dtype on a big table falls back to the column layout.  The columns stay
    # resident alongside the mirrors: the dense probe reads tag/key, the
    # post-loop dense check reads dense, and the epoch publisher's retrace
    # detection keys on column shapes — the mirrors only add ~50% node/slot
    # bytes, cheap next to a second hot-path memory pass per level.
    if jnp.finfo(dtype).nmant >= 52 or len(tagv) < (1 << 24):
        out["node_pack"] = jnp.asarray(np.stack(
            [av, bv, basev.astype(np.float64),
             (fov * np.where(densev > 0, -1, 1)).astype(np.float64)],
            axis=1), dtype)
        out["slot_pack"] = jnp.asarray(
            np.stack([keyv, tagv.astype(np.float64)], axis=1), dtype)
    return out


def as_snapshot_dict(idx) -> dict:
    """Accept either the raw snapshot dict or an `api.DeviceSnapshot`
    (duck-typed on `.as_dict()`, so `core` never imports `api`).  Every
    public search entry point funnels through here."""
    if isinstance(idx, dict):
        return idx
    return idx.as_dict()


def resolve_max_depth(idx) -> int:
    """The snapshot's true traversal depth, as a static int.

    Every search call site derives its trip count from the snapshot through
    here (or passes a depth it got from `FlatDILI.max_depth` /
    `SnapshotStore.max_depth` / `ShardedDILI.max_depth`) — hard-coded depths
    are a bug.  Raises inside traced code, where the depth must be threaded
    in explicitly as a Python int.
    """
    md = as_snapshot_dict(idx)["max_depth"]
    if isinstance(md, jax.core.Tracer):
        raise TypeError(
            "resolve_max_depth() needs a concrete snapshot; inside jit/"
            "shard_map pass max_depth explicitly as a static Python int")
    return int(md)


def _split_static(idx: dict) -> tuple[dict, bool]:
    """Strip host-static metadata from the snapshot dict before it crosses a
    jit boundary; returns (array-only dict, has_dense).  `has_dense` defaults
    to True (always-correct) when absent or already traced."""
    hd = idx.get("has_dense", True)
    if not isinstance(hd, (bool, np.bool_)):
        hd = True
    if "has_dense" in idx:
        idx = {k: v for k, v in idx.items() if k != "has_dense"}
    return idx, bool(hd)


# ---------------------------------------------------------------------------
# Unified traversal (Algorithm 6 batched)
# ---------------------------------------------------------------------------


def _traverse_step(idx: dict, q, state, with_stats: bool):
    """One level of the unified traversal; shared by the fixed-trip scan and
    the convergence early-exit while_loop."""
    if with_stats:
        n, done, val, found, nodes, probes = state
    else:
        n, done, val, found = state
    if "node_pack" in idx:
        # row-packed fast path: one node-row gather + one slot-row gather
        # (+ the payload) instead of eight scalar-column gathers per level
        npk = idx["node_pack"][n]                   # [Q, 4]
        a = npk[..., 0]
        b = npk[..., 1]
        base = npk[..., 2].astype(jnp.int32)
        fo_s = npk[..., 3].astype(jnp.int32)
        is_dense = fo_s < 0
        fo = jnp.where(is_dense, -fo_s, fo_s)
        pos = predict_slot(a, b, q, fo)
        s = base + pos
        spk = idx["slot_pack"][s]                   # [Q, 2]
        sk = spk[..., 0]
        t = spk[..., 1].astype(jnp.int8)
    else:
        # column layout (stacked shard tables, kernel fallback dicts)
        a = idx["a"][n]
        b = idx["b"][n]
        fo = idx["fo"][n]
        is_dense = idx["dense"][n] > 0
        pos = predict_slot(a, b, q, fo)
        s = idx["base"][n] + pos
        t = idx["tag"][s]
        sk = idx["key"][s]
    sv = idx["val"][s]
    step_active = ~done & ~is_dense
    is_child = (t == TAG_CHILD) & step_active
    hit = (t == TAG_PAIR) & (sk == q) & step_active
    miss = ((t == TAG_EMPTY) | ((t == TAG_PAIR) & (sk != q))) & step_active
    val = jnp.where(hit, sv, val)
    found = found | hit
    n = jnp.where(is_child, sv.astype(jnp.int32), n)
    done = done | hit | miss | (is_dense & ~done)
    if with_stats:
        nodes = nodes + step_active.astype(jnp.int32)
        probes = probes + step_active.astype(jnp.int32)
        return (n, done, val, found, nodes, probes)
    return (n, done, val, found)


@functools.partial(jax.jit,
                   static_argnames=("max_depth", "with_stats", "early_exit",
                                    "has_dense"))
def _search_batch(idx: dict, queries: jnp.ndarray, max_depth: int,
                  with_stats: bool = False, early_exit: bool = False,
                  has_dense: bool = True):
    q = queries
    # derive carries from q so their varying-manual-axes match inside
    # shard_map bodies (constants would be vma-unvarying and break scan)
    zi = (q * 0).astype(jnp.int32)
    zb = zi > 0
    n0 = zi + idx["root"]

    init = (n0, zb, (zi - 1).astype(idx["val"].dtype), zb)
    if with_stats:
        init = init + (zi, zi)

    if early_exit:
        # convergence early exit: the whole batch stops gathering once every
        # lane is done — a batch bottoming out at height h pays h rounds,
        # not max_depth
        def cond(st):
            return (st[0] < max_depth) & ~jnp.all(st[2])

        def body(st):
            return (st[0] + 1,) + _traverse_step(idx, q, st[1:], with_stats)

        out = jax.lax.while_loop(cond, body, (jnp.int32(0),) + init)
        state = out[1:]
    else:
        def sbody(state, _):
            return _traverse_step(idx, q, state, with_stats), None

        state, _ = jax.lax.scan(sbody, init, None, length=max_depth)

    if with_stats:
        n, done, val, found, nodes, probes = state
    else:
        n, done, val, found = state

    if not has_dense:
        # snapshot has no dense leaves (standard DILI): Algorithm 1's probe
        # phases (32 fixed gather trips) vanish from the computation
        if with_stats:
            return val, found, nodes, probes
        return val, found

    # dense-leaf exit: exponential + binary search (Algorithm 1 lines 2-5)
    is_dense = idx["dense"][n] > 0
    dval, dfound, dprobes = _dense_search(idx, q, n)
    val = jnp.where(is_dense & dfound, dval, val)
    found = found | (is_dense & dfound)
    if with_stats:
        nodes = nodes + is_dense.astype(jnp.int32)
        probes = probes + jnp.where(is_dense, dprobes, 0)
        return val, found, nodes, probes
    return val, found


def search_batch(idx: dict, queries: jnp.ndarray, max_depth: int | None = None,
                 with_stats: bool = False, early_exit: bool = False):
    """Point lookups. Returns (values, found) — values only valid where found.

    `idx` is the device snapshot — either the raw dict or an
    `api.DeviceSnapshot`.  `max_depth=None` derives the trip count from the
    snapshot (`resolve_max_depth`); pass it explicitly only inside traced
    code.  `early_exit=True` swaps the fixed-trip scan for a
    batch-convergence while_loop.  `with_stats` additionally returns
    (nodes_visited, slot_probes) per query — the Table-5 cache-miss proxy
    (each node visit + slot probe = one HBM/cache-line touch in the paper's
    cost model).
    """
    idx = as_snapshot_dict(idx)
    if max_depth is None:
        max_depth = resolve_max_depth(idx)
    idx, has_dense = _split_static(idx)
    return _search_batch(idx, queries, max_depth=max_depth,
                         with_stats=with_stats, early_exit=early_exit,
                         has_dense=has_dense)


def _dense_search(idx: dict, q: jnp.ndarray, n: jnp.ndarray):
    """Vectorized exponential search around the model prediction inside a
    dense leaf [base, base+fo).  Fixed trip counts (14 doubling + 14 binary
    halving cover fo <= 2^14 = 16384 > 2*omega)."""
    a = idx["a"][n]
    b = idx["b"][n]
    fo = idx["fo"][n]
    base = idx["base"][n]
    m1 = jnp.maximum(fo - 1, 0)
    pred = jnp.clip(predict_slot(a, b, q, fo), 0, m1)

    def key_at(i):
        return idx["key"][base + jnp.clip(i, 0, m1)]

    kp = key_at(pred)
    zi = pred * 0
    probes = zi + 1

    # --- exponential phase: grow a distance bound B until it brackets q ----
    going_up = kp < q

    def exp_body(state, _):
        bound, done, probes = state
        up_i = jnp.clip(pred + bound, 0, m1)
        dn_i = jnp.clip(pred - bound, 0, m1)
        need_up = going_up & ~done & (key_at(up_i) < q) & (pred + bound < m1)
        need_dn = ~going_up & ~done & (key_at(dn_i) > q) & (pred - bound > 0)
        probes = probes + (~done).astype(jnp.int32)
        done = done | ~(need_up | need_dn)
        bound = jnp.where(done, bound, bound * 2)
        return (bound, done, probes), None

    (bound, _, probes), _ = jax.lax.scan(
        exp_body, (zi + 1, zi > 0, probes), None, length=16)

    # bracket [lo, hi] guaranteed to contain the lower bound of q
    lo = jnp.where(going_up, pred, jnp.maximum(pred - bound, 0))
    hi = jnp.where(going_up, jnp.minimum(pred + bound, m1), pred)

    # --- binary phase: first index with key >= q ---------------------------
    def bin_body(state, _):
        lo, hi, probes = state
        mid = (lo + hi) // 2
        go = lo < hi
        below = key_at(mid) < q
        lo = jnp.where(go & below, mid + 1, lo)
        hi = jnp.where(go & ~below, mid, hi)
        probes = probes + go.astype(jnp.int32)
        return (lo, hi, probes), None

    (lo, hi, probes), _ = jax.lax.scan(bin_body, (lo, hi, probes), None,
                                       length=16)
    s = base + jnp.clip(lo, 0, m1)
    ok = (idx["tag"][s] == TAG_PAIR) & (idx["key"][s] == q)
    return idx["val"][s], ok, probes


# ---------------------------------------------------------------------------
# Overlay lookup + fused snapshot+overlay search
# ---------------------------------------------------------------------------


def overlay_arrays(ov: DeltaOverlay, dtype=jnp.float64) -> dict:
    # vals stay int64: overlay payloads must round-trip the same width as the
    # snapshot's (int32 silently wrapped payloads above 2^31)
    return dict(keys=jnp.asarray(ov.keys, dtype),
                vals=jnp.asarray(ov.vals, jnp.int64))


@jax.jit
def overlay_lookup(ov: dict, queries: jnp.ndarray):
    i = jnp.searchsorted(ov["keys"], queries)
    i = jnp.clip(i, 0, len(ov["keys"]) - 1)
    found = ov["keys"][i] == queries
    return ov["vals"][i], found


def resolve_overlay(ov: dict, queries: jnp.ndarray, snap_vals: jnp.ndarray,
                    snap_found: jnp.ndarray):
    """Fuse overlay state over snapshot results: an overlay hit wins, and an
    overlay tombstone (``ov["tomb"][i] != 0``) hides a snapshot hit.  `ov`
    without a "tomb" entry behaves as the legacy insert-only overlay."""
    i = jnp.clip(jnp.searchsorted(ov["keys"], queries),
                 0, len(ov["keys"]) - 1)
    hit = ov["keys"][i] == queries
    tomb = ov.get("tomb")
    dead = hit & (tomb[i] > 0) if tomb is not None else hit & False
    live = hit & ~dead
    val = jnp.where(live, ov["vals"][i], snap_vals)
    return val, live | (snap_found & ~dead)


def _search_with_overlay(idx: dict, ov: dict, queries: jnp.ndarray,
                         max_depth: int, early_exit: bool, has_dense: bool):
    v0, f0 = _search_batch(idx, queries, max_depth=max_depth,
                           early_exit=early_exit, has_dense=has_dense)
    return resolve_overlay(ov, queries, v0, f0)


_swo = jax.jit(_search_with_overlay, static_argnums=(3, 4, 5))
_swo_donated = jax.jit(_search_with_overlay, static_argnums=(3, 4, 5),
                       donate_argnums=(2,))


def search_with_overlay(idx: dict, ov: dict, queries: jnp.ndarray,
                        max_depth: int | None = None, *,
                        early_exit: bool = True,
                        donate_queries: bool = False):
    """ONE fused jitted dispatch: snapshot traversal + overlay searchsorted,
    resolving overlay-hit / overlay-tombstone / snapshot-hit (DESIGN.md
    section 8).  The overlay (recent writes) wins over the snapshot;
    tombstones hide snapshot hits.

    `donate_queries=True` donates the query buffer to the computation (the
    caller must not reuse it) — skipped on CPU, which does not support
    donation.  This is the serving read path: `SessionTable`/`OnlineIndex`
    and the per-shard distributed reads route through it, so a query batch
    costs one device dispatch, not a traversal dispatch plus an overlay
    round-trip.
    """
    idx = as_snapshot_dict(idx)
    if max_depth is None:
        max_depth = resolve_max_depth(idx)
    idx, has_dense = _split_static(idx)
    donate = donate_queries and jax.default_backend() != "cpu"
    fn = _swo_donated if donate else _swo
    return fn(idx, ov, queries, max_depth, early_exit, has_dense)


# ---------------------------------------------------------------------------
# Range query: bisect the sorted pair table, gather one bounded window
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("max_hits",))
def _range_query(idx: dict, lo: jnp.ndarray, hi: jnp.ndarray, max_hits: int):
    pk = idx["pair_key"]
    start = jnp.searchsorted(pk, lo, side="left")           # [Q]
    end = jnp.searchsorted(pk, hi, side="left")             # [Q]
    cnt = jnp.maximum(end - start, 0)
    offs = jnp.arange(max_hits)                             # [H]
    valid = offs[None, :] < cnt[:, None]                    # [Q, H]
    g = jnp.clip(start[:, None] + offs[None, :], 0, pk.shape[0] - 1)
    ks = jnp.where(valid, pk[g], jnp.inf)
    vs = jnp.where(valid, idx["pair_val"][g], -1)
    return ks, vs, jnp.minimum(cnt, max_hits).astype(jnp.int32)


def range_query_batch(idx: dict, lo: jnp.ndarray, hi: jnp.ndarray,
                      max_hits: int = 128):
    """For each (lo, hi): the first max_hits pair (key, val)s in [lo, hi),
    ascending, plus the count (saturating at max_hits).

    Two searchsorted bisections of the flatten()-time key-sorted pair table
    locate the window, then ONE bounded gather reads it — O(log n + max_hits)
    per query.  (The previous implementation mask-scanned the entire global
    slot table per query pair: O(n_slots), because DILI's entry arrays are
    not densely packed — Fig. 6b discussion.  The pair table densifies them
    once per publish instead.)
    """
    idx = as_snapshot_dict(idx)
    idx = {k: idx[k] for k in ("pair_key", "pair_val")}
    return _range_query(idx, lo, hi, max_hits=max_hits)


# retrace watchdog: expose per-entry-point traced-executable counts so
# `metrics()["retrace"]["jit_cache_entries"]` can attribute a retrace storm
# to the executable that grew (DESIGN.md section 13)
watchdog.register_jit("search.search_batch", _search_batch)
watchdog.register_jit("search.overlay_lookup", overlay_lookup)
watchdog.register_jit("search.search_with_overlay", _swo)
watchdog.register_jit("search.search_with_overlay_donated", _swo_donated)
watchdog.register_jit("search.range_query", _range_query)


# ---------------------------------------------------------------------------
# Convenience host wrapper
# ---------------------------------------------------------------------------


def lookup_np(idx: dict, queries: np.ndarray, max_depth: int | None = None):
    v, f = search_batch(idx, jnp.asarray(queries), max_depth,
                        early_exit=True)
    return np.asarray(v), np.asarray(f)
