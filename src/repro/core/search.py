"""Batched device-side DILI search (pure JAX reference path).

Level-synchronous traversal: a batch of Q queries advances together through
the unified node/slot tables (flat.py).  Each round costs one FMA + floor +
clamp + two gathers per query — the TPU adaptation of Algorithm 6's pointer
chase.  Dense (DILI-LO) leaves exit the loop and run the paper's exponential
search (Algorithm 1) as a bounded vectorized probe sequence.

All functions take the snapshot as a dict of jnp arrays (see `device_arrays`)
so they can be jitted/donated and fed to shard_map without re-tracing on every
publish (shapes are padded to powers of two).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from .flat import TAG_CHILD, TAG_EMPTY, TAG_PAIR, DeltaOverlay, FlatDILI

def predict_slot(a, b, q, fo):
    """floor(a + b*q) clipped to [0, fo).

    CRITICAL: XLA fuses `a + b*q` into an FMA whose single rounding differs
    from numpy's mul-then-add at exact-integer boundaries (e.g. 2.0 vs
    1.999...), sending a query to the wrong slot.  Construction places pairs
    with numpy semantics, so the search MUST evaluate mul-then-add with two
    IEEE roundings — the optimization_barrier blocks the FMA fusion.
    (Found the hard way; regression test: tests/test_search.py::test_fma_consistency.)
    """
    bq = jax.lax.optimization_barrier(b * q)
    return jnp.clip(jnp.floor(a + bq).astype(jnp.int32), 0, fo - 1)


def _pad_pow2(x: np.ndarray, fill) -> np.ndarray:
    n = len(x)
    m = 1 << max(int(math.ceil(math.log2(max(n, 1)))), 0)
    if m == n:
        return x
    out = np.full(m, fill, dtype=x.dtype)
    out[:n] = x
    return out


def device_arrays(flat: FlatDILI, dtype=jnp.float64, pad: bool = True) -> dict:
    """Upload the snapshot; pads table lengths to powers of two so republishes
    reuse the compiled search executable."""
    f = flat
    ap, bp = (np.asarray(f.a), np.asarray(f.b))
    conv = (lambda x, fill: _pad_pow2(x, fill)) if pad else (lambda x, fill: x)
    return dict(
        a=jnp.asarray(conv(ap, 0.0), dtype),
        b=jnp.asarray(conv(bp, 0.0), dtype),
        base=jnp.asarray(conv(f.base, 0), jnp.int32),
        fo=jnp.asarray(conv(f.fo, 1), jnp.int32),
        dense=jnp.asarray(conv(f.dense, 0), jnp.int8),
        tag=jnp.asarray(conv(f.tag, TAG_EMPTY), jnp.int8),
        key=jnp.asarray(conv(f.key, 0.0), dtype),
        # payloads keep the snapshot's int64 width — serving payloads (KV slot
        # ids, document offsets) may exceed 2^31 (requires x64; under x32 jax
        # silently narrows, matching the f32 kernel path)
        val=jnp.asarray(conv(f.val, -1), jnp.int64),
        root=jnp.int32(f.root),
        max_depth=jnp.int32(f.max_depth),
    )


# ---------------------------------------------------------------------------
# Unified traversal (Algorithm 6 batched)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("max_depth", "with_stats"))
def search_batch(idx: dict, queries: jnp.ndarray, max_depth: int = 24,
                 with_stats: bool = False):
    """Point lookups. Returns (values, found) — values only valid where found.

    with_stats additionally returns (nodes_visited, slot_probes) per query —
    the Table-5 cache-miss proxy (each node visit + slot probe = one
    HBM/cache-line touch in the paper's cost model).
    """
    q = queries
    # derive carries from q so their varying-manual-axes match inside
    # shard_map bodies (constants would be vma-unvarying and break scan)
    zi = (q * 0).astype(jnp.int32)
    zb = zi > 0
    n0 = zi + idx["root"]

    def body(state, _):
        n, done, val, found, nodes, probes = state
        a = idx["a"][n]
        b = idx["b"][n]
        fo = idx["fo"][n]
        is_dense = idx["dense"][n] > 0
        pos = predict_slot(a, b, q, fo)
        s = idx["base"][n] + pos
        t = idx["tag"][s]
        sk = idx["key"][s]
        sv = idx["val"][s]
        step_active = ~done & ~is_dense
        is_child = (t == TAG_CHILD) & step_active
        hit = (t == TAG_PAIR) & (sk == q) & step_active
        miss = ((t == TAG_EMPTY) | ((t == TAG_PAIR) & (sk != q))) & step_active
        val = jnp.where(hit, sv, val)
        found = found | hit
        n = jnp.where(is_child, sv.astype(jnp.int32), n)
        done = done | hit | miss | (is_dense & ~done)
        nodes = nodes + step_active.astype(jnp.int32)
        probes = probes + step_active.astype(jnp.int32)
        return (n, done, val, found, nodes, probes), None

    init = (n0, zb, (zi - 1).astype(idx["val"].dtype), zb, zi, zi)
    (n, done, val, found, nodes, probes), _ = jax.lax.scan(
        body, init, None, length=max_depth)

    # dense-leaf exit: exponential + binary search (Algorithm 1 lines 2-5)
    is_dense = idx["dense"][n] > 0
    dval, dfound, dprobes = _dense_search(idx, q, n)
    val = jnp.where(is_dense & dfound, dval, val)
    found = found | (is_dense & dfound)
    nodes = nodes + is_dense.astype(jnp.int32)
    probes = probes + jnp.where(is_dense, dprobes, 0)
    if with_stats:
        return val, found, nodes, probes
    return val, found


def _dense_search(idx: dict, q: jnp.ndarray, n: jnp.ndarray):
    """Vectorized exponential search around the model prediction inside a
    dense leaf [base, base+fo).  Fixed trip counts (14 doubling + 14 binary
    halving cover fo <= 2^14 = 16384 > 2*omega)."""
    a = idx["a"][n]
    b = idx["b"][n]
    fo = idx["fo"][n]
    base = idx["base"][n]
    m1 = jnp.maximum(fo - 1, 0)
    pred = jnp.clip(predict_slot(a, b, q, fo), 0, m1)

    def key_at(i):
        return idx["key"][base + jnp.clip(i, 0, m1)]

    kp = key_at(pred)
    zi = pred * 0
    probes = zi + 1

    # --- exponential phase: grow a distance bound B until it brackets q ----
    going_up = kp < q

    def exp_body(state, _):
        bound, done, probes = state
        up_i = jnp.clip(pred + bound, 0, m1)
        dn_i = jnp.clip(pred - bound, 0, m1)
        need_up = going_up & ~done & (key_at(up_i) < q) & (pred + bound < m1)
        need_dn = ~going_up & ~done & (key_at(dn_i) > q) & (pred - bound > 0)
        probes = probes + (~done).astype(jnp.int32)
        done = done | ~(need_up | need_dn)
        bound = jnp.where(done, bound, bound * 2)
        return (bound, done, probes), None

    (bound, _, probes), _ = jax.lax.scan(
        exp_body, (zi + 1, zi > 0, probes), None, length=16)

    # bracket [lo, hi] guaranteed to contain the lower bound of q
    lo = jnp.where(going_up, pred, jnp.maximum(pred - bound, 0))
    hi = jnp.where(going_up, jnp.minimum(pred + bound, m1), pred)

    # --- binary phase: first index with key >= q ---------------------------
    def bin_body(state, _):
        lo, hi, probes = state
        mid = (lo + hi) // 2
        go = lo < hi
        below = key_at(mid) < q
        lo = jnp.where(go & below, mid + 1, lo)
        hi = jnp.where(go & ~below, mid, hi)
        probes = probes + go.astype(jnp.int32)
        return (lo, hi, probes), None

    (lo, hi, probes), _ = jax.lax.scan(bin_body, (lo, hi, probes), None,
                                       length=16)
    s = base + jnp.clip(lo, 0, m1)
    ok = (idx["tag"][s] == TAG_PAIR) & (idx["key"][s] == q)
    return idx["val"][s], ok, probes


# ---------------------------------------------------------------------------
# Overlay lookup + combined search
# ---------------------------------------------------------------------------


def overlay_arrays(ov: DeltaOverlay, dtype=jnp.float64) -> dict:
    # vals stay int64: overlay payloads must round-trip the same width as the
    # snapshot's (int32 silently wrapped payloads above 2^31)
    return dict(keys=jnp.asarray(ov.keys, dtype),
                vals=jnp.asarray(ov.vals, jnp.int64))


@jax.jit
def overlay_lookup(ov: dict, queries: jnp.ndarray):
    i = jnp.searchsorted(ov["keys"], queries)
    i = jnp.clip(i, 0, len(ov["keys"]) - 1)
    found = ov["keys"][i] == queries
    return ov["vals"][i], found


def resolve_overlay(ov: dict, queries: jnp.ndarray, snap_vals: jnp.ndarray,
                    snap_found: jnp.ndarray):
    """Fuse overlay state over snapshot results: an overlay hit wins, and an
    overlay tombstone (``ov["tomb"][i] != 0``) hides a snapshot hit.  `ov`
    without a "tomb" entry behaves as the legacy insert-only overlay."""
    i = jnp.clip(jnp.searchsorted(ov["keys"], queries),
                 0, len(ov["keys"]) - 1)
    hit = ov["keys"][i] == queries
    tomb = ov.get("tomb")
    dead = hit & (tomb[i] > 0) if tomb is not None else hit & False
    live = hit & ~dead
    val = jnp.where(live, ov["vals"][i], snap_vals)
    return val, live | (snap_found & ~dead)


def search_with_overlay(idx: dict, ov: dict, queries: jnp.ndarray,
                        max_depth: int = 24):
    """Overlay (recent writes) wins over the snapshot; tombstones hide
    snapshot hits (DESIGN.md section 8)."""
    v0, f0 = search_batch(idx, queries, max_depth)
    return resolve_overlay(ov, queries, v0, f0)


# ---------------------------------------------------------------------------
# Range query: locate both endpoints, then mask-scan the slot table
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("max_hits", "max_depth"))
def range_query_batch(idx: dict, lo: jnp.ndarray, hi: jnp.ndarray,
                      max_hits: int = 128, max_depth: int = 24):
    """For each (lo, hi): gather up to max_hits pair keys in [lo, hi).

    DILI's entry arrays are not densely packed (Fig. 6b discussion), so a scan
    must skip EMPTY/CHILD slots; we vectorize by scanning the *global* slot
    table window around the leaf holding `lo` — leaves are laid out in BFS
    order so siblings are contiguous (flat.py).
    """
    tag = idx["tag"]
    key = idx["key"]

    in_range = (tag == TAG_PAIR)

    def one(lo1, hi1):
        sel = in_range & (key >= lo1) & (key < hi1)
        # top-k by position: compress indices of selected slots
        idxs = jnp.nonzero(sel, size=max_hits, fill_value=-1)[0]
        ks = jnp.where(idxs >= 0, key[jnp.clip(idxs, 0, None)], jnp.inf)
        vs = jnp.where(idxs >= 0, idx["val"][jnp.clip(idxs, 0, None)], -1)
        order = jnp.argsort(ks)
        return ks[order], vs[order], (idxs >= 0).sum()

    return jax.vmap(one)(lo, hi)


# ---------------------------------------------------------------------------
# Convenience host wrapper
# ---------------------------------------------------------------------------


def lookup_np(idx: dict, queries: np.ndarray, max_depth: int = 24):
    v, f = search_batch(idx, jnp.asarray(queries), max_depth)
    return np.asarray(v), np.asarray(f)
