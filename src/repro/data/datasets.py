"""Synthetic key datasets mirroring the paper's five workloads (section 7.1),
deterministic per (name, n, seed).  Real SOSD files are 200-800M uint64 keys;
these generators reproduce their distributional shapes at any scale:

  fb      — heavy-tail pareto mixture (Facebook user ids' skew)
  wikits  — near-sequential integer timestamps with bursts
  osm     — multi-modal clustered cell ids
  books   — smooth power-law (Amazon book popularity ranks)
  logn    — the paper's lognormal(0, 1)
"""

from __future__ import annotations

import zlib

import numpy as np


def generate(name: str, n: int, seed: int = 0) -> np.ndarray:
    # crc32, not hash(): str hashing is salted per process, which silently
    # made "deterministic" datasets differ between runs/CI jobs
    rng = np.random.default_rng(seed + zlib.crc32(name.encode()) % 65536)
    over = int(n * 1.25) + 16
    if name == "logn":
        raw = rng.lognormal(0.0, 1.0, over)
    elif name == "fb":
        raw = np.concatenate([
            (rng.pareto(1.05, over // 2) + 1) * 1e6,
            rng.uniform(0, 5e6, over - over // 2)])
    elif name == "wikits":
        steps = rng.integers(1, 4, over).astype(np.float64)
        bursts = rng.random(over) < 0.01
        steps[bursts] += rng.integers(100, 10000, int(bursts.sum()))
        raw = 1.6e9 + np.cumsum(steps)
    elif name == "osm":
        centers = rng.uniform(0, 2**40, 64)
        raw = (centers[rng.integers(0, 64, over)]
               + rng.normal(0, 2**20, over))
    elif name == "books":
        raw = np.cumsum(rng.pareto(1.6, over) + 0.1) * 1e3
    else:
        raise ValueError(name)
    keys = np.unique(raw.astype(np.float64))
    rng.shuffle(keys)            # unique + sort below
    keys = np.sort(keys[:n])
    return keys


ALL_DATASETS = ("fb", "wikits", "osm", "books", "logn")
