"""Deterministic, checkpointable token pipeline.

Batches come either from a synthetic stream (seeded, position-addressable so
a restore resumes mid-epoch exactly) or from a DILI-backed RecordStore
(documents looked up by key, packed/padded to seq_len).
"""

from __future__ import annotations

import numpy as np


class SyntheticLM:
    """position-addressable synthetic corpus: batch(i) is pure in (seed, i).

    The "language" has learnable structure (token t+1 depends on token t via
    a fixed random permutation + noise) so tiny models visibly learn."""

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0,
                 noise: float = 0.1):
        self.vocab = vocab
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed
        self.noise = noise
        rng = np.random.default_rng(seed)
        self.perm = rng.permutation(vocab)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = np.empty((self.batch, self.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, self.vocab, self.batch)
        for t in range(1, self.seq_len + 1):
            nxt = self.perm[toks[:, t - 1]]
            noise = rng.random(self.batch) < self.noise
            toks[:, t] = np.where(noise,
                                  rng.integers(0, self.vocab, self.batch),
                                  nxt)
        return dict(tokens=toks[:, :-1], labels=toks[:, 1:])


class StorePipeline:
    """Samples document keys per step (deterministic), fetches via the DILI
    record store, packs to fixed [batch, seq_len]."""

    def __init__(self, store, keys: np.ndarray, seq_len: int, batch: int,
                 seed: int = 0):
        self.store = store
        self.keys = np.asarray(keys)
        self.seq_len = seq_len
        self.batch = batch
        self.seed = seed

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        picks = self.keys[rng.integers(0, len(self.keys), self.batch)]
        offs, lens, found = self.store.lookup(picks)
        assert found.all(), "pipeline lookup missed a key"
        out = np.zeros((self.batch, self.seq_len + 1), np.int32)
        for i, (o, l) in enumerate(zip(offs, lens)):
            l = min(int(l), self.seq_len + 1)
            out[i, :l] = self.store.arena[o:o + l]
        return dict(tokens=out[:, :-1], labels=out[:, 1:])
