"""DILI-indexed record store: the training data pipeline's random-access path.

Variable-length records (token sequences) are stored in one flat token arena.
A `repro.api.LearnedIndex` maps document key -> doc ordinal; a sidecar table
maps ordinal -> (offset, length).  Batched `lookup` runs the engine's
batched device search — the paper's technique IS the pipeline's index — and
new documents are overlay upserts (visible immediately) that fold through
DILI's Algorithm-7 insert on `publish()`/merge.
"""

from __future__ import annotations

import numpy as np

from ..api import IndexConfig, LearnedIndex, manual_merge_policy


class RecordStore:
    def __init__(self, doc_keys: np.ndarray, docs: list[np.ndarray],
                 sample_stride: int = 4,
                 config: IndexConfig | None = None):
        order = np.argsort(doc_keys)
        doc_keys = np.asarray(doc_keys, np.float64)[order]
        docs = [np.asarray(docs[i], np.int32) for i in order]
        self.arena = (np.concatenate(docs) if docs
                      else np.zeros(0, np.int32))
        lens = np.array([len(d) for d in docs], np.int64)
        self.offsets = np.concatenate([[0], np.cumsum(lens)[:-1]])
        self.lengths = lens
        ordinals = np.arange(len(docs), dtype=np.int64)
        # ingest-controlled pipeline: merges happen at publish(), not on a
        # write-pressure trigger mid-epoch
        cfg = config or IndexConfig(sample_stride=sample_stride,
                                    merge=manual_merge_policy())
        self.index = LearnedIndex.build(doc_keys, ordinals, config=cfg)

    @property
    def dili(self):
        """The host writer (introspection)."""
        return self.index.host

    # -- write path ---------------------------------------------------------

    def add(self, key: float, tokens: np.ndarray) -> None:
        self.offsets = np.append(self.offsets, len(self.arena))
        self.lengths = np.append(self.lengths, len(tokens))
        self.arena = np.concatenate([self.arena,
                                     np.asarray(tokens, np.int32)])
        self.index.upsert(float(key), len(self.offsets) - 1)

    def publish(self) -> None:
        """Fold pending adds through the host tree (snapshot republish)."""
        self.index.flush()

    # -- read path ----------------------------------------------------------

    def lookup(self, keys) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batched: returns (offsets, lengths, found)."""
        v, f = self.index.lookup(keys)
        ords = np.where(f, v, 0)
        return self.offsets[ords], self.lengths[ords], f

    def fetch(self, key: float, pad_to: int = 0) -> np.ndarray | None:
        off, ln, f = self.lookup(np.array([key]))
        if not f[0]:
            return None
        seq = self.arena[off[0]: off[0] + ln[0]]
        if pad_to and len(seq) < pad_to:
            seq = np.pad(seq, (0, pad_to - len(seq)))
        return seq
