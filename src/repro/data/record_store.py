"""DILI-indexed record store: the training data pipeline's random-access path.

Variable-length records (token sequences) are stored in one flat token arena.
The DILI maps document key -> doc ordinal (int32-safe for the TPU kernel
path); a sidecar table maps ordinal -> (offset, length).  Batched `lookup`
runs the device-side batched search (core/search.py) — the paper's technique
IS the pipeline's index.  New documents go through DILI's Algorithm-7 insert
+ snapshot republish.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ..core import search as S
from ..core.dili import DILI, bulk_load
from ..core.flat import flatten


class RecordStore:
    def __init__(self, doc_keys: np.ndarray, docs: list[np.ndarray],
                 sample_stride: int = 4):
        order = np.argsort(doc_keys)
        doc_keys = np.asarray(doc_keys, np.float64)[order]
        docs = [np.asarray(docs[i], np.int32) for i in order]
        self.arena = (np.concatenate(docs) if docs
                      else np.zeros(0, np.int32))
        lens = np.array([len(d) for d in docs], np.int64)
        self.offsets = np.concatenate([[0], np.cumsum(lens)[:-1]])
        self.lengths = lens
        ordinals = np.arange(len(docs), dtype=np.int64)
        self.dili: DILI = bulk_load(doc_keys, ordinals,
                                    sample_stride=sample_stride)
        self._republish()

    def _republish(self):
        self.flat = flatten(self.dili)
        self.idx = S.device_arrays(self.flat)

    # -- write path ---------------------------------------------------------

    def add(self, key: float, tokens: np.ndarray) -> None:
        self.offsets = np.append(self.offsets, len(self.arena))
        self.lengths = np.append(self.lengths, len(tokens))
        self.arena = np.concatenate([self.arena,
                                     np.asarray(tokens, np.int32)])
        self.dili.insert(float(key), len(self.offsets) - 1)

    def publish(self) -> None:
        """Make writes visible to the device reader (snapshot swap)."""
        self._republish()

    # -- read path ----------------------------------------------------------

    def lookup(self, keys) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batched: returns (offsets, lengths, found)."""
        v, f = S.search_batch(self.idx, jnp.asarray(keys, jnp.float64),
                              max_depth=self.flat.max_depth, early_exit=True)
        v = np.asarray(v).astype(np.int64)
        f = np.asarray(f)
        ords = np.where(f, v, 0)
        return self.offsets[ords], self.lengths[ords], f

    def fetch(self, key: float, pad_to: int = 0) -> np.ndarray | None:
        off, ln, f = self.lookup(np.array([key]))
        if not f[0]:
            return None
        seq = self.arena[off[0]: off[0] + ln[0]]
        if pad_to and len(seq) < pad_to:
            seq = np.pad(seq, (0, pad_to - len(seq)))
        return seq
