"""Durability subsystem (DESIGN.md section 14): write-ahead log,
checkpointing, and crash recovery behind `api.IndexConfig.durability`.

Hard/soft state split: the overlay write stream is hard state — appended
to a per-shard CRC32 WAL before the engine acknowledges the write — and
everything derived (device snapshot, pair table, maintenance accounting)
is soft state, rebuilt at `recover()` time from the newest valid
checkpoint plus the WAL tail.
"""

from .config import DurabilityConfig, FSYNC_MODES
from .manager import DurabilityManager
from .recovery import recover
from .wal import OP_DELETE, OP_UPSERT, WalWriter, read_records

__all__ = [
    "DurabilityConfig",
    "DurabilityManager",
    "FSYNC_MODES",
    "OP_DELETE",
    "OP_UPSERT",
    "WalWriter",
    "read_records",
    "recover",
]
