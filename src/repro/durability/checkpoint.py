"""Index checkpoints: the durable base state the WAL tail replays onto.

Reuses `repro.ft.checkpoint`'s atomic-publish protocol — stage into
`step_X.tmp/`, `os.replace` to publish, best-effort `latest` pointer,
newest-first corruption-fallback walk (`step_candidates`) — over a
different payload: the index's logical content (the key-sorted live
pair table from `items()`) plus a manifest binding it to the WAL:

    <ckpt_dir>/step_NNNNNNNN/
        state.npz        # keys f64[n], vals i64[n]
        manifest.json    # step, epoch, wal_lsns, checksums, config
    <ckpt_dir>/latest

`wal_lsns` maps shard id -> the shard's next lsn AT CAPTURE TIME, sampled
BEFORE `items()` is read: any record racing past the sample is both in
the checkpoint and replayed on top of it, and replay in lsn order is
idempotent (last-write-wins), so the overlap is harmless — the other
order could lose acked writes.

The only difference from `ft.publish_dir` is a crash-injection point
between the `os.replace` and the `latest` move (`ckpt.mid_publish`): the
published step is then fully valid but unpointed, which is exactly the
state the candidates walk must tolerate.
"""

from __future__ import annotations

import json
import os
import shutil
import zlib

import numpy as np

from ..ft import checkpoint as ftck
from . import hooks

MANIFEST_VERSION = "dili.ckpt/1"


def write_checkpoint(ckpt_dir: str, step: int, keys: np.ndarray,
                     vals: np.ndarray, *, epoch: int, wal_lsns: dict,
                     config: dict | None = None, keep: int = 3) -> str:
    """Stage + atomically publish one checkpoint; returns its path."""
    keys = np.ascontiguousarray(keys, np.float64)
    vals = np.ascontiguousarray(vals, np.int64)
    name = ftck.step_name(step)
    tmp = ftck.make_tmp_dir(ckpt_dir, name)
    np.savez(os.path.join(tmp, "state.npz"), keys=keys, vals=vals)
    manifest = dict(version=MANIFEST_VERSION, step=step, epoch=epoch,
                    n_pairs=int(len(keys)),
                    wal_lsns={str(s): int(l) for s, l in wal_lsns.items()},
                    checksums=dict(keys=zlib.crc32(keys.tobytes()),
                                   vals=zlib.crc32(vals.tobytes())),
                    config=config or {})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    hooks.crash_point("ckpt.pre_publish")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    hooks.crash_point("ckpt.mid_publish")
    ftck.write_latest(ckpt_dir, name)
    ftck.gc_steps(ckpt_dir, keep)
    return final


def _load_one(path: str):
    """(manifest, keys, vals) of one published step dir; raises IOError on
    any corruption (bad json, checksum mismatch, truncated npz)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "state.npz")) as z:
        keys = np.asarray(z["keys"], np.float64)
        vals = np.asarray(z["vals"], np.int64)
    if len(keys) != manifest["n_pairs"] or len(vals) != manifest["n_pairs"]:
        raise IOError(f"pair count mismatch in {path}")
    if (zlib.crc32(keys.tobytes()) != manifest["checksums"]["keys"]
            or zlib.crc32(vals.tobytes()) != manifest["checksums"]["vals"]):
        raise IOError(f"state checksum mismatch in {path}")
    return manifest, keys, vals


def iter_checkpoints(ckpt_dir: str):
    """Yield (name, manifest, keys, vals) for every VALID checkpoint,
    newest first (the `latest` pointer promoted), silently walking past
    corrupt or partial ones — the recovery fallback order."""
    if not os.path.isdir(ckpt_dir):
        return
    for name in ftck.step_candidates(ckpt_dir):
        try:
            manifest, keys, vals = _load_one(os.path.join(ckpt_dir, name))
        except Exception:              # corrupt/partial: fall back
            continue
        yield name, manifest, keys, vals


def retained_manifests(ckpt_dir: str) -> list[dict]:
    """Manifests of every currently-valid checkpoint (any order) — the
    input to the WAL truncation watermark: a segment may only be purged
    once EVERY retained checkpoint's watermark has passed it, so a
    corrupt newest checkpoint can still fall back and replay further."""
    return [m for _, m, _, _ in iter_checkpoints(ckpt_dir)]
