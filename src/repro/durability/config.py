"""`DurabilityConfig`: the knob set of the durability subsystem.

Threaded through `api.IndexConfig.durability`; `None` (the default
everywhere) means the legacy in-memory index — no WAL, no checkpoints,
`save()`/`load()` only.  The directory layout it governs:

    <dir>/wal/shard_00000/seg_0000000000000000.wal   (one WAL per shard)
    <dir>/ckpt/step_00000000/{state.npz, manifest.json}
    <dir>/ckpt/latest

fsync policy semantics (the group-commit knob):

  "always"    — fsync after every acknowledged append: a record survives
                both process death AND power loss before the caller sees
                the write return.
  "interval"  — flush to the OS per append (survives process death),
                fsync at most once per `fsync_interval_s` (bounded
                power-loss window, amortized syscall cost).
  "off"       — flush to the OS per append only; no fsync is ever issued
                (crash-consistent against process death, not power loss).
"""

from __future__ import annotations

from dataclasses import dataclass

FSYNC_MODES = ("always", "interval", "off")


@dataclass(frozen=True)
class DurabilityConfig:
    """Durability knobs (DESIGN.md section 14).

    dir                     : root directory for the WAL + checkpoints.
    fsync                   : "always" | "interval" | "off" (see module
                              docstring).
    fsync_interval_s        : group-commit window for fsync="interval".
    checkpoint_every_merges : write a checkpoint after every N-th merge
                              publish (1 = after each; the checkpoint is
                              what lets the WAL truncate).
    keep_checkpoints        : published checkpoints retained; the WAL is
                              only truncated below the OLDEST retained
                              checkpoint's watermark so a corrupt newest
                              checkpoint can still fall back and replay a
                              longer tail.
    """

    dir: str = ""
    fsync: str = "interval"
    fsync_interval_s: float = 0.05
    checkpoint_every_merges: int = 1
    keep_checkpoints: int = 3

    def __post_init__(self):
        if not self.dir:
            raise ValueError("DurabilityConfig.dir is required")
        if self.fsync not in FSYNC_MODES:
            raise ValueError(f"unknown fsync mode {self.fsync!r}; "
                             f"expected one of {FSYNC_MODES}")
        if self.checkpoint_every_merges < 1:
            raise ValueError("checkpoint_every_merges must be >= 1")
        if self.keep_checkpoints < 1:
            raise ValueError("keep_checkpoints must be >= 1")

    # -- (de)serialization for api.IndexConfig round-trips -------------------

    def to_json_dict(self) -> dict:
        return dict(dir=self.dir, fsync=self.fsync,
                    fsync_interval_s=self.fsync_interval_s,
                    checkpoint_every_merges=self.checkpoint_every_merges,
                    keep_checkpoints=self.keep_checkpoints)

    @classmethod
    def from_json_dict(cls, d: dict) -> "DurabilityConfig":
        return cls(**d)
