"""Cooperative crash-injection points for the durability subsystem.

`tests/crashkit.py` arms a named kill point in a child process via the
environment:

    DILI_CRASH_POINT="<point>:<n>"     # SIGKILL on the n-th hit of <point>

and the durability code calls `crash_point("<point>")` at the protocol
boundaries worth dying at (after a WAL append, before/inside a checkpoint
publish, mid-WAL-record).  Unarmed (the production case) a crash point is
one cached string comparison; SIGKILL — not sys.exit — because the whole
point is that NO cleanup runs (no buffer flush, no atexit, no close).

The points:

  wal.append        — the batch's WAL record is fully written + synced
                      (the write is durable; the caller never saw the ack)
  wal.mid_record    — half a WAL record is on disk (torn tail)
  ckpt.pre_publish  — checkpoint staged in the .tmp dir, not yet published
  ckpt.mid_publish  — step dir published (os.replace done), `latest`
                      pointer not yet moved
"""

from __future__ import annotations

import os
import signal

ENV_VAR = "DILI_CRASH_POINT"

_armed_point: str | None = None
_remaining: int = 0
_parsed_env: str | None = None


def _parse() -> None:
    """(Re)parse the env var; cached per value so the unarmed hot path is
    one dict lookup + string compare."""
    global _armed_point, _remaining, _parsed_env
    spec = os.environ.get(ENV_VAR, "")
    if spec == _parsed_env:
        return
    _parsed_env = spec
    if not spec:
        _armed_point, _remaining = None, 0
        return
    point, _, n = spec.partition(":")
    _armed_point = point
    _remaining = int(n) if n else 1


def armed(point: str) -> bool:
    """Whether `point` is the armed kill point (used to gate test-only
    code shapes, e.g. the split two-write WAL record path)."""
    _parse()
    return _armed_point == point


def crash_point(point: str) -> None:
    """Die (SIGKILL, no cleanup) if this is the armed point's n-th hit."""
    global _remaining
    if not armed(point):
        return
    _remaining -= 1
    if _remaining <= 0:
        os.kill(os.getpid(), signal.SIGKILL)
