"""`DurabilityManager`: the hard/soft state split behind one object.

The overlay write stream is HARD state: `LearnedIndex.upsert/delete`
append to the per-shard WAL *before* the engine applies the write, so an
acknowledged write is always replayable.  Everything derived — device
snapshot, flattened pair table, maintenance accounting — is SOFT state,
rebuilt at recovery from checkpoint + WAL tail and never persisted
directly.

Lifecycle:

  attach(cfg, index, fresh=True)   at build: wipe any previous durability
                                   state (a rebuild supersedes it — use
                                   `LearnedIndex.recover` to resurrect),
                                   write the base checkpoint, start one
                                   `WalWriter` per engine shard.
  attach(cfg, index, fresh=False,  at recovery: continue each shard's lsn
         resume_lsns=...)          sequence where the replayed log ended,
                                   write a fresh base checkpoint, keep old
                                   segments until retained watermarks pass.
  log(op, keys, vals, epoch, ...)  append one batch (routed per shard)
                                   before the engine acknowledges it.
  on_merge_publish()               engine callback after each merge
                                   publish: every `checkpoint_every_merges`
                                   merges, checkpoint + rotate + truncate.
  sync() / close() / abandon()     durability barrier / clean shutdown /
                                   crash simulation (no final fsync).

Threading: `log` runs on the writer thread; `on_merge_publish` may run on
the maintenance worker (background merges).  A single lock serializes
checkpointing against appends and against concurrent publish callbacks;
the watermark is sampled under that lock BEFORE `items()` so replay
overlap stays idempotent (see durability.checkpoint).
"""

from __future__ import annotations

import os
import shutil
import threading

import numpy as np

from . import checkpoint as ckpt
from . import hooks, wal
from .config import DurabilityConfig


class DurabilityManager:
    def __init__(self, cfg: DurabilityConfig, index, *,
                 start_lsns: dict[int, int] | None = None,
                 extra_lsns: dict[int, int] | None = None,
                 start_step: int = 0):
        self.cfg = cfg
        self.index = index
        self.wal_dir = os.path.join(cfg.dir, "wal")
        self.ckpt_dir = os.path.join(cfg.dir, "ckpt")
        self._lock = threading.Lock()
        self._closed = False
        self._step = start_step
        self._merges_since_ckpt = 0
        # watermarks carried for shard dirs WITHOUT an active writer (the
        # shard count shrank across a recovery); persisted into every
        # manifest so their stale segments age out with the checkpoints
        self._extra_lsns = dict(extra_lsns or {})
        start_lsns = start_lsns or {}
        n = getattr(index._engine, "n_wal_shards", 1)
        self.writers = {
            s: wal.WalWriter(wal.shard_dir(self.wal_dir, s),
                             fsync=cfg.fsync,
                             fsync_interval_s=cfg.fsync_interval_s,
                             start_lsn=start_lsns.get(s, 0))
            for s in range(n)}

    # -- construction --------------------------------------------------------

    @classmethod
    def attach(cls, cfg: DurabilityConfig, index, *, fresh: bool,
               resume_lsns: dict[int, int] | None = None,
               start_step: int = 0) -> "DurabilityManager":
        """Create the manager for `index` and publish its base checkpoint.

        fresh=True (a new `build`) wipes any existing WAL/checkpoint state
        under `cfg.dir` first.  fresh=False (post-recovery) continues each
        shard's lsn numbering at `resume_lsns` and leaves old segments for
        the watermark GC; shard dirs beyond the rebuilt engine's shard
        count keep their replayed end-lsn as a manifest-carried watermark.
        """
        if fresh and os.path.isdir(cfg.dir):
            shutil.rmtree(os.path.join(cfg.dir, "wal"), ignore_errors=True)
            shutil.rmtree(os.path.join(cfg.dir, "ckpt"), ignore_errors=True)
        resume = dict(resume_lsns or {})
        n = getattr(index._engine, "n_wal_shards", 1)
        extra = {s: l for s, l in resume.items() if s >= n}
        mgr = cls(cfg, index, start_lsns=resume, extra_lsns=extra,
                  start_step=start_step)
        mgr.checkpoint()
        return mgr

    # -- the write path ------------------------------------------------------

    def log(self, op: int, keys: np.ndarray, vals: np.ndarray | None,
            epoch: int, shard_ids: np.ndarray) -> None:
        """Append one acknowledged-to-be batch, routed to each shard's
        log.  Within a shard the per-key order is append order; across
        shards the key ranges are disjoint, so no cross-log ordering is
        needed."""
        with self._lock:
            if self._closed:
                raise RuntimeError("durability manager is closed")
            if len(self.writers) == 1:
                self.writers[0].append(op, keys, vals, epoch)
            else:
                for s in np.unique(shard_ids):
                    m = shard_ids == s
                    self.writers[int(s)].append(
                        op, keys[m], None if vals is None else vals[m],
                        epoch)
        hooks.crash_point("wal.append")

    def sync(self) -> None:
        """Durability barrier: fsync every shard log (facade `flush()`)."""
        with self._lock:
            if self._closed:
                return
            for w in self.writers.values():
                w.sync()

    # -- checkpointing -------------------------------------------------------

    def on_merge_publish(self) -> None:
        """Engine callback after a merge publish; checkpoints every
        `checkpoint_every_merges`-th call."""
        if self._closed:
            return
        self._merges_since_ckpt += 1
        if self._merges_since_ckpt >= self.cfg.checkpoint_every_merges:
            self._merges_since_ckpt = 0
            self.checkpoint()

    def checkpoint(self) -> str | None:
        """Capture `items()` into a new published checkpoint, rotate every
        shard log, and truncate segments below the oldest retained
        watermark.  Serialized: concurrent callers coalesce."""
        with self._lock:
            if self._closed:
                return None
            # watermark BEFORE items(): records racing past this sample
            # end up both in the checkpoint and in the replayed tail —
            # idempotent; sampling after could lose them
            lsns = {s: w.next_lsn for s, w in self.writers.items()}
            lsns.update(self._extra_lsns)
            keys, vals = self.index.items()
            self._step += 1
            path = ckpt.write_checkpoint(
                self.ckpt_dir, self._step, keys, vals,
                epoch=self.index.epoch, wal_lsns=lsns,
                config=self.index.config.to_json_dict(),
                keep=self.cfg.keep_checkpoints)
            for w in self.writers.values():
                w.rotate()
            self._truncate()
            return path

    def _truncate(self) -> None:
        """Purge WAL segments below the MIN watermark over every retained
        valid checkpoint (so a corrupt newer checkpoint can still fall
        back to an older one and replay a longer tail)."""
        manifests = ckpt.retained_manifests(self.ckpt_dir)
        if not manifests:
            return
        for s, w in self.writers.items():
            marks = [int(m["wal_lsns"].get(str(s), 0)) for m in manifests]
            w.purge_upto(min(marks))
        for s, end in list(self._extra_lsns.items()):
            marks = [int(m["wal_lsns"].get(str(s), 0)) for m in manifests]
            d = wal.shard_dir(self.wal_dir, s)
            wal.purge_dir_upto(d, min(marks))
            if not wal.list_segments(d):
                shutil.rmtree(d, ignore_errors=True)
                del self._extra_lsns[s]

    # -- shutdown ------------------------------------------------------------

    def close(self) -> None:
        """Clean shutdown: final fsync, close every log.  Idempotent."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            for w in self.writers.values():
                w.close()

    def abandon(self) -> None:
        """Crash simulation (tests): stop WITHOUT the final fsync.  Acked
        records were flushed to the OS per append, so reopening the
        directory sees exactly what a SIGKILL would have left."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            for w in self.writers.values():
                w.abandon()
