"""`recover(dir)`: checkpoint + WAL-tail replay -> a serving index.

The recovery protocol (DESIGN.md section 14):

  1. load    — walk published checkpoints newest-first (`latest` pointer
               promoted), skipping corrupt/partial ones; a corrupt newest
               checkpoint falls back to the previous valid one, whose
               smaller watermark simply means a longer tail to replay
               (truncation keeps segments until EVERY retained
               checkpoint's watermark passes them).
  2. replay  — rebuild the engine from the checkpoint pair table (the
               normal `build` path: bulk load, re-shard elastically, soft
               state re-derived), then apply each shard's WAL tail from
               the checkpoint's watermark through the normal facade
               upsert/delete fold path, in lsn order.  A torn trailing
               record truncates the tail at the first bad CRC.
  3. publish — attach a fresh `DurabilityManager` (new base checkpoint,
               lsn numbering continued, old segments left to age out),
               re-arming the WAL for new writes.

Spans `recovery.load` / `recovery.replay` / `recovery.publish` and the
`recovery.*` counters are recorded UNCONDITIONALLY on the rebuilt index's
telemetry (bypassing the `enabled` gate): recovery is rare and its
observability is the point — a disabled-telemetry index still shows the
recovery in `metrics()`.
"""

from __future__ import annotations

import os
import time

import numpy as np

from . import checkpoint as ckpt
from . import wal
from .config import DurabilityConfig


def recover(dur_dir: str, config=None, engine: str | None = None):
    """Rebuild a `LearnedIndex` from the durability directory `dur_dir`
    (an `IndexConfig.durability.dir`).  `config` overrides the
    checkpoint-recorded `IndexConfig` (its `durability` field is forced
    back to this directory); `engine` is a convenience engine override.
    Raises FileNotFoundError when no valid checkpoint exists."""
    from ..api.config import IndexConfig
    from ..api.index import LearnedIndex
    from dataclasses import replace

    ckpt_dir = os.path.join(dur_dir, "ckpt")
    wal_dir = os.path.join(dur_dir, "wal")
    t0 = time.perf_counter()
    chosen = None
    for name, manifest, keys, vals in ckpt.iter_checkpoints(ckpt_dir):
        chosen = (name, manifest, keys, vals)
        break
    if chosen is None:
        raise FileNotFoundError(
            f"no valid checkpoint under {ckpt_dir!r}; nothing to recover")
    name, manifest, keys, vals = chosen
    if config is None:
        config = IndexConfig.from_json_dict(manifest["config"])
    if engine is not None:
        config = replace(config, engine=engine)
    dur_cfg = replace(config.durability or DurabilityConfig(dir=dur_dir),
                      dir=dur_dir)
    load_s = time.perf_counter() - t0

    # -- replay: rebuild (durability detached — the manager re-attaches
    # with the POST-replay base checkpoint) then fold the tails ----------
    t0 = time.perf_counter()
    ix = LearnedIndex.build(keys, vals, config=replace(config,
                                                       durability=None))
    watermarks = {int(s): int(l)
                  for s, l in manifest["wal_lsns"].items()}
    resume_lsns: dict[int, int] = {}
    n_records = n_tail_shards = 0
    for s in sorted(_shard_ids_on_disk(wal_dir) | set(watermarks)):
        d = wal.shard_dir(wal_dir, s)
        from_lsn = watermarks.get(s, 0)
        recs = wal.read_records(d, from_lsn=from_lsn)
        for r in recs:
            if r["op"] == wal.OP_UPSERT:
                ix.upsert(r["keys"], r["vals"])
            else:
                ix.delete(r["keys"])
        n_records += len(recs)
        if recs:
            n_tail_shards += 1
        resume_lsns[s] = (recs[-1]["lsn"] + 1 if recs
                          else max(from_lsn, wal.end_lsn(d)))
    replay_s = time.perf_counter() - t0

    # -- publish: new base checkpoint, WAL re-armed ----------------------
    t0 = time.perf_counter()
    ix.config = replace(config, durability=dur_cfg)
    ix._attach_durability(fresh=False, resume_lsns=resume_lsns,
                          start_step=int(manifest["step"]))
    publish_s = time.perf_counter() - t0

    # recovery observability is unconditional (see module docstring)
    tel = ix.telemetry
    tel.spans.record("recovery.load", load_s, checkpoint=name)
    tel.spans.record("recovery.replay", replay_s, records=n_records,
                     shards=n_tail_shards)
    tel.spans.record("recovery.publish", publish_s)
    tel.metrics.count("recovery.count")
    tel.metrics.count("recovery.replayed_records", n_records)
    return ix


def _shard_ids_on_disk(wal_dir: str) -> set[int]:
    if not os.path.isdir(wal_dir):
        return set()
    return {int(n[6:]) for n in os.listdir(wal_dir)
            if n.startswith("shard_")}
