"""Append-only write-ahead log: CRC32-per-record segments, one log per
shard (DESIGN.md section 14).

Record layout (little-endian, `_HEADER` then payload):

    magic  u32   0x57414C31 ("WAL1")
    crc    u32   crc32 over header[8:] + payload (everything below)
    lsn    u64   per-shard log sequence number, dense and monotone
    epoch  u64   engine epoch at append time (diagnostic tag)
    op     u8    1 = upsert, 2 = delete   (+3 pad bytes)
    count  u32   number of keys
    keys   f64[count]
    vals   i64[count]      (upsert only)

One facade write batch = one record = one group commit: the python buffer
is flushed to the OS per append (an in-process crash never loses an acked
record) and fsync'd per the `DurabilityConfig.fsync` policy.

Segments are named `seg_<start_lsn:016d>.wal`; a segment's lsn range is
[its start, the next segment's start), so truncation (`purge_upto`) never
has to read a file: a closed segment is deletable exactly when the NEXT
segment's start lsn is at or below the checkpoint watermark.  The active
segment is never deleted.

Replay (`read_records`) walks segments in lsn order and applies the
torn-tail rule: the first bad magic/CRC/short-read OR lsn discontinuity
ends the log — everything before it is the durable prefix, everything
after is garbage from a crashed writer and is ignored.
"""

from __future__ import annotations

import os
import struct
import zlib

import numpy as np

from . import hooks

MAGIC = 0x57414C31
OP_UPSERT, OP_DELETE = 1, 2

_HEADER = struct.Struct("<IIQQBxxxI")


def shard_dir(wal_dir: str, shard: int) -> str:
    return os.path.join(wal_dir, f"shard_{shard:05d}")


def _seg_name(start_lsn: int) -> str:
    return f"seg_{start_lsn:016d}.wal"


def _seg_start(name: str) -> int:
    return int(name[4:-4])


def list_segments(d: str) -> list[tuple[int, str]]:
    """(start_lsn, path) of every segment in `d`, lsn-ascending."""
    if not os.path.isdir(d):
        return []
    return sorted((_seg_start(n), os.path.join(d, n))
                  for n in os.listdir(d)
                  if n.startswith("seg_") and n.endswith(".wal"))


def encode_record(lsn: int, epoch: int, op: int, keys: np.ndarray,
                  vals: np.ndarray | None) -> bytes:
    keys = np.ascontiguousarray(keys, np.float64)
    payload = keys.tobytes()
    if op == OP_UPSERT:
        payload += np.ascontiguousarray(vals, np.int64).tobytes()
    meta = _HEADER.pack(MAGIC, 0, lsn, epoch, op, len(keys))
    crc = zlib.crc32(meta[8:] + payload) & 0xFFFFFFFF
    return _HEADER.pack(MAGIC, crc, lsn, epoch, op, len(keys)) + payload


def _decode_one(buf: bytes, off: int):
    """(record dict, next offset) or None at the first torn/corrupt byte."""
    if off + _HEADER.size > len(buf):
        return None
    magic, crc, lsn, epoch, op, count = _HEADER.unpack_from(buf, off)
    if magic != MAGIC or op not in (OP_UPSERT, OP_DELETE):
        return None
    n_pay = 8 * count * (2 if op == OP_UPSERT else 1)
    end = off + _HEADER.size + n_pay
    if end > len(buf):
        return None
    if zlib.crc32(buf[off + 8: end]) & 0xFFFFFFFF != crc:
        return None
    keys = np.frombuffer(buf, np.float64, count, off + _HEADER.size)
    vals = (np.frombuffer(buf, np.int64, count,
                          off + _HEADER.size + 8 * count)
            if op == OP_UPSERT else None)
    return dict(lsn=lsn, epoch=epoch, op=op, keys=keys, vals=vals), end


def read_records(d: str, from_lsn: int = 0) -> list[dict]:
    """Every durable record with lsn >= `from_lsn`, in lsn order, stopping
    at the first corruption or lsn gap (torn-tail truncation).  Segments
    wholly below `from_lsn` (already checkpointed + purged or purgeable)
    are skipped without reading.

    One deliberate continuation: a torn tail followed by a segment that
    starts at EXACTLY the next expected lsn is read through — that is the
    signature of a writer resumed by recovery (the torn bytes were a dead
    record whose lsn the resumed writer re-issued in a fresh segment), not
    of corruption."""
    segs = list_segments(d)
    out: list[dict] = []
    expect = None
    for i, (start, path) in enumerate(segs):
        nxt = segs[i + 1][0] if i + 1 < len(segs) else None
        if nxt is not None and nxt <= from_lsn:
            continue                      # fully below the replay window
        if expect is not None and start != expect:
            break                         # gap between segments: stop here
        with open(path, "rb") as f:
            buf = f.read()
        off, lsn, torn = 0, start, False
        while True:
            dec = _decode_one(buf, off)
            if dec is None:
                torn = off < len(buf)     # undecodable trailing bytes
                break
            rec, off = dec
            if rec["lsn"] != lsn:
                torn = True
                break
            lsn += 1
            if rec["lsn"] >= from_lsn:
                out.append(rec)
        expect = lsn
        if torn and nxt != lsn:
            break                         # torn tail with no resumed segment
    return out


def _valid_prefix_len(path: str, start_lsn: int) -> int:
    """Byte length of the decodable record prefix of one segment file."""
    with open(path, "rb") as f:
        buf = f.read()
    off, lsn = 0, start_lsn
    while True:
        dec = _decode_one(buf, off)
        if dec is None or dec[0]["lsn"] != lsn:
            return off
        off, lsn = dec[1], lsn + 1


class WalWriter:
    """Single-shard append-only writer.  One writer thread per the
    online-index threading contract; the durability manager serializes
    rotate/purge against appends with its own lock."""

    def __init__(self, d: str, fsync: str = "interval",
                 fsync_interval_s: float = 0.05, start_lsn: int = 0):
        import time
        self._time = time
        self.dir = d
        self.fsync = fsync
        self.fsync_interval_s = fsync_interval_s
        self.next_lsn = start_lsn
        self._seg_start = start_lsn
        self._last_sync = 0.0
        os.makedirs(d, exist_ok=True)
        path = os.path.join(d, _seg_name(start_lsn))
        # a crashed writer can leave this very path holding a torn record
        # (mid-record kill on a segment's FIRST record); appending after
        # garbage would strand every new record behind it, so clip the
        # file to its valid prefix before reopening
        if os.path.exists(path) and os.path.getsize(path):
            keep = _valid_prefix_len(path, start_lsn)
            if keep < os.path.getsize(path):
                with open(path, "r+b") as f:
                    f.truncate(keep)
        self._f = open(path, "ab")

    def append(self, op: int, keys: np.ndarray, vals: np.ndarray | None,
               epoch: int) -> int:
        """Write one record; returns its lsn.  The python buffer is always
        flushed to the OS before returning (in-process crash safety);
        fsync follows the configured policy."""
        rec = encode_record(self.next_lsn, epoch, op, keys, vals)
        if hooks.armed("wal.mid_record"):
            # test-only shape: land half the record, offer to die, then
            # finish — the production path below is a single write
            half = len(rec) // 2
            self._f.write(rec[:half])
            self._f.flush()
            hooks.crash_point("wal.mid_record")
            self._f.write(rec[half:])
        else:
            self._f.write(rec)
        self._f.flush()
        if self.fsync == "always":
            os.fsync(self._f.fileno())
        elif self.fsync == "interval":
            now = self._time.monotonic()
            if now - self._last_sync >= self.fsync_interval_s:
                os.fsync(self._f.fileno())
                self._last_sync = now
        self.next_lsn += 1
        return self.next_lsn - 1

    def sync(self) -> None:
        """Explicit durability barrier: flush + fsync regardless of policy
        (the facade's `flush()` calls this)."""
        self._f.flush()
        if self.fsync != "off":
            os.fsync(self._f.fileno())

    def rotate(self) -> None:
        """Close the active segment and start a fresh one at the current
        lsn (no-op when the active segment is empty).  Called at
        checkpoint time so the just-checkpointed prefix becomes a CLOSED
        segment that `purge_upto` can delete."""
        if self.next_lsn == self._seg_start:
            return
        self.sync()
        self._f.close()
        self._seg_start = self.next_lsn
        self._f = open(os.path.join(self.dir, _seg_name(self.next_lsn)),
                       "ab")

    def purge_upto(self, watermark: int) -> int:
        """Delete closed segments whose entire lsn range is below
        `watermark` (= records already captured by every retained
        checkpoint).  Returns the number of segments removed."""
        return purge_dir_upto(self.dir, watermark,
                              active_start=self._seg_start)

    def close(self) -> None:
        if not self._f.closed:
            self.sync()
            self._f.close()

    def abandon(self) -> None:
        """Crash simulation: stop using the writer WITHOUT the closing
        sync.  Appended records were flushed to the OS per append, so
        they stay readable — exactly the state a killed process leaves."""
        if not self._f.closed:
            self._f.close()     # close() flushes the (empty) buffer only


def purge_dir_upto(d: str, watermark: int,
                   active_start: int | None = None) -> int:
    """Segment GC for one shard dir: drop every segment whose range ends
    at or below `watermark` (range end = next segment's start).  A writer
    passes its active segment's start so the live file is never a purge
    candidate; for stale dirs (no writer — the shard count shrank) every
    segment is eligible."""
    segs = list_segments(d)
    n = 0
    for i, (start, path) in enumerate(segs):
        if active_start is not None and start >= active_start:
            break
        end = segs[i + 1][0] if i + 1 < len(segs) else None
        if end is None or end > watermark:
            break
        os.remove(path)
        n += 1
    return n


def end_lsn(d: str) -> int:
    """One past the last durable lsn in a shard dir (0 when empty) —
    where a continuing writer must resume numbering."""
    recs = read_records(d)
    if recs:
        return recs[-1]["lsn"] + 1
    segs = list_segments(d)
    return segs[0][0] if segs else 0
