"""Fault-tolerant checkpointing: sharded npz + manifest, atomic publish,
corruption fallback, cross-mesh (elastic) restore.

Layout:
    <dir>/step_000123/
        shard_00000.npz       # this host's param/optimizer leaves
        manifest.json         # step, config hash, tree paths, data state
    <dir>/latest              # text file naming the newest VALID step dir

Writes go to `step_X.tmp/` then os.replace -> atomic.  `restore` walks
checkpoints newest-first and falls back past unreadable/corrupt ones
(validated against the manifest's per-leaf checksums).  Restore takes the
*target* shardings, so a run restarted on a different mesh (elastic scaling)
re-shards automatically via device_put.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import zlib

import jax
import numpy as np

# ---------------------------------------------------------------------------
# shared atomic-publish helpers (also used by repro.durability.checkpoint):
# every checkpoint directory in the repo follows the same protocol —
# write into `step_X.tmp/`, fsync-free `os.replace` to publish atomically,
# maintain a best-effort `latest` pointer, walk candidates newest-first on
# restore and fall back past corrupt ones.
# ---------------------------------------------------------------------------


def step_name(step: int) -> str:
    return f"step_{step:08d}"


def make_tmp_dir(ckpt_dir: str, name: str) -> str:
    """Fresh `<name>.tmp` staging dir under `ckpt_dir` (replacing stale
    leftovers from a crashed writer)."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    return tmp


def publish_dir(ckpt_dir: str, name: str) -> str:
    """Atomically publish `<name>.tmp` -> `<name>` (os.replace), then move
    the `latest` pointer.  A crash before the replace leaves only a .tmp
    (ignored by restore); a crash after it leaves a fully valid step that
    the newest-first walk finds even without the pointer."""
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    final = os.path.join(ckpt_dir, name)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    write_latest(ckpt_dir, name)
    return final


def write_latest(ckpt_dir: str, name: str) -> None:
    with open(os.path.join(ckpt_dir, "latest.tmp"), "w") as f:
        f.write(name)
    os.replace(os.path.join(ckpt_dir, "latest.tmp"),
               os.path.join(ckpt_dir, "latest"))


def step_candidates(ckpt_dir: str) -> list[str]:
    """Published step dir names, newest first, `latest` pointer (when valid)
    promoted to the front — the restore walk order."""
    candidates = sorted((d for d in os.listdir(ckpt_dir)
                         if d.startswith("step_") and not d.endswith(".tmp")),
                        reverse=True)
    latest = os.path.join(ckpt_dir, "latest")
    if os.path.exists(latest):
        with open(latest) as f:
            name = f.read().strip()
        if name in candidates:
            candidates.remove(name)
            candidates.insert(0, name)
    return candidates


def gc_steps(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def _paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]


def save(ckpt_dir: str, step: int, state, extra: dict | None = None,
         keep: int = 3) -> str:
    name = step_name(step)
    tmp = make_tmp_dir(ckpt_dir, name)

    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    arrays = {}
    checksums = {}
    for i, (path, leaf) in enumerate(flat):
        key = f"leaf_{i:05d}"
        a = np.asarray(jax.device_get(leaf))
        arrays[key] = a
        checksums[key] = zlib.crc32(a.tobytes())
    np.savez(os.path.join(tmp, "shard_00000.npz"), **arrays)
    manifest = dict(step=step, paths=_paths(state), checksums=checksums,
                    extra=extra or {})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    final = publish_dir(ckpt_dir, name)
    gc_steps(ckpt_dir, keep)
    return final


def _load_dir(path: str, template, shardings=None, prefix: str = ""):
    """Leaves are matched BY PATH (exact, with optional sub-tree prefix), not
    by flatten index, so a sub-tree template (e.g. prefix="params" out of a
    full train state) restores correctly and reordered states stay valid."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "shard_00000.npz"))
    saved_paths = manifest["paths"]
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat_t))
    leaves = []
    for (tpath, tmpl), shd in zip(flat_t, shard_flat):
        tname = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                         for k in tpath)
        if prefix:
            tname = prefix + "/" + tname
        idx = saved_paths.index(tname) if tname in saved_paths else None
        if idx is None:
            raise IOError(f"no saved leaf for path {tname} in {path}")
        key = f"leaf_{idx:05d}"
        a = data[key]
        if zlib.crc32(a.tobytes()) != manifest["checksums"][key]:
            raise IOError(f"checksum mismatch for {key} in {path}")
        if tuple(a.shape) != tuple(tmpl.shape):
            raise IOError(f"shape mismatch for {tname}: {a.shape} vs "
                          f"{tmpl.shape}")
        a = a.astype(tmpl.dtype)
        leaves.append(jax.device_put(a, shd) if shd is not None
                      else jax.numpy.asarray(a))
    return treedef.unflatten(leaves), manifest


def restore(ckpt_dir: str, template, shardings=None, prefix: str = ""):
    """Load the newest valid checkpoint; fall back past corrupt ones.
    `prefix` restores a sub-tree (e.g. prefix="params") of a saved state.
    Returns (state, manifest) or (None, None) when nothing is restorable."""
    if not os.path.isdir(ckpt_dir):
        return None, None
    for name in step_candidates(ckpt_dir):
        path = os.path.join(ckpt_dir, name)
        try:
            return _load_dir(path, template, shardings, prefix)
        except Exception as e:     # corrupt/partial: fall back
            print(f"[ckpt] skipping {name}: {e}")
    return None, None
