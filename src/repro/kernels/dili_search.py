"""Pallas TPU kernel: batched DILI point lookup (the paper's hot loop).

TPU adaptation of Algorithm 6 (DESIGN.md section 2): queries are tiled into
VMEM blocks of BLOCK_Q; the node table and slot table are small relative to
the key count (two f32 + three i32 words per node, ~2.5 words per slot) and
are kept fully VMEM-resident per grid step — for a 1M-key index the tables
are ~12 MB < 16 MB VMEM on v5e.  Larger indexes use the sharded/XLA path
(ops.py dispatches).

The traversal is a fixed-trip fori_loop (max_depth from the snapshot, a
static bound: DILI's adjustment strategy bounds tree height, Table 6).  Each
trip is FMA + floor + clamp + two VMEM gathers per lane — entirely VPU work;
there is no MXU component, the kernel is gather-bandwidth-bound, which is the
TPU analogue of the paper's cache-miss economy.

Dense (DILI-LO) leaves and depth overflow set a `needs_fallback` flag; the
jit wrapper re-checks those lanes with the pure-XLA path (rare by
construction: local optimization removes dense leaves).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TAG_EMPTY, TAG_PAIR, TAG_CHILD = 0, 1, 2

BLOCK_Q = 2048   # 16 sublanes x 128 lanes of f32


def _kernel(a_ref, b_ref, base_ref, fo_ref, dense_ref, tag_ref, key_ref,
            val_ref, root_ref, q_ref, out_ref, found_ref, fb_ref, *,
            max_depth: int):
    q = q_ref[...]
    a_t = a_ref[...]
    b_t = b_ref[...]
    base_t = base_ref[...]
    fo_t = fo_ref[...]
    dense_t = dense_ref[...]
    tag_t = tag_ref[...]
    key_t = key_ref[...]
    val_t = val_ref[...]
    root = root_ref[0]

    zi = jnp.zeros(q.shape, jnp.int32)
    state = (zi + root,          # current node id
             zi > 0,             # done
             zi - 1,             # out value
             zi > 0,             # found
             zi > 0)             # needs fallback

    def body(_, state):
        n, done, out, found, fb = state
        an = jnp.take(a_t, n, axis=0)
        bn = jnp.take(b_t, n, axis=0)
        fon = jnp.take(fo_t, n, axis=0)
        is_dense = jnp.take(dense_t, n, axis=0) > 0
        pos = jnp.clip(jnp.floor(an + bn * q).astype(jnp.int32), 0, fon - 1)
        s = jnp.take(base_t, n, axis=0) + pos
        t = jnp.take(tag_t, s, axis=0)
        sk = jnp.take(key_t, s, axis=0)
        sv = jnp.take(val_t, s, axis=0)
        active = ~done & ~is_dense
        is_child = (t == TAG_CHILD) & active
        hit = (t == TAG_PAIR) & (sk == q) & active
        miss = ((t == TAG_EMPTY) | ((t == TAG_PAIR) & (sk != q))) & active
        out = jnp.where(hit, sv, out)
        found = found | hit
        fb = fb | (is_dense & ~done)
        n = jnp.where(is_child, sv, n)
        done = done | hit | miss | (is_dense & ~done)
        return (n, done, out, found, fb)

    n, done, out, found, fb = jax.lax.fori_loop(0, max_depth, body, state)
    out_ref[...] = out
    found_ref[...] = found
    fb_ref[...] = fb | ~done


@functools.partial(jax.jit,
                   static_argnames=("max_depth", "interpret", "block_q"))
def dili_search_pallas(a, b, base, fo, dense, tag, key, val, root, queries,
                       max_depth: int, interpret: bool = True,
                       block_q: int = BLOCK_Q):
    """pallas_call wrapper.  Tables are replicated to every grid step (full
    blocks, index_map -> 0); only the query batch is tiled."""
    nq = queries.shape[0]
    assert nq % block_q == 0, f"pad queries to a multiple of {block_q}"
    grid = (nq // block_q,)

    n_nodes = a.shape[0]
    n_slots = tag.shape[0]

    def full(shape):
        return pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))

    qspec = pl.BlockSpec((block_q,), lambda i: (i,))

    out, found, fb = pl.pallas_call(
        functools.partial(_kernel, max_depth=max_depth),
        grid=grid,
        in_specs=[full((n_nodes,))] * 5 + [full((n_slots,))] * 3
                 + [full((1,)), qspec],
        out_specs=[qspec, qspec, qspec],
        out_shape=[
            jax.ShapeDtypeStruct((nq,), jnp.int32),
            jax.ShapeDtypeStruct((nq,), jnp.bool_),
            jax.ShapeDtypeStruct((nq,), jnp.bool_),
        ],
        interpret=interpret,
    )(a, b, base, fo, dense, tag, key, val, root, queries)
    return out, found, fb
