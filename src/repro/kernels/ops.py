"""jit'd public wrapper for the DILI search kernel.

Dispatch policy:
  * tables fit the VMEM budget -> Pallas kernel (interpret=True on CPU,
    compiled on real TPU), with an XLA fallback pass for lanes flagged
    needs_fallback (dense leaves / depth overflow);
  * otherwise -> the pure-XLA batched path (core/search.py), which keeps
    tables in HBM and lets XLA schedule the gathers.

Keys are f32 on this path; the snapshot must have been built under
``placement_dtype(np.float32)`` so construction and kernel arithmetic agree
(see core/dili.py).  build_f32_index() below does exactly that.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from ..core import search as core_search
from ..core.dili import bulk_load, placement_dtype
from ..core.flat import FlatDILI, flatten
from .dili_search import BLOCK_Q, dili_search_pallas

VMEM_BUDGET_BYTES = 12 * 1024 * 1024


def build_f32_index(keys: np.ndarray, vals: np.ndarray | None = None, **kw):
    """Bulk-load a DILI whose placement arithmetic is exactly float32."""
    keys32 = np.unique(np.asarray(keys, np.float64).astype(np.float32))
    if vals is None:
        vals = np.arange(len(keys32), dtype=np.int64)
    with placement_dtype(np.float32):
        d = bulk_load(keys32.astype(np.float64), vals, **kw)
    return d, keys32


def kernel_arrays(flat: FlatDILI) -> dict:
    """Device arrays in kernel dtypes (f32 keys/models, i32 the rest)."""
    return dict(
        a=jnp.asarray(flat.a, jnp.float32),
        b=jnp.asarray(flat.b, jnp.float32),
        base=jnp.asarray(flat.base, jnp.int32),
        fo=jnp.asarray(flat.fo, jnp.int32),
        dense=jnp.asarray(flat.dense.astype(np.int32)),
        tag=jnp.asarray(flat.tag.astype(np.int32)),
        key=jnp.asarray(flat.key, jnp.float32),
        val=jnp.asarray(flat.val, jnp.int32),
        root=jnp.asarray([flat.root], jnp.int32),
        max_depth=flat.max_depth,
    )


def table_bytes(arrs: dict) -> int:
    return sum(int(np.prod(v.shape)) * v.dtype.itemsize
               for k, v in arrs.items() if hasattr(v, "dtype"))


def dili_search(arrs: dict, queries: jnp.ndarray, interpret: bool = True,
                vmem_budget: int | None = None):
    """Batched lookup via the Pallas kernel with XLA fallback lanes.

    `vmem_budget` overrides the module-level `VMEM_BUDGET_BYTES` dispatch
    ceiling (the `IndexConfig.vmem_budget_bytes` knob of the api facade);
    tables above it take the pure-XLA path outright.
    """
    max_depth = int(arrs["max_depth"])
    nq = queries.shape[0]
    pad = (-nq) % BLOCK_Q
    qp = jnp.pad(queries, (0, pad), constant_values=jnp.inf)

    budget = VMEM_BUDGET_BYTES if vmem_budget is None else vmem_budget
    if table_bytes(arrs) <= budget:
        out, found, fb = dili_search_pallas(
            arrs["a"], arrs["b"], arrs["base"], arrs["fo"], arrs["dense"],
            arrs["tag"], arrs["key"], arrs["val"], arrs["root"], qp,
            max_depth=max_depth, interpret=interpret)
        if bool(jnp.any(fb)):
            # rare path: dense leaves / overflow — recheck those lanes in XLA
            # (search_batch handles the dense exit itself, so the snapshot's
            # exact depth is the right trip count here too)
            idx = _as_search_idx(arrs)
            v2, f2 = core_search.search_batch(idx, qp, max_depth=max_depth)
            out = jnp.where(fb, v2, out)
            found = jnp.where(fb, f2, found)
        return out[:nq], found[:nq]

    idx = _as_search_idx(arrs)
    v, f = core_search.search_batch(idx, qp, max_depth=max_depth,
                                    early_exit=True)
    return v[:nq], f[:nq]


def _as_search_idx(arrs: dict) -> dict:
    return dict(a=arrs["a"], b=arrs["b"], base=arrs["base"], fo=arrs["fo"],
                dense=arrs["dense"].astype(jnp.int8),
                tag=arrs["tag"].astype(jnp.int8), key=arrs["key"],
                val=arrs["val"], root=arrs["root"][0],
                max_depth=arrs["max_depth"])
