"""Pure-jnp oracle for the Pallas dili_search kernel.

Mirrors the kernel semantics exactly: f32 keys/models, mul-then-add slot
prediction, fixed `max_depth` unrolled traversal, no dense-leaf handling
(dense lanes are flagged for the wrapper's XLA fallback — see ops.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

TAG_EMPTY, TAG_PAIR, TAG_CHILD = 0, 1, 2


def dili_search_ref(a, b, base, fo, dense, tag, key, val, root, queries,
                    max_depth: int):
    """Returns (vals, found, needs_fallback) for a batch of queries."""
    q = queries
    zi = (q * 0).astype(jnp.int32)
    n = zi + root
    done = zi > 0
    out = zi - 1
    found = zi > 0
    fallback = zi > 0

    for _ in range(max_depth):
        an = a[n]
        bn = b[n]
        fon = fo[n]
        is_dense = dense[n] > 0
        pos = jnp.clip(jnp.floor(an + bn * q).astype(jnp.int32), 0, fon - 1)
        s = base[n] + pos
        t = tag[s]
        sk = key[s]
        sv = val[s]
        active = ~done & ~is_dense
        is_child = (t == TAG_CHILD) & active
        hit = (t == TAG_PAIR) & (sk == q) & active
        miss = ((t == TAG_EMPTY) | ((t == TAG_PAIR) & (sk != q))) & active
        out = jnp.where(hit, sv, out)
        found = found | hit
        fallback = fallback | (is_dense & ~done)
        n = jnp.where(is_child, sv, n)
        done = done | hit | miss | (is_dense & ~done)

    fallback = fallback | ~done   # ran out of depth: let the wrapper recheck
    return out, found, fallback
