import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS_EXTRA", ""))
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this prints/records:
  * compiled.memory_analysis()  — proves the cell fits HBM,
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * per-collective traffic parsed from the post-SPMD HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all /
     collective-permute) with ring-traffic formulas per chip.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi-pod]
  python -m repro.launch.dryrun ... --out results/dryrun
"""

import argparse
import dataclasses
import json
import re
import sys
import time
import traceback

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import get_config, list_archs
from ..models.config import ALL_SHAPES, ModelConfig, ShapeConfig
from ..parallel import sharding as SH
from ..train import step as STEP
from ..train.optim import get_optimizer
from . import specs as SPECS
from .mesh import make_production_mesh

# ---------------------------------------------------------------------------
# cell applicability (DESIGN.md section 4)
# ---------------------------------------------------------------------------

SUBQUADRATIC = {"ssm", "hybrid"}


def cell_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC:
        return "long_500k needs sub-quadratic attention (full-attn arch)"
    return None


def pick_optimizer(cfg: ModelConfig) -> str:
    return "adafactor" if cfg.d_model >= 5120 or cfg.n_experts >= 8 else "adamw"


def probe_points(cfg: ModelConfig) -> list[int]:
    """Layer counts for the roofline probes.  XLA's cost analysis counts a
    scan body ONCE regardless of trip count (verified), so per-step totals
    are recovered by linear extrapolation over n_layers:
      generic:  f(L) = f1 + (L-1)(f2-f1)            probes [1, 2]
      gemma2:   per-pair (local+global)             probes [2, 4]
      zamba2:   f(L) = a + b*L + c*sites(L)         probes [6, 7, 12]
    """
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        k = cfg.shared_attn_every
        return [k, k + 1, 2 * k]
    if cfg.attn_type == "local_global":
        return [2, 4]
    return [1, 2]


# ---------------------------------------------------------------------------
# collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*) = \(?([a-z0-9]+)\[([0-9,]*)\][^)]*\)? "
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^\n]*")
_GROUP_RE = re.compile(r"replica_groups=\{?\[?(\d+),(\d+)\]?")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo: str) -> dict:
    """Per-chip traffic estimates from post-SPMD HLO (shapes are
    per-partition).  Ring formulas: AR=2*S*(g-1)/g, AG/RS/A2A=S*(g-1)/g,
    CP=S."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts: dict = {}
    for m in _COLL_RE.finditer(hlo):
        _, dtype, dims, op = m.groups()
        size = _shape_bytes(dtype, dims)
        g = 0
        gm = _GROUP_RE.search(m.group(0))
        if gm:
            a, b = int(gm.group(1)), int(gm.group(2))
            g = max(a, b) if min(a, b) in (0, 1) else b
        g = g or 8
        if op == "all-reduce":
            traffic = 2 * size * (g - 1) / g
        elif op == "collective-permute":
            traffic = size
        elif op == "all-gather":
            # HLO shape for all-gather is the OUTPUT (gathered) shape
            traffic = size * (g - 1) / g
        else:
            traffic = size * (g - 1) / g
        out[op] += traffic
        counts[op] = counts.get(op, 0) + 1
    out["counts"] = counts
    out["total_bytes"] = sum(v for k, v in out.items()
                             if isinstance(v, float))
    return out


# ---------------------------------------------------------------------------
# lowering per cell
# ---------------------------------------------------------------------------


def shardings_for(kind, cfg, shape, mesh, spec_tree):
    dp = SH.dp_axes(mesh)

    def batch_shard(tree):
        def one(path, leaf):
            nd = len(leaf.shape)
            lead = (None,) if cfg.accum_steps > 1 and kind == "train" else ()
            inner = (dp,) + (None,) * (nd - len(lead) - 1)
            return NamedSharding(mesh, SH.fit_spec(leaf.shape,
                                                   P(*(lead + inner)), mesh))
        return jax.tree_util.tree_map_with_path(one, tree)

    if kind == "train":
        params_sh = SH.param_shardings(cfg, mesh, spec_tree["state"]["params"])
        # optimizer states mirror their param's sharding via path matching
        opt_sh = _opt_shardings(cfg, mesh, spec_tree["state"])
        state_sh = dict(params=params_sh, opt=opt_sh,
                        step=NamedSharding(mesh, P()))
        return (state_sh, batch_shard(spec_tree["batch"])), state_sh
    params_sh = SH.param_shardings(cfg, mesh, spec_tree["params"])
    long_ctx = shape.name == "long_500k"
    cache_sh = SH.cache_shardings(cfg, mesh, spec_tree["cache"], long_ctx)
    if kind == "prefill":
        return (params_sh, batch_shard(spec_tree["batch"]), cache_sh), cache_sh
    tok_sh = NamedSharding(mesh, SH.fit_spec((shape.global_batch, 1),
                                             P(dp, None), mesh))
    return (params_sh, tok_sh, cache_sh), cache_sh


def _opt_shardings(cfg, mesh, state_spec):
    """Optimizer-state shardings: mirror the param sharding; factored
    adafactor rows/cols inherit the matching prefix of the param spec."""
    params_sh = SH.param_shardings(cfg, mesh, state_spec["params"])
    flat_p = dict(jax.tree_util.tree_flatten_with_path(state_spec["params"])[0])

    def one(path, leaf):
        names = [getattr(k, "key", str(k)) for k in path]
        # path like ('v'|'mu'|'nu', <param path...>, ['vr'|'vc'|'v'])
        tail = names[-1]
        core = [n for n in names if n not in
                ("v", "mu", "nu", "vr", "vc", "step")]
        # find matching param spec by path suffix
        spec = None
        for ppath, psh in jax.tree_util.tree_flatten_with_path(params_sh)[0]:
            pnames = [getattr(k, "key", str(k)) for k in ppath]
            if pnames == core:
                spec = psh.spec
                break
        if spec is None:
            return NamedSharding(mesh, P())
        if tail == "vr":        # param spec minus last dim
            spec = P(*tuple(spec)[:len(leaf.shape)])
        elif tail == "vc":      # param spec minus second-to-last dim
            t = tuple(spec)
            spec = P(*(t[:max(len(leaf.shape) - 1, 0)] + t[-1:])) \
                if len(t) >= 2 else P()
        return NamedSharding(mesh, SH.fit_spec(leaf.shape, spec, mesh))

    return dict(
        **{k: jax.tree_util.tree_map_with_path(one, v)
           for k, v in state_spec["opt"].items() if k != "step"},
        step=NamedSharding(mesh, P()))


def lower_cell(arch: str, shape: ShapeConfig, multi_pod: bool,
               overrides: dict | None = None):
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    reason = cell_skip_reason(cfg, shape)
    if reason:
        return dict(arch=arch, shape=shape.name, mesh="multi" if multi_pod
                    else "single", status="SKIP", reason=reason)
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = SPECS.effective_config(cfg, shape)
    opt = get_optimizer(pick_optimizer(cfg))
    spec_tree = SPECS.input_specs(cfg, shape, opt)
    kind = spec_tree["kind"]
    in_sh, _ = shardings_for(kind, cfg, shape, mesh, spec_tree)

    if kind == "train":
        fn = STEP.make_train_step(cfg, opt)
        args = (spec_tree["state"], spec_tree["batch"])
        out_sh = (in_sh[0], None)
    elif kind == "prefill":
        fn = STEP.make_prefill_step(cfg)
        args = (spec_tree["params"], spec_tree["batch"], spec_tree["cache"])
        out_sh = (None, in_sh[2])
    else:
        fn = STEP.make_decode_step(cfg)
        args = (spec_tree["params"], spec_tree["token"], spec_tree["cache"])
        out_sh = (None, None, in_sh[2])

    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        t0 = time.time()
        lowered = jitted.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax: one dict per device
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)

    row = dict(
        arch=arch, shape=shape.name,
        mesh="multi" if multi_pod else "single",
        status="OK", kind=kind, hlo_text=hlo,
        lower_s=round(t1 - t0, 1), compile_s=round(t2 - t1, 1),
        flops=float(cost.get("flops", 0.0)),
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        collectives=coll,
        optimizer=pick_optimizer(cfg),
        accum_steps=cfg.accum_steps,
    )
    for attr in ("bytes_accessed", "argument_size_in_bytes",
                 "output_size_in_bytes", "temp_size_in_bytes",
                 "generated_code_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            row[f"mem_{attr}"] = int(v)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--probes", action="store_true",
                    help="also lower reduced-layer probes (single-pod) for "
                         "scan-corrected roofline extrapolation")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value (e.g. remat=full)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = (ALL_SHAPES if args.shape == "all"
              else [s for s in ALL_SHAPES if s.name == args.shape])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = 0

    def run_one(arch, shape, mp, ov, tag_extra=""):
        nonlocal failures
        tag = f"{arch}_{shape.name}_{'multi' if mp else 'single'}{tag_extra}"
        if ov and not tag_extra:
            tag += "_" + "_".join(f"{k}-{v}" for k, v in sorted(ov.items()))
        out_path = os.path.join(args.out, tag + ".json")
        if os.path.exists(out_path):
            with open(out_path) as f:
                cached = json.load(f)
            if cached.get("status") != "FAIL":    # retry failures
                print(f"[skip-cached] {tag}")
                return cached
        print(f"[lower] {tag} ...", flush=True)
        try:
            row = lower_cell(arch, shape, mp, ov)
        except Exception as e:
            traceback.print_exc()
            row = dict(arch=arch, shape=shape.name,
                       mesh="multi" if mp else "single",
                       status="FAIL", error=str(e)[-2000:])
            failures += 1
        if ov:
            row["overrides"] = {k: v for k, v in ov.items()}
        hlo = row.pop("hlo_text", None)
        if hlo is not None:
            import gzip
            with gzip.open(os.path.join(args.out, tag + ".hlo.gz"),
                           "wt") as f:
                f.write(hlo)
        with open(out_path, "w") as f:
            json.dump(row, f, indent=1)
        print(f"[done ] {tag}: {row['status']} "
              + (f"compile={row.get('compile_s')}s "
                 f"flops={row.get('flops', 0):.3g}" if
                 row["status"] == "OK" else
                 row.get("reason", row.get("error", ""))[:200]),
              flush=True)
        return row

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                row = run_one(arch, shape, mp, dict(overrides))
                if (args.probes and not mp and row.get("status") == "OK"):
                    cfg = get_config(arch)
                    for lp in probe_points(cfg):
                        ov = dict(overrides, n_layers=lp, accum_steps=1)
                        if cfg.is_encdec:
                            ov["encoder_layers"] = min(
                                lp, cfg.encoder_layers)
                        run_one(arch, shape, False, ov,
                                tag_extra=f"_probeL{lp}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
