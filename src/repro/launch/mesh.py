"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run forces 512 host
devices via XLA_FLAGS while tests/benches must keep seeing 1 device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))
