"""Serving launcher: prefill/decode engine + DILI session table behind
the concurrent serving front-end (DESIGN.md section 15).

Session admits/evicts/lookups no longer call the index facade directly:
a `ServeFrontend` batches them through `repro.serve`, and the admit/evict
bookkeeping for each decode batch runs on `--frontend-threads` concurrent
client threads — the same shape a real deployment has (many request
handlers, one batcher, one index writer).

    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --reduced \\
        --requests 16 --tokens 8 --frontend-threads 4
"""

from __future__ import annotations

import argparse
import time
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..models import model as MDL
from ..serve.frontend import ServeFrontend
from ..serve.sessions import SessionTable
from ..train import step as STEP


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--frontend-threads", type=int, default=4,
                    help="concurrent session-admission threads")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = MDL.init_params(jax.random.PRNGKey(0), cfg)
    prefill = jax.jit(STEP.make_prefill_step(cfg))
    decode = jax.jit(STEP.make_decode_step(cfg))
    sessions = SessionTable(n_slots=args.batch + 4)
    frontend = ServeFrontend(sessions.index)
    sessions.serve_through(frontend)
    pool = ThreadPoolExecutor(max_workers=args.frontend_threads,
                              thread_name_prefix="frontend")
    rng = np.random.default_rng(0)
    max_len = args.prompt_len + args.tokens + 1
    kw = {}
    if cfg.family == "vlm":
        kw["extra_embeds"] = jnp.zeros((args.batch, cfg.frontend_seq,
                                        cfg.d_model), jnp.float32)
        max_len += cfg.frontend_seq
    if cfg.is_encdec:
        kw["enc_frames"] = jnp.zeros((args.batch, cfg.frontend_seq,
                                      cfg.d_model), jnp.float32)

    done, rid, t0 = 0, 1000.0, time.time()
    try:
        while done < args.requests:
            ids = []
            for _ in range(args.batch):
                rid += 1.0
                ids.append(rid)
            # admits fan out across the frontend threads; each admit is a
            # get+upsert pair through the batcher under the table lock
            list(pool.map(sessions.admit, ids))
            # KV-slot resolution for the decode batch rides the batched
            # lookup path (coalesced with any other serving traffic)
            slots, found = sessions.lookup_batch(ids)
            assert found.all(), "admitted sessions must resolve"
            prompts = rng.integers(
                0, cfg.vocab,
                (args.batch, args.prompt_len)).astype(np.int32)
            cache = MDL.make_cache(cfg, args.batch, max_len)
            batch = dict(tokens=jnp.asarray(prompts), **kw)
            logits, cache = prefill(params, batch, cache)
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            for _ in range(args.tokens - 1):
                tok, logits, cache = decode(params, tok, cache)
            list(pool.map(sessions.evict, ids))
            done += args.batch
    finally:
        pool.shutdown(wait=True)
        stats = frontend.stats()
        frontend.close()
    dt = time.time() - t0
    print(f"[serve] {done} requests x {args.tokens} tokens in {dt:.1f}s "
          f"({done * args.tokens / dt:.1f} tok/s)")
    print(f"[serve] frontend: {stats['accepted_ops']} ops in "
          f"{stats['n_batches']} batches "
          f"(mean {stats['batch_ops_mean']:.1f} ops/batch, "
          f"shed {stats['shed_ops']})")


if __name__ == "__main__":
    main()
