"""input_specs(): ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, zero allocation (the dry-run contract)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models import model as MDL
from ..models.config import ModelConfig, ShapeConfig
from ..train import step as STEP
from ..train.optim import Optimizer

SDS = jax.ShapeDtypeStruct


def effective_config(cfg: ModelConfig, shape: ShapeConfig) -> ModelConfig:
    """Shape-dependent config tweaks (accumulation only applies to train)."""
    if shape.kind != "train":
        return dataclasses.replace(cfg, accum_steps=1)
    return cfg


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    a = cfg.accum_steps
    b = shape.global_batch
    s = shape.seq_len
    assert b % a == 0, (b, a)
    lead = (a, b // a) if a > 1 else (b,)
    batch = dict(
        tokens=SDS(lead + (s,), jnp.int32),
        labels=SDS(lead + (s,), jnp.int32),
    )
    if cfg.family == "vlm":
        batch["extra_embeds"] = SDS(lead + (cfg.frontend_seq, cfg.d_model),
                                    jnp.dtype(cfg.dtype))
    if cfg.is_encdec:
        batch["enc_frames"] = SDS(lead + (cfg.frontend_seq, cfg.d_model),
                                  jnp.dtype(cfg.dtype))
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    batch = dict(tokens=SDS((b, s), jnp.int32))
    if cfg.family == "vlm":
        batch["extra_embeds"] = SDS((b, cfg.frontend_seq, cfg.d_model),
                                    jnp.dtype(cfg.dtype))
    if cfg.is_encdec:
        batch["enc_frames"] = SDS((b, cfg.frontend_seq, cfg.d_model),
                                  jnp.dtype(cfg.dtype))
    return batch


def cache_specs_abstract(cfg: ModelConfig, shape: ShapeConfig):
    b = shape.global_batch
    max_len = shape.seq_len + (cfg.frontend_seq if cfg.family == "vlm" else 0)
    cache = jax.eval_shape(lambda: MDL.make_cache(cfg, b, max_len))
    if cfg.is_encdec:
        cache = dict(cache, enc_out=SDS((b, cfg.frontend_seq, cfg.d_model),
                                        jnp.dtype(cfg.dtype)))
    return cache


def decode_token_specs(cfg: ModelConfig, shape: ShapeConfig):
    return SDS((shape.global_batch, 1), jnp.int32)


def params_abstract(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: MDL.init_params(jax.random.PRNGKey(0), cfg))


def state_abstract(cfg: ModelConfig, opt: Optimizer):
    return jax.eval_shape(
        lambda: STEP.init_state(jax.random.PRNGKey(0), cfg, opt))


def input_specs(cfg: ModelConfig, shape: ShapeConfig, opt=None) -> dict:
    """Everything the step function needs, as ShapeDtypeStructs."""
    cfg = effective_config(cfg, shape)
    if shape.kind == "train":
        return dict(kind="train", cfg=cfg,
                    state=state_abstract(cfg, opt),
                    batch=train_batch_specs(cfg, shape))
    if shape.kind == "prefill":
        return dict(kind="prefill", cfg=cfg,
                    params=params_abstract(cfg),
                    batch=prefill_batch_specs(cfg, shape),
                    cache=cache_specs_abstract(cfg, shape))
    return dict(kind="decode", cfg=cfg,
                params=params_abstract(cfg),
                token=decode_token_specs(cfg, shape),
                cache=cache_specs_abstract(cfg, shape))
