"""Production training launcher: mesh setup, sharded state, DILI-backed
pipeline, checkpoint/auto-resume, straggler deadline, elastic restore.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \\
        --steps 100 --batch 8 --seq 128 --reduced        # CPU-runnable
    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \\
        --mesh 16x16                                     # pod-scale (TPU)

Fault tolerance: every --ckpt-every steps a sharded checkpoint is written
atomically; on restart the newest valid checkpoint is restored (corrupt ones
are skipped), onto whatever mesh is configured — elastic rescale is a
restart with a different --mesh.  A per-step deadline flags stragglers
(simulated hook on CPU: logs + continues; on real fleets, pair with the
scheduler's replace-and-restart).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..data.pipeline import SyntheticLM
from ..ft import checkpoint as CKPT
from ..parallel import sharding as SH
from ..train import step as STEP
from ..train.optim import adamw, adafactor, cosine_schedule
from .mesh import make_local_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU)")
    ap.add_argument("--mesh", default="local",
                    help="local | 16x16 | 2x16x16")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--step-deadline-s", type=float, default=0.0,
                    help="straggler deadline per step (0 = off)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, accum_steps=1)

    if args.mesh == "local":
        mesh = make_local_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh.count("x") == 2)

    opt = (adafactor(lr=args.lr) if cfg.d_model >= 5120
           else adamw(lr=args.lr,
                      schedule=cosine_schedule(args.lr, 20, args.steps)))

    pipe = SyntheticLM(cfg.vocab, args.seq, args.batch, seed=0)
    template = jax.eval_shape(
        lambda: STEP.init_state(jax.random.PRNGKey(0), cfg, opt))
    shardings = dict(
        params=SH.param_shardings(cfg, mesh, template["params"]))

    with mesh:
        state, manifest = CKPT.restore(args.ckpt_dir, template)
        if state is None:
            state = STEP.init_state(jax.random.PRNGKey(0), cfg, opt)
            start = 0
            print("[launch] cold start", flush=True)
        else:
            start = manifest["step"]
            print(f"[launch] resumed from step {start}", flush=True)
        train_step = jax.jit(STEP.make_train_step(cfg, opt),
                             donate_argnums=0)
        for step in range(start, args.steps):
            t0 = time.time()
            b = pipe.batch_at(step)
            state, m = train_step(state, {k: jnp.asarray(v)
                                          for k, v in b.items()})
            dt = time.time() - t0
            if args.step_deadline_s and dt > args.step_deadline_s:
                print(f"[straggler] step {step} took {dt:.2f}s "
                      f"> deadline {args.step_deadline_s}s — flagged",
                      flush=True)
            if step % 10 == 0:
                print(f"step {step} loss={float(m['loss']):.4f} "
                      f"({dt:.2f}s/step)", flush=True)
            if (step + 1) % args.ckpt_every == 0:
                CKPT.save(args.ckpt_dir, step + 1, state)
    print("[launch] done")


if __name__ == "__main__":
    main()
