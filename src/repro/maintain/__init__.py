"""Adaptive maintenance subsystem (DESIGN.md section 12).

Converts merge cost from O(n) to O(dirty): per-leaf accounting decides
WHAT degraded (write counts, tombstone density, a KS drift statistic),
the incremental flattener re-materializes ONLY the dirty subtrees
bit-identically to a full `flatten()`, local retrains re-run the paper's
top-down fanout individualization on drifted regions, and the
`MaintenanceScheduler` runs the whole merge pipeline on a background
thread against the double-buffered `SnapshotStore`.
"""

from .accounting import (LeafAccount, LeafAccounting, fold_with_accounting,
                         ks_uniform, leaf_drift, run_reclusters,
                         run_retrains)
from .config import MaintenanceConfig
from .flattener import IncrementalFlattener, SegmentBlock, flatten_segment
from .scheduler import MaintenanceScheduler

__all__ = [
    "IncrementalFlattener", "LeafAccount", "LeafAccounting",
    "MaintenanceConfig", "MaintenanceScheduler", "SegmentBlock",
    "flatten_segment", "fold_with_accounting", "ks_uniform", "leaf_drift",
    "run_reclusters", "run_retrains",
]
