"""Per-leaf maintenance accounting: write counts, tombstone density, and a
drift statistic comparing recent key arrivals against the leaf's
build-time distribution.

The drift statistic needs no stored histogram: the leaf's linear model IS
its build-time distribution summary (least squares maps the build keys
roughly uniformly over the slot range).  Mapping recent arrival keys
through the model, `u = clip((a + b*k) / fo, 0, 1)`, a leaf still serving
its build distribution sees `u ~ uniform[0, 1]`; a drifted region piles
arrivals into a narrow slot band.  The Kolmogorov-Smirnov distance between
the arrival `u`s and uniform is the drift score — the same multicriteria
"has the model's error budget moved" view the PGM-index takes, localized
to DILI's equal-division subtrees.

`LeafAccounting.plan()` turns the accounts into a retrain list: leaves
whose drift crossed `drift_threshold` (with at least `retrain_min_writes`
arrivals) or whose tombstone density crossed `tombstone_trigger`.
`fold_with_accounting` is the drop-in replacement for
`online.overlay.fold_overlay` that feeds the accounts while folding.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.dili import DILI, Leaf, rebuild_subtree, split_leaf
from .config import MaintenanceConfig


@dataclass
class LeafAccount:
    leaf: Leaf                  # strong ref: keeps the account's id stable
    writes: int = 0
    deletes: int = 0
    arrivals: list = field(default_factory=list)   # recent upsert keys
    # write heat (re-clustering signal): epoch of the last write and the
    # number of CONSECUTIVE merge epochs with at least one write — O(1)
    # bookkeeping per write, no per-epoch sweep over accounts
    last_epoch: int = 0
    hot_streak: int = 0

    def note(self, key: float, tomb: bool, window: int) -> None:
        self.writes += 1
        if tomb:
            self.deletes += 1
        else:
            self.arrivals.append(key)
            if len(self.arrivals) > window:
                del self.arrivals[: len(self.arrivals) - window]


def ks_uniform(u: np.ndarray) -> float:
    """Kolmogorov-Smirnov distance of samples `u` (in [0, 1]) vs uniform."""
    n = len(u)
    if n == 0:
        return 0.0
    u = np.sort(u)
    grid = np.arange(1, n + 1) / n
    return float(np.maximum(grid - u, u - (grid - 1 / n)).max())


def leaf_drift(leaf: Leaf, arrivals) -> float:
    """KS distance of arrival keys mapped through the leaf's model."""
    if len(arrivals) == 0 or leaf.fo <= 1:
        return 0.0
    k = np.asarray(arrivals, np.float64)
    u = np.clip((leaf.a + leaf.b * k) / leaf.fo, 0.0, 1.0)
    return ks_uniform(u)


class LeafAccounting:
    """Account book for one host DILI (or one shard's)."""

    def __init__(self, cfg: MaintenanceConfig):
        self.cfg = cfg
        self._accounts: dict[int, LeafAccount] = {}
        self._touched: set[int] = set()          # since the last plan()
        self.epoch = 0                           # merge epochs seen
        self._hot_touched: set[int] = set()      # since the last recluster plan

    def __len__(self) -> int:
        return len(self._accounts)

    def accounts(self) -> list[LeafAccount]:
        """The live accounts (read-only view for `obs.inspect`'s heat
        summaries)."""
        return list(self._accounts.values())

    def begin_epoch(self) -> None:
        """Advance the merge-epoch counter; called once per merge fold so
        `hot_streak` measures persistence ACROSS merges, not within one."""
        self.epoch += 1

    def note(self, leaf: Leaf, key: float, tomb: bool) -> None:
        lid = id(leaf)
        acct = self._accounts.get(lid)
        if acct is None or acct.leaf is not leaf:
            acct = self._accounts[lid] = LeafAccount(leaf)
        acct.note(key, tomb, self.cfg.arrival_window)
        if acct.last_epoch != self.epoch:
            acct.hot_streak = (acct.hot_streak + 1
                               if acct.last_epoch == self.epoch - 1 else 1)
            acct.last_epoch = self.epoch
        self._touched.add(lid)
        self._hot_touched.add(lid)

    # -- decisions -----------------------------------------------------------

    def tombstone_density(self, acct: LeafAccount) -> float:
        return acct.deletes / max(acct.leaf.omega + acct.deletes, 1)

    def should_retrain(self, acct: LeafAccount) -> bool:
        cfg = self.cfg
        if acct.leaf.omega < 2:
            return False
        if (acct.deletes >= cfg.retrain_min_writes
                and self.tombstone_density(acct) > cfg.tombstone_trigger):
            return True
        return (acct.writes >= cfg.retrain_min_writes
                and leaf_drift(acct.leaf, acct.arrivals)
                > cfg.drift_threshold)

    def plan(self) -> list[Leaf]:
        """Leaves (touched since the last plan) due for a retrain."""
        due = [self._accounts[lid] for lid in self._touched
               if lid in self._accounts]
        self._touched.clear()
        if not self.cfg.retrain:      # accounting kept for recluster only
            return []
        return [a.leaf for a in due if self.should_retrain(a)]

    def forget(self, leaf: Leaf) -> None:
        """Drop a retrained leaf's account (its region restarts clean)."""
        self._accounts.pop(id(leaf), None)

    def plan_reclusters(self, flattener) -> list[tuple[Leaf, int]]:
        """Persistently-hot large segments due for a locality split, hottest
        and largest first, as `(leaf, n_children)` pairs.

        A leaf qualifies when it has received writes in
        `recluster_hot_streak` consecutive merge epochs AND its cached
        flatten segment spans at least `recluster_min_rows` slot rows (the
        flattener's row count is the actual cost a dirty segment adds to a
        merge — pairs undercount conflict-chain slots).  The per-merge
        budget `recluster_max_per_merge` keeps any single publish bounded;
        leftover hot leaves re-qualify next merge if the writes persist."""
        cfg = self.cfg
        due = self._hot_touched
        self._hot_touched = set()
        if not cfg.recluster or flattener is None:
            return []
        cand: list[tuple[int, int, Leaf]] = []
        for lid in due:
            acct = self._accounts.get(lid)
            if acct is None or acct.hot_streak < cfg.recluster_hot_streak:
                continue
            rows = flattener.segment_rows(lid)
            if rows is None or rows < cfg.recluster_min_rows:
                continue
            cand.append((acct.hot_streak, rows, acct.leaf))
        cand.sort(key=lambda c: (c[0], c[1]), reverse=True)
        out = []
        for _, rows, leaf in cand[: cfg.recluster_max_per_merge]:
            fo = int(np.clip(-(-rows // max(cfg.recluster_target_pairs, 1)),
                             2, 256))
            out.append((leaf, fo))
        return out


def fold_with_accounting(dili: DILI, ov,
                         accounting: LeafAccounting | None) -> None:
    """`fold_overlay` plus per-write accounting: tombstones via Algorithm 8,
    live entries via Algorithm 7, each noted against the top-level leaf the
    write lands in (the incremental flattener's segment unit).

    One tree walk per entry: the leaf is located once and the Alg. 7/8
    bodies are driven with it directly — `dili.upsert`/`delete` would
    re-locate the same leaf, doubling the host-walk cost on the merge
    path this subsystem exists to shrink.  The dirty marking the public
    entry points perform happens here instead."""
    if accounting is not None:
        accounting.begin_epoch()
    keys, vals, tomb = ov.entries()
    for k, v, t in zip(keys, vals, tomb):
        k = float(k)
        leaf, _ = dili.locate_leaf(k)
        dili.dirty_ids.add(id(leaf))
        if accounting is not None:
            accounting.note(leaf, k, bool(t))
        if t:
            dili._delete_from_leaf(leaf, k)
        elif not dili._insert_to_leaf(leaf, k, int(v)):
            dili._set_payload_at(leaf, k, int(v))   # update in place


def run_retrains(dili: DILI, accounting: LeafAccounting) -> int:
    """Rebuild every leaf the accounting flagged; returns the count."""
    n = 0
    for leaf in accounting.plan():
        if rebuild_subtree(dili, leaf) is not None:
            accounting.forget(leaf)
            n += 1
    return n


def run_reclusters(dili: DILI, accounting: LeafAccounting,
                   flattener) -> int:
    """Split every persistently-hot large leaf the accounting flagged into
    its own fan of small splice segments (DESIGN.md section 12); returns
    the number of splits performed.  Runs AFTER `run_retrains` in the
    merge pipeline: a leaf both retrained and heat-flagged was already
    replaced (and its account forgotten), so the planner skips it and the
    fresh subtree re-qualifies from a cold streak if the heat persists."""
    n = 0
    for leaf, fo in accounting.plan_reclusters(flattener):
        if split_leaf(dili, leaf, fo) is not None:
            accounting.forget(leaf)
            n += 1
    return n
