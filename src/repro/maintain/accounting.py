"""Per-leaf maintenance accounting: write counts, tombstone density, and a
drift statistic comparing recent key arrivals against the leaf's
build-time distribution.

The drift statistic needs no stored histogram: the leaf's linear model IS
its build-time distribution summary (least squares maps the build keys
roughly uniformly over the slot range).  Mapping recent arrival keys
through the model, `u = clip((a + b*k) / fo, 0, 1)`, a leaf still serving
its build distribution sees `u ~ uniform[0, 1]`; a drifted region piles
arrivals into a narrow slot band.  The Kolmogorov-Smirnov distance between
the arrival `u`s and uniform is the drift score — the same multicriteria
"has the model's error budget moved" view the PGM-index takes, localized
to DILI's equal-division subtrees.

`LeafAccounting.plan()` turns the accounts into a retrain list: leaves
whose drift crossed `drift_threshold` (with at least `retrain_min_writes`
arrivals) or whose tombstone density crossed `tombstone_trigger`.
`fold_with_accounting` is the drop-in replacement for
`online.overlay.fold_overlay` that feeds the accounts while folding.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.dili import DILI, Leaf, rebuild_subtree
from .config import MaintenanceConfig


@dataclass
class LeafAccount:
    leaf: Leaf                  # strong ref: keeps the account's id stable
    writes: int = 0
    deletes: int = 0
    arrivals: list = field(default_factory=list)   # recent upsert keys

    def note(self, key: float, tomb: bool, window: int) -> None:
        self.writes += 1
        if tomb:
            self.deletes += 1
        else:
            self.arrivals.append(key)
            if len(self.arrivals) > window:
                del self.arrivals[: len(self.arrivals) - window]


def ks_uniform(u: np.ndarray) -> float:
    """Kolmogorov-Smirnov distance of samples `u` (in [0, 1]) vs uniform."""
    n = len(u)
    if n == 0:
        return 0.0
    u = np.sort(u)
    grid = np.arange(1, n + 1) / n
    return float(np.maximum(grid - u, u - (grid - 1 / n)).max())


def leaf_drift(leaf: Leaf, arrivals) -> float:
    """KS distance of arrival keys mapped through the leaf's model."""
    if len(arrivals) == 0 or leaf.fo <= 1:
        return 0.0
    k = np.asarray(arrivals, np.float64)
    u = np.clip((leaf.a + leaf.b * k) / leaf.fo, 0.0, 1.0)
    return ks_uniform(u)


class LeafAccounting:
    """Account book for one host DILI (or one shard's)."""

    def __init__(self, cfg: MaintenanceConfig):
        self.cfg = cfg
        self._accounts: dict[int, LeafAccount] = {}
        self._touched: set[int] = set()          # since the last plan()

    def __len__(self) -> int:
        return len(self._accounts)

    def note(self, leaf: Leaf, key: float, tomb: bool) -> None:
        lid = id(leaf)
        acct = self._accounts.get(lid)
        if acct is None or acct.leaf is not leaf:
            acct = self._accounts[lid] = LeafAccount(leaf)
        acct.note(key, tomb, self.cfg.arrival_window)
        self._touched.add(lid)

    # -- decisions -----------------------------------------------------------

    def tombstone_density(self, acct: LeafAccount) -> float:
        return acct.deletes / max(acct.leaf.omega + acct.deletes, 1)

    def should_retrain(self, acct: LeafAccount) -> bool:
        cfg = self.cfg
        if acct.leaf.omega < 2:
            return False
        if (acct.deletes >= cfg.retrain_min_writes
                and self.tombstone_density(acct) > cfg.tombstone_trigger):
            return True
        return (acct.writes >= cfg.retrain_min_writes
                and leaf_drift(acct.leaf, acct.arrivals)
                > cfg.drift_threshold)

    def plan(self) -> list[Leaf]:
        """Leaves (touched since the last plan) due for a retrain."""
        due = [self._accounts[lid] for lid in self._touched
               if lid in self._accounts]
        self._touched.clear()
        return [a.leaf for a in due if self.should_retrain(a)]

    def forget(self, leaf: Leaf) -> None:
        """Drop a retrained leaf's account (its region restarts clean)."""
        self._accounts.pop(id(leaf), None)


def fold_with_accounting(dili: DILI, ov,
                         accounting: LeafAccounting | None) -> None:
    """`fold_overlay` plus per-write accounting: tombstones via Algorithm 8,
    live entries via Algorithm 7, each noted against the top-level leaf the
    write lands in (the incremental flattener's segment unit).

    One tree walk per entry: the leaf is located once and the Alg. 7/8
    bodies are driven with it directly — `dili.upsert`/`delete` would
    re-locate the same leaf, doubling the host-walk cost on the merge
    path this subsystem exists to shrink.  The dirty marking the public
    entry points perform happens here instead."""
    keys, vals, tomb = ov.entries()
    for k, v, t in zip(keys, vals, tomb):
        k = float(k)
        leaf, _ = dili.locate_leaf(k)
        dili.dirty_ids.add(id(leaf))
        if accounting is not None:
            accounting.note(leaf, k, bool(t))
        if t:
            dili._delete_from_leaf(leaf, k)
        elif not dili._insert_to_leaf(leaf, k, int(v)):
            dili._set_payload_at(leaf, k, int(v))   # update in place


def run_retrains(dili: DILI, accounting: LeafAccounting) -> int:
    """Rebuild every leaf the accounting flagged; returns the count."""
    n = 0
    for leaf in accounting.plan():
        if rebuild_subtree(dili, leaf) is not None:
            accounting.forget(leaf)
            n += 1
    return n
