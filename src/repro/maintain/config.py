"""`MaintenanceConfig`: the knob set of the adaptive maintenance subsystem.

One frozen dataclass shared by every engine (threaded through
`api.IndexConfig.maintenance`) and by `OnlineIndex` directly.  `None`
anywhere a `MaintenanceConfig` is accepted means the legacy monolithic
path: full `flatten()` per merge, no drift accounting, no retrains, no
background thread.
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class MaintenanceConfig:
    """Adaptive maintenance knobs (DESIGN.md section 12).

    incremental       : splice-flatten — re-flatten only the subtrees the
                        merge dirtied and reassemble from cached segment
                        blocks; bit-identical to a full `flatten()`.
    retrain           : drift/tombstone-triggered subtree rebuilds — re-run
                        the paper's top-down fanout individualization
                        (Alg. 4/5) on degraded regions instead of letting
                        Alg. 7's per-leaf adjustment degrade globally.
    drift_threshold   : KS distance between recent arrival keys (mapped
                        through the leaf's own model) and the uniform slot
                        fill the model was fit to; above it the leaf's
                        region no longer looks like its build distribution.
    retrain_min_writes: per-leaf write floor before drift is trusted (a KS
                        statistic over a handful of arrivals is noise).
    tombstone_trigger : deletes / (live + deletes) density per leaf above
                        which the region is rebuilt to compact it.
    arrival_window    : per-leaf ring-buffer size of recent arrival keys
                        the drift statistic is computed over.
    background        : run merges + retrains on a `MaintenanceScheduler`
                        worker thread against the double-buffered
                        `SnapshotStore` (local engine only) so the writer
                        never blocks on a publish.
    max_queue         : background task-queue bound; triggers that find the
                        queue full coalesce into the next merge.
    max_merge_retries : background-merge attempts AFTER the first failure
                        (jittered exponential backoff between attempts;
                        re-folding a partially-applied overlay is
                        idempotent).  After exhaustion the index degrades
                        to synchronous merges and sets the `maint_degraded`
                        stats()/metrics() flag.  0 = fail on first error
                        (the pre-durability behavior).
    retry_backoff_s   : base backoff before retry k is
                        `retry_backoff_s * 2**k`, jittered to 50-150%.
    recluster         : locality-aware segment re-clustering — split leaves
                        that stay write-hot across consecutive merges into
                        many small leaf segments, so a skewed write stream
                        dirties O(hot segments) per merge instead of
                        re-flattening nearly every row (the zipfian
                        hashed-rank-scatter pathology, DESIGN.md section 12).
    recluster_hot_streak : consecutive merge epochs a leaf must receive
                        writes before it counts as persistently hot.
    recluster_min_rows: only split leaves whose flattened segment spans at
                        least this many slot rows — splitting already-small
                        segments churns node ids for no dirty-row savings.
    recluster_target_pairs : aim each child segment at roughly this many
                        pairs; the split fanout is ceil(pairs / target),
                        clamped to [2, 256].
    recluster_max_per_merge : per-merge split budget, bounding splice work
                        added to any single publish.  Sized to FINISH
                        adoption fast: under uniform-scatter skew nearly
                        every large segment eventually qualifies, and a
                        small budget prolongs the phase where merges pay
                        both high dirty fractions AND split cost — better
                        to front-load the one-time splits into a few
                        merges (visible as p95/p99 spikes) and reach the
                        low-dirty steady state early.
    """

    incremental: bool = True
    retrain: bool = True
    drift_threshold: float = 0.35
    retrain_min_writes: int = 96
    tombstone_trigger: float = 0.25
    arrival_window: int = 128
    background: bool = False
    max_queue: int = 4
    max_merge_retries: int = 2
    retry_backoff_s: float = 0.05
    recluster: bool = True
    recluster_hot_streak: int = 2
    recluster_min_rows: int = 2048
    recluster_target_pairs: int = 512
    recluster_max_per_merge: int = 1024

    # -- (de)serialization for api.IndexConfig round-trips -------------------

    def to_json_dict(self) -> dict:
        return dict(incremental=self.incremental, retrain=self.retrain,
                    drift_threshold=self.drift_threshold,
                    retrain_min_writes=self.retrain_min_writes,
                    tombstone_trigger=self.tombstone_trigger,
                    arrival_window=self.arrival_window,
                    background=self.background, max_queue=self.max_queue,
                    max_merge_retries=self.max_merge_retries,
                    retry_backoff_s=self.retry_backoff_s,
                    recluster=self.recluster,
                    recluster_hot_streak=self.recluster_hot_streak,
                    recluster_min_rows=self.recluster_min_rows,
                    recluster_target_pairs=self.recluster_target_pairs,
                    recluster_max_per_merge=self.recluster_max_per_merge)

    @classmethod
    def from_json_dict(cls, d: dict) -> "MaintenanceConfig":
        return cls(**d)
