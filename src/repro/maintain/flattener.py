"""Incremental (splice) flattening: re-flatten only the dirty subtrees.

The monolithic `core.flat.flatten` walks EVERY node and EVERY slot of the
host tree per merge — O(n) Python-loop work whose cost grows with total
index size, not with the write footprint.  This module converts that to
O(dirty): the tree is partitioned into **segments** (the maximal mutable
subtrees — every leaf that hangs off an internal node, conflict-leaf
chains included; the paper's Alg. 7/8 only ever mutate inside these, while
internal nodes are structurally immutable after construction), each
segment's flattened block (node rows, slot rows, key-sorted pair run) is
cached, and a merge re-materializes only the segments its writes dirtied.  Reassembly is numpy concatenation plus vectorized id/offset
shifts — no per-slot Python.

Exactness contract: the result is **bit-identical** to `flatten(dili)` on
the same tree (asserted by tests/test_maintain.py's property test).  Two
structural facts make that cheap:

  * `flatten` is DFS preorder, so a segment occupies one contiguous run of
    node ids and slot rows; splicing never renumbers interleaved levels.
  * the equal-division routing is monotone in the key, so consecutive
    segments hold consecutive key ranges — the global key-sorted pair
    table is the concatenation of per-segment sorted runs, no global
    argsort.

Dirty plumbing: `DILI` records the id of every leaf its mutation entry
points located (`DILI.dirty_ids`); the flattener maps those to segments
via the node->segment index it builds while flattening.  An id it cannot
map (should not happen — every located leaf existed at the previous
flatten) falls back to a full re-flatten rather than risking a stale
block: correctness never depends on the plumbing being airtight.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.dili import DILI, Internal
from ..core.flat import (FlatDILI, TAG_CHILD, TAG_PAIR, _max_depth,
                         node_tables, preorder)


@dataclass
class SegmentBlock:
    """One segment's cached flatten output, in segment-local coordinates
    (node ids 0-based at the segment root, slot offsets 0-based at the
    segment's first slot row)."""
    root: object                 # strong ref: keeps ids in the index stable
    nodes: list                  # strong refs to every node (id stability)
    a: np.ndarray
    b: np.ndarray
    base: np.ndarray             # local slot offsets
    fo: np.ndarray
    dense: np.ndarray
    tag: np.ndarray
    key: np.ndarray
    val: np.ndarray              # CHILD entries hold segment-local node ids
    child_mask: np.ndarray       # tag == TAG_CHILD (precomputed for shifts)
    pair_key: np.ndarray         # segment pairs, key-sorted
    pair_val: np.ndarray
    pair_slot: np.ndarray        # local slot ranks of the sorted pairs
    depth: int                   # local subtree height (segment root = 1)

    @property
    def n_nodes(self) -> int:
        return len(self.a)

    @property
    def n_slots(self) -> int:
        return len(self.tag)


def flatten_segment(root) -> SegmentBlock:
    """Flatten one subtree in isolation, via the same `node_tables` code
    path as the whole-tree `flatten()` (bit-for-bit the same rows once the
    local ids/offsets are shifted into place)."""
    nodes = preorder(root)
    ids = {id(nd): i for i, nd in enumerate(nodes)}
    a, b, base, fo, dense, tag, key, val = node_tables(nodes, ids)
    slots = np.nonzero(tag == TAG_PAIR)[0].astype(np.int32)
    order = np.argsort(key[slots], kind="stable")
    pair_slot = slots[order]
    return SegmentBlock(
        root=root, nodes=nodes, a=a, b=b, base=base, fo=fo, dense=dense,
        tag=tag, key=key, val=val, child_mask=tag == TAG_CHILD,
        pair_key=key[pair_slot], pair_val=val[pair_slot],
        pair_slot=pair_slot, depth=_max_depth(root))


class IncrementalFlattener:
    """Segment-cached flattener.  `flatten(dili, dirty_ids)` returns a
    `FlatDILI` bit-identical to `core.flat.flatten(dili)`, re-flattening
    only segments containing a dirty id (plus segments whose root object
    changed — a retrained subtree is a cache miss by identity)."""

    def __init__(self) -> None:
        self._cache: dict[int, SegmentBlock] = {}
        self._node2seg: dict[int, int] = {}
        # observability (read by engine stats())
        self.last_dirty_segments = 0
        self.last_total_segments = 0
        self.last_dirty_rows = 0
        self.last_total_rows = 0
        self.last_incremental = False
        # forced full re-flattens from an unmappable dirty id — distinct
        # from INTENTIONAL full flattens (cold cache, incremental=False):
        # a nonzero count means the dirty plumbing leaked an id and the
        # O(dirty) guarantee silently degraded to O(n).  Surfaced as
        # `n_forced_full_flattens` in engine stats().
        self.n_fallback_full = 0

    def segment_rows(self, nid: int) -> int | None:
        """Flattened slot-row count of the segment containing node `nid`,
        or None if the node was never flattened.  The re-clustering
        planner's size signal: rows (not pairs) are what a dirty segment
        actually costs a merge."""
        seg = self._node2seg.get(nid)
        if seg is None:
            return None
        blk = self._cache.get(seg)
        return blk.n_slots if blk is not None else None

    # -- structure -----------------------------------------------------------

    @staticmethod
    def _units(root) -> list:
        """DFS preorder as a list of units: ('spine', node, depth) single
        Internal nodes and ('seg', node, depth) whole leaf-rooted mutable
        subtrees.  Concatenating per-unit blocks in this order IS
        `preorder(root)`.

        The spine is DYNAMIC — every `Internal` is a spine unit, including
        internals a retrain introduced.  Internals are structurally
        immutable after construction (Alg. 7/8 mutate only leaf subtrees;
        bulk_load and rebuild_subtree never touch an existing internal's
        children list, only swap one pointer), so caching applies exactly
        to the mutable units.  This also keeps segments fine-grained under
        append-style workloads: when the frontier leaf is retrained into
        an Internal-rooted subtree, its leaves become independent segments
        instead of one ever-growing block."""
        units: list = []
        stack = [(root, 1)]
        while stack:
            nd, d = stack.pop()
            if isinstance(nd, Internal):
                units.append(("spine", nd, d))
                stack.extend((c, d + 1) for c in reversed(nd.children))
            else:
                units.append(("seg", nd, d))
        return units

    # -- the splice ----------------------------------------------------------

    def flatten(self, dili: DILI, dirty_ids: set[int] | None = None
                ) -> FlatDILI:
        dirty_ids = dirty_ids or set()
        units = self._units(dili.root)
        had_cache = bool(self._cache)

        # translate dirty node ids -> dirty segment ids; an id the index
        # does not know forces a full re-flatten (safety net, see module
        # docstring) by dirtying every segment
        dirty_segs: set[int] = set()
        force_full = False
        for nid in dirty_ids:
            seg = self._node2seg.get(nid)
            if seg is None:
                force_full = True
                self.n_fallback_full += 1
                break
            dirty_segs.add(seg)

        # pass 1: refresh segment blocks (cache miss == dirty by identity)
        seen: set[int] = set()
        n_dirty = dirty_rows = 0
        for kind, nd, _ in units:
            if kind != "seg":
                continue
            sid = id(nd)
            seen.add(sid)
            if force_full or sid in dirty_segs or sid not in self._cache:
                old = self._cache.pop(sid, None)
                if old is not None:
                    for onode in old.nodes:
                        self._node2seg.pop(id(onode), None)
                blk = flatten_segment(nd)
                self._cache[sid] = blk
                for bnode in blk.nodes:
                    self._node2seg[id(bnode)] = sid
                n_dirty += 1
                dirty_rows += blk.n_slots
        # drop segments that no longer exist (retrained away)
        for dead in set(self._cache) - seen:
            for onode in self._cache.pop(dead).nodes:
                self._node2seg.pop(id(onode), None)

        # pass 2: assign global offsets per unit (plain python ints — a
        # numpy scalar store per unit costs more than the whole pass)
        node_off: list[int] = []
        slot_off: list[int] = []
        cur_n = cur_s = 0
        blocks: list[SegmentBlock | None] = []
        for kind, nd, _ in units:
            node_off.append(cur_n)
            slot_off.append(cur_s)
            if kind == "spine":
                blocks.append(None)
                cur_n += 1
                cur_s += nd.fanout
            else:
                blk = self._cache[id(nd)]
                blocks.append(blk)
                cur_n += blk.n_nodes
                cur_s += blk.n_slots
        unit_of_node = {id(nd): u for u, (_, nd, _) in enumerate(units)}

        # pass 3: assemble.  The unit loop only APPENDS segment-local
        # arrays (zero numpy calls per cached segment — with many small
        # segments the per-segment numpy-call overhead used to dominate
        # the whole splice); every id/offset shift is applied after the
        # concat as one vectorized repeat/masked-add over the full table.
        a_parts, b_parts, base_parts, fo_parts, dense_parts = [], [], [], [], []
        tag_parts, key_parts, val_parts = [], [], []
        pk_parts, pv_parts, ps_parts = [], [], []
        u_nodes: list[int] = []      # node rows per unit  (base shift runs)
        u_slots: list[int] = []      # slot rows per unit  (val shift runs)
        u_noff: list[int] = []       # node-id shift for seg CHILD slots
        seg_pairs: list[int] = []    # pair rows per seg   (pair_slot runs)
        seg_soff: list[int] = []     # slot-row shift per seg's pair run
        zero1_i8 = np.zeros(1, np.int8)
        zero1_i32 = np.zeros(1, np.int32)
        max_depth = 1
        for u, (kind, nd, d) in enumerate(units):
            if kind == "spine":
                a_parts.append(np.array([nd.a]))
                b_parts.append(np.array([nd.b]))
                base_parts.append(zero1_i32)
                fo_parts.append(np.array([nd.fanout], np.int32))
                dense_parts.append(zero1_i8)
                m = nd.fanout
                tag_parts.append(np.full(m, TAG_CHILD, np.int8))
                key_parts.append(np.zeros(m))
                # spine CHILD targets are arbitrary units' offsets — only
                # these are resolved in-loop (few internals, many segments)
                val_parts.append(np.array(
                    [node_off[unit_of_node[id(c)]] for c in nd.children],
                    np.int64))
                u_nodes.append(1)
                u_slots.append(m)
                u_noff.append(0)     # already global
                max_depth = max(max_depth, d)
            else:
                blk = blocks[u]
                a_parts.append(blk.a)
                b_parts.append(blk.b)
                base_parts.append(blk.base)
                fo_parts.append(blk.fo)
                dense_parts.append(blk.dense)
                tag_parts.append(blk.tag)
                key_parts.append(blk.key)
                val_parts.append(blk.val)
                pk_parts.append(blk.pair_key)
                pv_parts.append(blk.pair_val)
                ps_parts.append(blk.pair_slot)
                u_nodes.append(blk.n_nodes)
                u_slots.append(blk.n_slots)
                u_noff.append(node_off[u])
                seg_pairs.append(len(blk.pair_slot))
                seg_soff.append(slot_off[u])
                max_depth = max(max_depth, d + blk.depth - 1)

        total_rows = int(cur_s)
        self.last_dirty_segments = n_dirty
        self.last_total_segments = len(self._cache)
        self.last_dirty_rows = dirty_rows
        self.last_total_rows = total_rows
        self.last_incremental = had_cache and not force_full

        z8, zf, zi = (np.zeros(0, np.int8), np.zeros(0),
                      np.zeros(0, np.int64))
        zi32 = np.zeros(0, np.int32)
        tag = np.concatenate(tag_parts) if tag_parts else z8
        # base rows are segment-local: one repeat of each unit's slot
        # offset over its node rows re-bases them globally (spine locals
        # are 0, so the uniform shift is exact for both unit kinds)
        base = np.concatenate(base_parts) if base_parts else zi32
        base += np.repeat(np.asarray(slot_off, np.int32),
                          np.asarray(u_nodes, np.int32))
        # CHILD slot entries of a segment hold segment-local node ids;
        # shift them by their unit's node offset in one masked add
        # (spine units carry shift 0 — their targets are already global)
        val = np.concatenate(val_parts) if val_parts else zi
        child = tag == TAG_CHILD
        val[child] += np.repeat(np.asarray(u_noff, np.int64),
                                np.asarray(u_slots, np.int64))[child]
        # sorted pair runs: slot ranks are segment-local too
        pair_slot = np.concatenate(ps_parts) if ps_parts else zi32
        pair_slot += np.repeat(np.asarray(seg_soff, np.int32),
                               np.asarray(seg_pairs, np.int32))
        return FlatDILI(
            a=np.concatenate(a_parts) if a_parts else zf,
            b=np.concatenate(b_parts) if b_parts else zf,
            base=base,
            fo=(np.concatenate(fo_parts) if fo_parts else zi32),
            dense=np.concatenate(dense_parts) if dense_parts else z8,
            tag=tag,
            key=np.concatenate(key_parts) if key_parts else zf,
            val=val,
            pair_key=np.concatenate(pk_parts) if pk_parts else zf,
            pair_val=np.concatenate(pv_parts) if pv_parts else zi,
            pair_slot=pair_slot,
            root=0, max_depth=max_depth,
            key_lo=float(dili.root.lb), key_hi=float(dili.root.ub),
            n_segments=len(self._cache),
        )
