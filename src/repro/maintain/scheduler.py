"""`MaintenanceScheduler`: one daemon worker draining maintenance tasks.

The serving thread never blocks on a publish: merge triggers enqueue a
task and return; the worker folds, retrains, flattens, and publishes
against the double-buffered `SnapshotStore` while reads keep serving the
previous epoch fused with the pending overlays.

Failure surface: a task exception is caught, recorded in `errors`, and the
worker keeps running.  `errors` is exported through engine `stats()`
(`maint_errors`) and checked by the workload runner, so a broken
background merge fails CI instead of silently stalling maintenance.
"""

from __future__ import annotations

import queue
import threading
import traceback


class MaintenanceScheduler:
    def __init__(self, max_queue: int = 4, name: str = "dili-maint"):
        self.max_queue = max_queue
        self.errors: list[str] = []
        self._q: queue.Queue = queue.Queue()
        self._pending = 0
        self._lock = threading.Lock()
        self._closed = False
        self._worker = threading.Thread(target=self._run, name=name,
                                        daemon=True)
        self._worker.start()

    # -- worker --------------------------------------------------------------

    def _run(self) -> None:
        while True:
            task = self._q.get()
            if task is None:
                self._q.task_done()
                return
            try:
                task()
            except BaseException:
                self.errors.append(traceback.format_exc())
            finally:
                with self._lock:
                    self._pending -= 1
                self._q.task_done()

    # -- submission side -----------------------------------------------------

    @property
    def depth(self) -> int:
        """Tasks submitted but not yet finished (incl. the running one)."""
        with self._lock:
            return self._pending

    def submit(self, task) -> bool:
        """Enqueue `task` unless closed or the queue is full (the caller
        coalesces into a later trigger).  Returns whether it was taken."""
        with self._lock:
            if self._closed or self._pending >= self.max_queue:
                return False
            self._pending += 1
        self._q.put(task)
        return True

    def drain(self) -> None:
        """Block until every submitted task has finished."""
        self._q.join()

    def close(self) -> None:
        """Drain, then stop the worker (idempotent)."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._q.join()
        self._q.put(None)
        self._worker.join(timeout=30.0)
