"""Model configuration shared by all assigned architectures."""

from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | ssm | hybrid | moe | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    # attention flavor
    attn_type: str = "full"     # full | local_global (gemma2 alternation)
    window: int = 4096
    logit_softcap: float = 0.0  # gemma2 final-logit softcap (0 = off)
    attn_softcap: float = 0.0   # gemma2 attention softcap
    rope_theta: float = 10000.0
    act: str = "swiglu"         # swiglu | geglu | gelu
    use_bias: bool = False
    tie_embeddings: bool = False
    parallel_block: bool = False  # command-r style attn||ffn
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM
    ssm_state: int = 0
    ssm_version: int = 1
    d_conv: int = 4
    expand: int = 2
    ssm_heads: int = 0          # mamba2 heads
    # hybrid (zamba2): one shared attention block applied every k blocks
    shared_attn_every: int = 0
    # encoder-decoder (whisper)
    is_encdec: bool = False
    encoder_layers: int = 0
    # modality frontend stub
    frontend: str = ""          # "" | audio | vision
    frontend_seq: int = 0       # precomputed embedding length
    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    # training
    remat: str = "dots"         # none | dots | full
    accum_steps: int = 1
    # perf knobs (section Perf hillclimbing)
    attn_tp: str = "packed"     # packed | auto (heads-aware) | off
    scan_dtype: str = "float32"  # mamba chunk-scan compute dtype
    scan_chunk: int = 64         # mamba chunk length

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test sized variant of the same family (CPU-runnable)."""
        base = dict(
            n_layers=min(self.n_layers, 2 if not self.is_encdec else 2),
            d_model=128,
            n_heads=max(min(self.n_heads, 4), 1),
            n_kv_heads=max(min(self.n_kv_heads, 2), 1),
            d_ff=256 if self.n_experts == 0 else 64,
            vocab=512,
            head_dim=32,
            window=64,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            ssm_state=min(self.ssm_state, 8),
            ssm_heads=min(self.ssm_heads, 4) if self.ssm_heads else 0,
            shared_attn_every=2 if self.shared_attn_every else 0,
            encoder_layers=min(self.encoder_layers, 2),
            frontend_seq=min(self.frontend_seq, 16) if self.frontend_seq else 0,
            dtype="float32",
            name=self.name + "-smoke",
        )
        base.update(overrides)
        return replace(self, **base)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]
