"""Core transformer layers: norms, RoPE, GQA attention (full / sliding /
softcapped), gated MLPs, embeddings.  Pure functions over param pytrees."""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = dict


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def init_dense(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def rms_norm(x, w, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, w, b, eps):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = xf.var(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w + b).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float32) / hd))


def apply_rope(x, positions, theta):
    """x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))            # [hd/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softcap(x, cap):
    return cap * jnp.tanh(x / cap) if cap else x


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def init_attention(key, cfg) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    hd = cfg.hd
    return dict(
        wq=init_dense(ks[0], cfg.d_model, cfg.n_heads * hd, dt),
        wk=init_dense(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dt),
        wv=init_dense(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dt),
        wo=init_dense(ks[3], cfg.n_heads * hd, cfg.d_model, dt),
    )


# Above this many score elements per (batch*head) the full S x T score
# tensor is replaced by the flash-style chunked kernel (online softmax).
FLASH_THRESHOLD = 4096 * 4096
FLASH_Q_CHUNK = 1024
FLASH_KV_CHUNK = 1024


def _grouped_scores(q, k):
    """GQA without materializing repeated KV.
    q: [B,S,Hkv,G,hd]; k: [B,T,Hkv,hd] -> [B,Hkv,G,S,T]."""
    return jnp.einsum("bskgd,btkd->bkgst", q, k)


def _attend_dense(q, k, v, qpos, kpos, *, causal, window, attn_softcap,
                  scale):
    """Full-score attention.  q: [B,S,Hkv,G,hd]; k,v: [B,T,Hkv,hd]."""
    b, s, hkv, g, hd = q.shape
    scores = _grouped_scores(q, k).astype(jnp.float32) * scale
    if attn_softcap:
        scores = softcap(scores, attn_softcap)
    mask = (kpos >= 0)[:, None, None, None, :]
    if causal:
        mask = mask & (kpos[:, None, None, None, :]
                       <= qpos[:, None, None, :, None])
    if window:
        mask = mask & (kpos[:, None, None, None, :]
                       > qpos[:, None, None, :, None] - window)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgst,btkd->bskgd", probs, v)


def _attend_flash(q, k, v, qpos, kpos, *, causal, window, attn_softcap,
                  scale, q_chunk=FLASH_Q_CHUNK, kv_chunk=FLASH_KV_CHUNK):
    """Online-softmax chunked attention: never materializes S x T scores.
    Shapes as in _attend_dense.  Double scan: outer q chunks, inner kv."""
    b, s, hkv, g, hd = q.shape
    t = k.shape[1]
    qc = min(q_chunk, s)
    kc = min(kv_chunk, t)
    nq = (s + qc - 1) // qc
    nk = (t + kc - 1) // kc
    # pad to multiples
    def padq(x, fill=0):
        return jnp.pad(x, [(0, 0), (0, nq * qc - s)] + [(0, 0)] * (x.ndim - 2),
                       constant_values=fill)

    def padk(x, fill=0):
        return jnp.pad(x, [(0, 0), (0, nk * kc - t)] + [(0, 0)] * (x.ndim - 2),
                       constant_values=fill)
    qp = padq(q).reshape(b, nq, qc, hkv, g, hd)
    qpp = padq(qpos, -2).reshape(b, nq, qc)
    kp = padk(k).reshape(b, nk, kc, hkv, hd)
    vp = padk(v).reshape(b, nk, kc, hkv, hd)
    kpp = padk(kpos, -1).reshape(b, nk, kc)

    def q_step(_, qi):
        qq, qpos_c = qi                       # [B,qc,Hkv,G,hd], [B,qc]

        def kv_step(carry, ki):
            m, l, acc = carry
            kk, vv, kpos_c = ki
            sc = jnp.einsum("bskgd,btkd->bkgst", qq, kk).astype(jnp.float32)
            sc = sc * scale
            if attn_softcap:
                sc = softcap(sc, attn_softcap)
            msk = (kpos_c >= 0)[:, None, None, None, :]
            msk = msk & (kpos_c[:, None, None, None, :]
                         <= qpos_c[:, None, None, :, None]) if causal else msk
            if window:
                msk = msk & (kpos_c[:, None, None, None, :]
                             > qpos_c[:, None, None, :, None] - window)
            sc = jnp.where(msk, sc, -1e30)
            m_new = jnp.maximum(m, sc.max(-1))             # [B,Hkv,G,qc]
            alpha = jnp.exp(m - m_new)
            pe = jnp.exp(sc - m_new[..., None])
            l_new = l * alpha + pe.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgst,btkd->bkgsd", pe.astype(vv.dtype), vv
            ).astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, g, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, qc), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, qc, hd), jnp.float32)   # f32 accumulator
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kp.transpose(1, 0, 2, 3, 4), vp.transpose(1, 0, 2, 3, 4),
             kpp.transpose(1, 0, 2)))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(v.dtype)
        return None, out.transpose(0, 3, 1, 2, 4)          # [B,qc,Hkv,G,hd]

    _, outs = jax.lax.scan(q_step, None,
                           (qp.transpose(1, 0, 2, 3, 4, 5),
                            qpp.transpose(1, 0, 2)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * qc, hkv, g, hd)
    return out[:, :s]


def attention(p, cfg, x, positions, *, causal=True, window=0,
              kv=None, kv_positions=None, cross_kv=None):
    """Batched GQA without KV repetition.  x: [B,S,D].

    kv: optional precomputed (k, v) tensors [B,T,Hkv,hd] (decode w/ cache or
    cross attention); kv_positions: [B,T] (masking; -1 = invalid slot).
    """
    b, s, _ = x.shape
    hd = cfg.hd
    hkv = cfg.n_kv_heads
    g = cfg.n_heads // hkv
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    if cross_kv is not None:
        k, v = cross_kv
        kpos = kv_positions
        causal = False
        window = 0
    elif kv is not None:
        k, v = kv
        kpos = kv_positions
        q = apply_rope(q, positions, cfg.rope_theta)
    else:
        k = (x @ p["wk"]).reshape(b, s, hkv, hd)
        v = (x @ p["wv"]).reshape(b, s, hkv, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        kpos = positions
    qg = q.reshape(b, s, hkv, g, hd)
    scale = 1.0 / math.sqrt(hd)
    big = s * k.shape[1] > FLASH_THRESHOLD
    fn = _attend_flash if big else _attend_dense
    out = fn(qg, k, v, positions, kpos, causal=causal, window=window,
             attn_softcap=cfg.attn_softcap, scale=scale)
    return out.reshape(b, s, cfg.n_heads * hd) @ p["wo"]


def project_kv(p, cfg, x, positions):
    """Compute rotated (k, v) for cache insertion. x: [B,S,D]."""
    b, s, _ = x.shape
    hd = cfg.hd
    k = (x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg, d_ff=None) -> Params:
    dt = _dtype(cfg)
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = dict(
        w_up=init_dense(ks[0], cfg.d_model, d_ff, dt),
        w_down=init_dense(ks[1], d_ff, cfg.d_model, dt),
    )
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = init_dense(ks[2], cfg.d_model, d_ff, dt)
    return p


def mlp(p, cfg, x):
    up = x @ p["w_up"]
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * up
    elif cfg.act == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * up
    else:
        h = jax.nn.gelu(up, approximate=True)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def init_embedding(key, cfg) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 2)
    p = dict(tok=(jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dt))
    if not cfg.tie_embeddings:
        p["head"] = init_dense(ks[1], cfg.d_model, cfg.vocab, dt)
    return p


def embed(p, cfg, tokens):
    return jnp.take(p["tok"], tokens, axis=0)


def lm_logits(p, cfg, x):
    if cfg.tie_embeddings:
        logits = x @ p["tok"].T
    else:
        logits = x @ p["head"]
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap:
        logits = softcap(logits, cfg.logit_softcap)
    return logits
