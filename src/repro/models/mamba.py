"""Mamba-1 selective scan and Mamba-2 SSD blocks (falcon-mamba / zamba2).

Training/prefill uses `jax.lax.associative_scan` over the sequence — the
parallel-scan formulation maps the recurrence  h_t = A_t ⊙ h_{t-1} + B_t x_t
onto TPU's log-depth tree reduction.  Decode is a single O(1) state update —
which is why `long_500k` decode is trivial for SSM archs while full-attention
archs are skipped (DESIGN.md section 4).

State layout:
  mamba1: conv state [B, d_conv-1, d_inner]; ssm state [B, d_inner, d_state]
  mamba2: conv state [B, d_conv-1, d_inner(+2*groups*d_state)];
          ssm state [B, n_heads, head_dim, d_state]
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import init_dense, _dtype


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------


def init_mamba(key, cfg) -> dict:
    dt = _dtype(cfg)
    di = cfg.d_inner
    ds = cfg.ssm_state
    ks = jax.random.split(key, 8)
    dt_rank = max(cfg.d_model // 16, 1)
    return dict(
        w_in=init_dense(ks[0], cfg.d_model, 2 * di, dt),
        conv_w=(jax.random.normal(ks[1], (cfg.d_conv, di), jnp.float32)
                * 0.1).astype(dt),
        conv_b=jnp.zeros((di,), dt),
        w_xbc=init_dense(ks[2], di, dt_rank + 2 * ds, dt),
        w_dt=init_dense(ks[3], dt_rank, di, dt),
        dt_bias=jnp.zeros((di,), jnp.float32),
        a_log=jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32),
                               (di, 1))),           # [di, ds]
        d_skip=jnp.ones((di,), jnp.float32),
        w_out=init_dense(ks[4], di, cfg.d_model, dt),
    )


def _causal_conv(x, w, b, state=None):
    """x: [B,S,C]; w: [K,C] depthwise.  Returns (y, new_state)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # [B, S+K-1, C]
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    new_state = xp[:, -(k - 1):, :] if k > 1 else jnp.zeros_like(pad)
    return jax.nn.silu(y + b), new_state


SCAN_CHUNK = 64   # sequence chunk for the selective scan (memory knob):
                  # per-chunk state tensor is [B, chunk, d_inner, d_state]


def _scan_combine(a, b):
    a_l, b_l = a
    a_r, b_r = b
    return a_l * a_r, b_l * a_r + b_r


def _selective_scan(u, dt_, A, B, C, h0=None, chunk: int = SCAN_CHUNK):
    """u: [B,S,di]; dt_: [B,S,di]; A: [di,ds]; B,C: [B,S,ds].
    Returns (y [B,S,di], h_last [B,di,ds]).

    Chunked over the sequence: an outer lax.scan carries the state across
    chunks, the inner associative_scan parallelizes within a chunk — the
    full [B,S,di,ds] tensor (550 TB for falcon-mamba at 32k!) is never
    materialized; peak is [B,chunk,di,ds].
    """
    b, s, di = u.shape
    ds = A.shape[1]
    sdt = u.dtype                 # scan compute dtype (perf knob)
    if h0 is None:
        h0 = jnp.zeros((b, di, ds), jnp.float32)
    c = min(chunk, s)
    nc = (s + c - 1) // c
    pad = nc * c - s

    def padded(x):
        return jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))

    uc = padded(u).reshape(b, nc, c, di).transpose(1, 0, 2, 3)
    dtc = padded(dt_).reshape(b, nc, c, di).transpose(1, 0, 2, 3)
    Bc = padded(B).reshape(b, nc, c, ds).transpose(1, 0, 2, 3)
    Cc = padded(C).reshape(b, nc, c, ds).transpose(1, 0, 2, 3)

    def chunk_step(h, inp):
        u1, dt1, B1, C1 = inp                      # [B,c,...]
        dA = jnp.exp(dt1[..., None] * A[None, None]).astype(sdt)
        dBu = (dt1[..., None] * B1[:, :, None, :]
               * u1[..., None]).astype(sdt)        # [B,c,di,ds]
        dBu = dBu.at[:, 0].add((dA[:, 0].astype(jnp.float32) * h).astype(sdt))
        _, hh = jax.lax.associative_scan(_scan_combine, (dA, dBu), axis=1)
        y = jnp.einsum("bsdn,bsn->bsd", hh, C1)
        return hh[:, -1].astype(jnp.float32), y    # f32 carry across chunks

    h_last, ys = jax.lax.scan(chunk_step, h0, (uc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3).reshape(b, nc * c, di)[:, :s]
    return y, h_last


def mamba_block(p, cfg, x, state=None):
    """x: [B,S,D] -> (y, new_state).  state = (conv_state, ssm_state)."""
    b, s, _ = x.shape
    di = cfg.d_inner
    ds = cfg.ssm_state
    dt_rank = p["w_dt"].shape[0]
    xz = x @ p["w_in"]
    u, z = jnp.split(xz, 2, axis=-1)
    conv_state = state[0] if state is not None else None
    u, new_conv = _causal_conv(u, p["conv_w"], p["conv_b"], conv_state)
    xbc = u @ p["w_xbc"]
    dt_in, Bm, Cm = jnp.split(xbc, [dt_rank, dt_rank + ds], axis=-1)
    dt_ = jax.nn.softplus((dt_in @ p["w_dt"]).astype(jnp.float32)
                          + p["dt_bias"])
    A = -jnp.exp(p["a_log"])                                # [di, ds]
    h0 = state[1] if state is not None else None
    sdt = jnp.dtype(getattr(cfg, "scan_dtype", "float32"))
    y, h_last = _selective_scan(u.astype(sdt), dt_.astype(sdt), A.astype(sdt),
                                Bm.astype(sdt), Cm.astype(sdt), h0,
                                chunk=getattr(cfg, "scan_chunk", SCAN_CHUNK))
    y = y.astype(jnp.float32)
    y = y + u.astype(jnp.float32) * p["d_skip"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    return y @ p["w_out"], (new_conv, h_last)


def mamba_decode_step(p, cfg, x, state):
    """Single-token decode: x [B,1,D]; O(1) state update."""
    return mamba_block(p, cfg, x, state)


# ---------------------------------------------------------------------------
# Mamba-2 (SSD, scalar-decay-per-head)
# ---------------------------------------------------------------------------


def init_mamba2(key, cfg) -> dict:
    dt = _dtype(cfg)
    di = cfg.d_inner
    ds = cfg.ssm_state
    nh = cfg.ssm_heads or max(di // 64, 1)
    ks = jax.random.split(key, 6)
    return dict(
        w_in=init_dense(ks[0], cfg.d_model, 2 * di + 2 * ds + nh, dt),
        conv_w=(jax.random.normal(ks[1], (cfg.d_conv, di + 2 * ds),
                                  jnp.float32) * 0.1).astype(dt),
        conv_b=jnp.zeros((di + 2 * ds,), dt),
        a_log=jnp.zeros((nh,), jnp.float32),
        dt_bias=jnp.zeros((nh,), jnp.float32),
        d_skip=jnp.ones((nh,), jnp.float32),
        norm_w=jnp.zeros((di,), jnp.float32),
        w_out=init_dense(ks[2], di, cfg.d_model, dt),
    )


def _ssd_scan(u_h, dt_, A_h, Bm, Cm, h0, chunk: int = SCAN_CHUNK):
    """Mamba-2 SSD dual form, chunked.

    u_h: [B,S,nh,hd]; dt_: [B,S,nh]; A_h: [nh] (negative); Bm,Cm: [B,S,ds];
    h0: [B,nh,hd,ds].  Within a chunk the recurrence collapses to an
    attention-like [c,c] decay-weighted matmul (never materializes the
    per-position state tensor); across chunks a lax.scan carries the state.
    """
    b, s, nh, hd = u_h.shape
    ds = Bm.shape[-1]
    c = min(chunk, s)
    nc = (s + c - 1) // c
    pad = nc * c - s

    def padded(x):
        return jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2))

    ld = A_h[None, None, :] * dt_                    # [B,S,nh] log-decay <= 0
    uc = padded(u_h).reshape(b, nc, c, nh, hd).transpose(1, 0, 2, 3, 4)
    dtc = padded(dt_).reshape(b, nc, c, nh).transpose(1, 0, 2, 3)
    ldc = padded(ld).reshape(b, nc, c, nh).transpose(1, 0, 2, 3)
    Bc = padded(Bm).reshape(b, nc, c, ds).transpose(1, 0, 2, 3)
    Cc = padded(Cm).reshape(b, nc, c, ds).transpose(1, 0, 2, 3)

    def chunk_step(h, inp):
        u1, dt1, ld1, B1, C1 = inp
        g = jnp.cumsum(ld1, axis=1)                          # [B,c,nh]
        # intra-chunk: w[t,s] = exp(g_t - g_s) * dt_s * (C_t . B_s), s <= t
        cb = jnp.einsum("btk,bsk->bts", C1, B1)              # [B,c,c]
        dec = jnp.exp(g[:, :, None, :] - g[:, None, :, :])   # [B,t,s,nh]
        tri = jnp.tril(jnp.ones((c, c), bool))
        w = jnp.where(tri[None, :, :, None],
                      dec * dt1[:, None, :, :], 0.0) * cb[..., None]
        y_intra = jnp.einsum("btsn,bsnd->btnd", w, u1)
        # inter-chunk: y_t += exp(g_t) * (C_t . h)
        y_inter = (jnp.exp(g)[..., None]
                   * jnp.einsum("btk,bndk->btnd", C1, h))
        # state: h' = exp(g_end)*h + sum_s exp(g_end - g_s)*dt_s * u_s (x) B_s
        g_end = g[:, -1]                                     # [B,nh]
        w_end = jnp.exp(g_end[:, None, :] - g) * dt1         # [B,c,nh]
        h_new = (jnp.exp(g_end)[:, :, None, None] * h
                 + jnp.einsum("bsn,bsnd,bsk->bndk", w_end, u1, B1))
        return h_new, y_intra + y_inter

    if h0 is None:
        h0 = jnp.zeros((b, nh, hd, ds), u_h.dtype)
    h_last, ys = jax.lax.scan(chunk_step, h0, (uc, dtc, ldc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, nc * c, nh, hd)[:, :s]
    return y, h_last


def mamba2_block(p, cfg, x, state=None):
    """SSD with scalar per-head decay.  x: [B,S,D]."""
    b, s, _ = x.shape
    di = cfg.d_inner
    ds = cfg.ssm_state
    nh = cfg.ssm_heads or max(di // 64, 1)
    hd = di // nh
    zxbcdt = x @ p["w_in"]
    z, xbc, dt_in = jnp.split(zxbcdt, [di, 2 * di + 2 * ds], axis=-1)
    conv_state = state[0] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    u, Bm, Cm = jnp.split(xbc, [di, di + ds], axis=-1)
    dt_ = jax.nn.softplus(dt_in.astype(jnp.float32) + p["dt_bias"])  # [B,S,nh]
    A_h = -jnp.exp(p["a_log"])                                        # [nh]
    u_h = u.reshape(b, s, nh, hd).astype(jnp.float32)
    h0 = state[1] if state is not None else None
    y, h_last = _ssd_scan(u_h, dt_, A_h, Bm.astype(jnp.float32),
                          Cm.astype(jnp.float32), h0)
    y = y + u_h * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, di)
    # gated RMSNorm (mamba2)
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(var + 1e-5) * (1.0 + p["norm_w"])
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["w_out"], (new_conv, h_last)
