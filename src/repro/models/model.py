"""Unified decoder LM covering all assigned families, built as a
scan-over-layers with stacked params (small HLO at any depth, remat-friendly).

Families:
  dense        — llama-style pre-norm GQA + gated MLP (granite, phi3,
                 command-r [parallel block], internvl2 backbone)
  dense+gemma2 — alternating local/global attention, attn & logit softcaps,
                 post-norms
  moe          — router + sort-based capacity dispatch (granite-moe, grok)
  ssm          — mamba-1 stack (falcon-mamba)
  hybrid       — mamba-2 stack + ONE shared attention block applied every k
                 blocks (zamba2)
  audio        — whisper-style encoder-decoder (frontend stubbed)
  vlm          — dense backbone consuming precomputed patch embeds + tokens

Entry points: init_params, forward_train, prefill, decode_step, make_cache.
All are pure; distribution happens in launch/ via pjit shardings.
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from . import mamba as M
from . import moe as X
from .config import ModelConfig

Params = dict


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _layer_init(cfg: ModelConfig):
    """Return an init function for ONE layer's params (to be vmapped)."""
    def init_one(key):
        ks = jax.random.split(key, 8)
        dt = jnp.dtype(cfg.dtype)
        p: Params = {}
        if cfg.family == "ssm":
            p["norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
            p["mamba"] = M.init_mamba(ks[0], cfg)
        elif cfg.family == "hybrid":
            p["norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
            p["mamba"] = M.init_mamba2(ks[0], cfg)
        else:
            p["norm1"] = jnp.zeros((cfg.d_model,), jnp.float32)
            p["norm2"] = jnp.zeros((cfg.d_model,), jnp.float32)
            p["attn"] = L.init_attention(ks[0], cfg)
            if cfg.attn_type == "local_global":   # gemma2 post-norms
                p["post_norm1"] = jnp.zeros((cfg.d_model,), jnp.float32)
                p["post_norm2"] = jnp.zeros((cfg.d_model,), jnp.float32)
            if cfg.n_experts > 0:
                p["moe"] = X.init_moe(ks[1], cfg)
            else:
                p["mlp"] = L.init_mlp(ks[1], cfg)
        return p
    return init_one


def init_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = dict(embed=L.init_embedding(ks[0], cfg))
    layer_keys = jax.random.split(ks[1], cfg.n_layers)
    p["layers"] = jax.vmap(_layer_init(cfg))(layer_keys)
    p["final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        sk = jax.random.split(ks[2], 3)
        p["shared_attn"] = dict(
            norm1=jnp.zeros((cfg.d_model,), jnp.float32),
            norm2=jnp.zeros((cfg.d_model,), jnp.float32),
            attn=L.init_attention(sk[0], cfg),
            mlp=L.init_mlp(sk[1], cfg),
        )
    if cfg.is_encdec:
        enc_cfg = cfg
        ek = jax.random.split(ks[3], cfg.encoder_layers)

        def enc_init(k):
            k1, k2 = jax.random.split(k)
            return dict(norm1=jnp.zeros((cfg.d_model,), jnp.float32),
                        norm2=jnp.zeros((cfg.d_model,), jnp.float32),
                        attn=L.init_attention(k1, enc_cfg),
                        mlp=L.init_mlp(k2, enc_cfg))
        p["encoder"] = jax.vmap(enc_init)(ek)
        p["enc_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        # decoder cross-attention params per layer
        ck = jax.random.split(ks[4], cfg.n_layers)

        def cross_init(k):
            return dict(norm=jnp.zeros((cfg.d_model,), jnp.float32),
                        attn=L.init_attention(k, cfg))
        p["cross"] = jax.vmap(cross_init)(ck)
    if cfg.frontend == "vision":
        # learned projection for the (stubbed) patch embeddings
        p["patch_proj"] = L.init_dense(ks[5], cfg.d_model, cfg.d_model,
                                       jnp.dtype(cfg.dtype))
    return p


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------


def _is_global_layer(cfg, i):
    # gemma2: alternate local (even) / global (odd)
    return (i % 2) == 1


def _attn_block(pl_, cfg, x, positions, kv=None, kv_positions=None,
                window=0):
    h = L.rms_norm(x, pl_["norm1"], cfg.norm_eps)
    a = L.attention(pl_["attn"], cfg, h, positions, causal=True,
                    window=window, kv=kv, kv_positions=kv_positions)
    if cfg.attn_type == "local_global":
        a = L.rms_norm(a, pl_["post_norm1"], cfg.norm_eps)
    if cfg.parallel_block:
        m = L.mlp(pl_["mlp"], cfg, L.rms_norm(x, pl_["norm2"], cfg.norm_eps))
        return x + a + m, jnp.float32(0.0)
    x = x + a
    h = L.rms_norm(x, pl_["norm2"], cfg.norm_eps)
    if cfg.n_experts > 0:
        m, aux = X.moe_block(pl_["moe"], cfg, h)
    else:
        m, aux = L.mlp(pl_["mlp"], cfg, h), jnp.float32(0.0)
    if cfg.attn_type == "local_global":
        m = L.rms_norm(m, pl_["post_norm2"], cfg.norm_eps)
    return x + m, aux


def _remat(f, cfg):
    if cfg.remat == "none":
        return f
    if cfg.remat == "full":
        return jax.checkpoint(f)
    return jax.checkpoint(
        f, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def _run_decoder(params, cfg: ModelConfig, x, positions, *,
                 make_cache_out=False, enc_out=None, enc_positions=None,
                 shared_cache=None):
    """Scan over stacked layers.  Returns (x, aux_loss, cache_kv or None).

    cache_kv (when make_cache_out): per-layer rotated (k, v) — stacked ys.
    """
    b, s, _ = x.shape
    li = jnp.arange(cfg.n_layers)

    if cfg.family in ("ssm", "hybrid"):
        blk = M.mamba_block if cfg.family == "ssm" else M.mamba2_block
        shared = params.get("shared_attn")
        k_every = cfg.shared_attn_every
        fill_shared = (make_cache_out and shared is not None
                       and shared_cache is not None)

        def body(carry, inp):
            x, sk, sv = carry
            pl_, i = inp
            h = L.rms_norm(x, pl_["norm"], cfg.norm_eps)
            y, st = blk(pl_["mamba"], cfg, h)
            x = x + y
            if shared is not None and k_every:
                def apply_shared(args):
                    x, sk, sv = args
                    h = L.rms_norm(x, shared["norm1"], cfg.norm_eps)
                    if fill_shared:
                        kk, vv = L.project_kv(shared["attn"], cfg, h,
                                              positions)
                        site = i // k_every
                        zi = jnp.zeros((), site.dtype)
                        sk = jax.lax.dynamic_update_slice(
                            sk, kk[None].astype(sk.dtype),
                            (site, zi, zi, zi, zi))
                        sv = jax.lax.dynamic_update_slice(
                            sv, vv[None].astype(sv.dtype),
                            (site, zi, zi, zi, zi))
                    a = L.attention(shared["attn"], cfg, h, positions,
                                    causal=True)
                    x = x + a
                    h = L.rms_norm(x, shared["norm2"], cfg.norm_eps)
                    return x + L.mlp(shared["mlp"], cfg, h), sk, sv
                x, sk, sv = jax.lax.cond((i % k_every) == (k_every - 1),
                                         apply_shared, lambda a: a,
                                         (x, sk, sv))
            out = st if make_cache_out else None
            return (x, sk, sv), out

        body = _remat(body, cfg)
        if fill_shared:
            sk0, sv0 = shared_cache
        else:
            sk0 = sv0 = jnp.zeros((1,), x.dtype)   # placeholder carry
        (x, sk, sv), states = jax.lax.scan(body, (x, sk0, sv0),
                                           (params["layers"], li))
        return x, jnp.float32(0.0), (states, (sk, sv) if fill_shared else None)

    # attention families
    def body(carry, inp):
        x, aux = carry
        pl_, i = inp
        if cfg.attn_type == "local_global":
            # window must be static for the masking math: two-branch cond
            def local_fn(x):
                return _attn_block(pl_, cfg, x, positions, window=cfg.window)

            def global_fn(x):
                return _attn_block(pl_, cfg, x, positions, window=0)
            x2, a2 = jax.lax.cond(_is_global_layer(cfg, i), global_fn,
                                  local_fn, x)
        else:
            x2, a2 = _attn_block(pl_, cfg, x, positions, window=0)
        cache_out = None
        if make_cache_out:
            h = L.rms_norm(x, pl_["norm1"], cfg.norm_eps)
            cache_out = L.project_kv(pl_["attn"], cfg, h, positions)
        return (x2, aux + a2), cache_out

    if not cfg.is_encdec:
        body = _remat(body, cfg)
        (x, aux), cache = jax.lax.scan(body, (x, jnp.float32(0.0)),
                                       (params["layers"], li))
        return x, aux, cache

    # ---- enc-dec path (whisper): self-attn -> cross-attn -> FFN ----------
    def body_ed(carry, inp):
        x, aux = carry
        pl_, pc, i = inp
        h = L.rms_norm(x, pl_["norm1"], cfg.norm_eps)
        cache_out = (L.project_kv(pl_["attn"], cfg, h, positions)
                     if make_cache_out else None)
        a = L.attention(pl_["attn"], cfg, h, positions, causal=True)
        x = x + a
        h = L.rms_norm(x, pc["norm"], cfg.norm_eps)
        ca = L.attention(pc["attn"], cfg, h, positions,
                         cross_kv=_cross_kv(pc["attn"], cfg, enc_out),
                         kv_positions=enc_positions)
        x = x + ca
        h = L.rms_norm(x, pl_["norm2"], cfg.norm_eps)
        x = x + L.mlp(pl_["mlp"], cfg, h)
        return (x, aux), cache_out

    body_ed = _remat(body_ed, cfg)
    (x, aux), cache = jax.lax.scan(
        body_ed, (x, jnp.float32(0.0)),
        (params["layers"], params["cross"], li))
    return x, aux, cache


def _cross_kv(pa, cfg, enc_out):
    b, t, _ = enc_out.shape
    hd = cfg.hd
    k = (enc_out @ pa["wk"]).reshape(b, t, cfg.n_kv_heads, hd)
    v = (enc_out @ pa["wv"]).reshape(b, t, cfg.n_kv_heads, hd)
    return k, v


def run_encoder(params, cfg: ModelConfig, frames):
    """Whisper encoder over (stubbed) frame embeddings [B, T, D]."""
    b, t, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    x = frames

    def body(x, pl_):
        h = L.rms_norm(x, pl_["norm1"], cfg.norm_eps)
        a = L.attention(pl_["attn"], cfg, h, positions, causal=False)
        x = x + a
        h = L.rms_norm(x, pl_["norm2"], cfg.norm_eps)
        return x + L.mlp(pl_["mlp"], cfg, h), None

    body = _remat(body, cfg)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def forward_train(params, cfg: ModelConfig, tokens, extra_embeds=None,
                  enc_frames=None):
    """tokens: [B,S] -> logits [B,S,V] (f32), aux loss."""
    b, s = tokens.shape
    x = L.embed(params["embed"], cfg, tokens)
    if cfg.family == "vlm" and extra_embeds is not None:
        patches = extra_embeds @ params["patch_proj"]
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), (b, x.shape[1]))
    enc_out = enc_positions = None
    if cfg.is_encdec:
        enc_out = run_encoder(params, cfg, enc_frames)
        enc_positions = jnp.broadcast_to(jnp.arange(enc_out.shape[1]),
                                         (b, enc_out.shape[1]))
    x, aux, _ = _run_decoder(params, cfg, x, positions, enc_out=enc_out,
                             enc_positions=enc_positions)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.family == "vlm" and extra_embeds is not None:
        x = x[:, -s:]           # logits over the text positions only
    return L.lm_logits(params["embed"], cfg, x), aux


def loss_fn(params, cfg, tokens, labels, extra_embeds=None, enc_frames=None):
    logits, aux = forward_train(params, cfg, tokens,
                                extra_embeds=extra_embeds,
                                enc_frames=enc_frames)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return nll.mean() + 0.01 * aux


# ---------------------------------------------------------------------------
# serving: cache creation, prefill, decode
# ---------------------------------------------------------------------------


def make_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dt = jnp.dtype(dtype or cfg.dtype)
    hd = cfg.hd
    if cfg.family == "ssm":
        di = cfg.d_inner
        return dict(
            conv=jnp.zeros((cfg.n_layers, batch, cfg.d_conv - 1, di), dt),
            ssm=jnp.zeros((cfg.n_layers, batch, di, cfg.ssm_state),
                          jnp.float32),
            pos=jnp.zeros((), jnp.int32))
    if cfg.family == "hybrid":
        di = cfg.d_inner
        nh = cfg.ssm_heads or max(di // 64, 1)
        n_sites = (cfg.n_layers + cfg.shared_attn_every - 1) \
            // max(cfg.shared_attn_every, 1) if cfg.shared_attn_every else 0
        c = dict(
            conv=jnp.zeros((cfg.n_layers, batch, cfg.d_conv - 1,
                            di + 2 * cfg.ssm_state), dt),
            ssm=jnp.zeros((cfg.n_layers, batch, nh, di // nh, cfg.ssm_state),
                          jnp.float32),
            pos=jnp.zeros((), jnp.int32))
        if n_sites:
            c["shared_k"] = jnp.zeros((n_sites, batch, max_len,
                                       cfg.n_kv_heads, hd), dt)
            c["shared_v"] = jnp.zeros_like(c["shared_k"])
        return c
    return dict(
        k=jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dt),
        v=jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dt),
        pos=jnp.zeros((), jnp.int32))


def prefill(params, cfg: ModelConfig, tokens, cache, extra_embeds=None,
            enc_frames=None):
    """Run the prompt, fill the cache, return (last-token logits, cache)."""
    b, s = tokens.shape
    x = L.embed(params["embed"], cfg, tokens)
    if cfg.family == "vlm" and extra_embeds is not None:
        patches = extra_embeds @ params["patch_proj"]
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    s_eff = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s_eff), (b, s_eff))
    enc_out = enc_positions = None
    if cfg.is_encdec:
        enc_out = run_encoder(params, cfg, enc_frames)
        enc_positions = jnp.broadcast_to(jnp.arange(enc_out.shape[1]),
                                         (b, enc_out.shape[1]))
        cache = dict(cache, enc_out=enc_out)
    shared_cache = None
    if cfg.family == "hybrid" and "shared_k" in cache:
        # prefill writes into the leading s_eff positions of the site caches
        sk = cache["shared_k"][:, :, :s_eff]
        sv = cache["shared_v"][:, :, :s_eff]
        shared_cache = (sk, sv)
    x, aux, kv = _run_decoder(params, cfg, x, positions, make_cache_out=True,
                              enc_out=enc_out, enc_positions=enc_positions,
                              shared_cache=shared_cache)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(params["embed"], cfg, x[:, -1:])
    if cfg.family == "ssm":
        (conv, ssm), _ = kv
        cache = dict(cache, conv=conv, ssm=ssm,
                     pos=jnp.asarray(s_eff, jnp.int32))
    elif cfg.family == "hybrid":
        (conv, ssm), shared_kv = kv
        cache = dict(cache, conv=conv, ssm=ssm,
                     pos=jnp.asarray(s_eff, jnp.int32))
        if shared_kv is not None:
            sk, sv = shared_kv
            cache["shared_k"] = jax.lax.dynamic_update_slice_in_dim(
                cache["shared_k"], sk.astype(cache["shared_k"].dtype), 0,
                axis=2)
            cache["shared_v"] = jax.lax.dynamic_update_slice_in_dim(
                cache["shared_v"], sv.astype(cache["shared_v"].dtype), 0,
                axis=2)
    else:
        k, v = kv                   # [L, B, S, Hkv, hd]
        cache = dict(cache,
                     k=jax.lax.dynamic_update_slice_in_dim(
                         cache["k"], k.astype(cache["k"].dtype), 0, axis=2),
                     v=jax.lax.dynamic_update_slice_in_dim(
                         cache["v"], v.astype(cache["v"].dtype), 0, axis=2),
                     pos=jnp.asarray(s_eff, jnp.int32))
    return logits, cache


def decode_step(params, cfg: ModelConfig, token, cache):
    """One token for the whole batch.  token: [B, 1]."""
    b = token.shape[0]
    x = L.embed(params["embed"], cfg, token)
    pos_scalar = cache["pos"]
    positions = jnp.broadcast_to(pos_scalar, (b, 1)).astype(jnp.int32)
    li = jnp.arange(cfg.n_layers)

    if cfg.family in ("ssm", "hybrid"):
        blk = (M.mamba_block if cfg.family == "ssm" else M.mamba2_block)
        shared = params.get("shared_attn")
        k_every = cfg.shared_attn_every
        site_of = li // max(k_every, 1) if k_every else li * 0

        def body(carry, inp):
            x, sk, sv = carry
            pl_, conv, ssm, i, site = inp
            h = L.rms_norm(x, pl_["norm"], cfg.norm_eps)
            y, (conv2, ssm2) = blk(pl_["mamba"], cfg, h, (conv, ssm))
            x = x + y
            if shared is not None and k_every:
                def apply_shared(args):
                    x, sk, sv = args
                    h = L.rms_norm(x, shared["norm1"], cfg.norm_eps)
                    kk, vv = L.project_kv(shared["attn"], cfg, h, positions)
                    z = pos_scalar * 0
                    skc = jax.lax.dynamic_update_slice(
                        sk, kk[None].astype(sk.dtype),
                        (site.astype(pos_scalar.dtype), z, pos_scalar, z, z))
                    svc = jax.lax.dynamic_update_slice(
                        sv, vv[None].astype(sv.dtype),
                        (site.astype(pos_scalar.dtype), z, pos_scalar, z, z))
                    t = skc.shape[2]
                    kv_pos = jnp.where(jnp.arange(t) <= pos_scalar,
                                       jnp.arange(t), -1)
                    kv_pos = jnp.broadcast_to(kv_pos, (b, t))
                    a = L.attention(shared["attn"], cfg, h, positions,
                                    kv=(skc[site], svc[site]),
                                    kv_positions=kv_pos)
                    x = x + a
                    h2 = L.rms_norm(x, shared["norm2"], cfg.norm_eps)
                    return x + L.mlp(shared["mlp"], cfg, h2), skc, svc
                x, sk, sv = jax.lax.cond(
                    (i % k_every) == (k_every - 1), apply_shared,
                    lambda args: args, (x, sk, sv))
            return (x, sk, sv), (conv2, ssm2)

        sk = cache.get("shared_k", jnp.zeros((1, b, 1, cfg.n_kv_heads,
                                              cfg.hd), x.dtype))
        sv = cache.get("shared_v", sk)
        (x, sk, sv), (conv, ssm) = jax.lax.scan(
            body, (x, sk, sv),
            (params["layers"], cache["conv"], cache["ssm"], li, site_of))
        x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = L.lm_logits(params["embed"], cfg, x)
        new_cache = dict(cache, conv=conv, ssm=ssm, pos=pos_scalar + 1)
        if "shared_k" in cache:
            new_cache["shared_k"] = sk
            new_cache["shared_v"] = sv
        return logits, new_cache

    # attention families: update per-layer KV, attend over prefix
    t = cache["k"].shape[2]
    kv_pos_row = jnp.where(jnp.arange(t) <= pos_scalar, jnp.arange(t), -1)
    kv_pos = jnp.broadcast_to(kv_pos_row, (b, t))

    enc_out = cache.get("enc_out")
    enc_positions = None
    if enc_out is not None:
        enc_positions = jnp.broadcast_to(jnp.arange(enc_out.shape[1]),
                                         (b, enc_out.shape[1]))

    def body(carry, inp):
        x, aux = carry
        if cfg.is_encdec:
            pl_, pc, kc, vc, i = inp
        else:
            pl_, kc, vc, i = inp
            pc = None
        h = L.rms_norm(x, pl_["norm1"], cfg.norm_eps)
        kk, vv = L.project_kv(pl_["attn"], cfg, h, positions)
        z = pos_scalar * 0
        kc = jax.lax.dynamic_update_slice(kc, kk.astype(kc.dtype),
                                          (z, pos_scalar, z, z))
        vc = jax.lax.dynamic_update_slice(vc, vv.astype(vc.dtype),
                                          (z, pos_scalar, z, z))

        def do_attn(window):
            return L.attention(pl_["attn"], cfg, h, positions,
                               kv=(kc, vc), kv_positions=kv_pos,
                               window=window)
        if cfg.attn_type == "local_global":
            a = jax.lax.cond(_is_global_layer(cfg, i),
                             lambda: do_attn(0), lambda: do_attn(cfg.window))
        else:
            a = do_attn(0)
        if cfg.attn_type == "local_global":
            a = L.rms_norm(a, pl_["post_norm1"], cfg.norm_eps)
        if cfg.parallel_block:
            m = L.mlp(pl_["mlp"], cfg,
                      L.rms_norm(x, pl_["norm2"], cfg.norm_eps))
            x = x + a + m
            return (x, aux), (kc, vc)
        x = x + a
        if pc is not None:
            hh = L.rms_norm(x, pc["norm"], cfg.norm_eps)
            ca = L.attention(pc["attn"], cfg, hh, positions,
                             cross_kv=_cross_kv(pc["attn"], cfg, enc_out),
                             kv_positions=enc_positions)
            x = x + ca
        h2 = L.rms_norm(x, pl_["norm2"], cfg.norm_eps)
        if cfg.n_experts > 0:
            m, a2 = X.moe_block(pl_["moe"], cfg, h2)
        else:
            m, a2 = L.mlp(pl_["mlp"], cfg, h2), jnp.float32(0.0)
        if cfg.attn_type == "local_global":
            m = L.rms_norm(m, pl_["post_norm2"], cfg.norm_eps)
        return (x + m, aux + a2), (kc, vc)

    xs = ((params["layers"], params["cross"], cache["k"], cache["v"], li)
          if cfg.is_encdec else
          (params["layers"], cache["k"], cache["v"], li))
    (x, aux), (k2, v2) = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(params["embed"], cfg, x)
    return logits, dict(cache, k=k2, v=v2, pos=pos_scalar + 1)
