"""Mixture-of-Experts block: top-k routing with sort-based capacity dispatch.

Dispatch is the gather/scatter-by-sort formulation (dropless up to the
capacity factor): token-expert assignments are sorted by expert, the first C
per expert are gathered into [E, C, d] and processed by a single batched
einsum — active-FLOPs-proportional, unlike the dense one-hot dispatch.

Sharding (parallel/sharding.py):
  * EP  when n_experts % |model| == 0: expert dim sharded over "model";
  * expert-TP otherwise: d_ff dim sharded over "model";
weights always FSDP over ("pod","data") on the d_model dim.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .layers import init_dense, _dtype


def init_moe(key, cfg) -> dict:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    s = 1.0 / math.sqrt(D)
    p = dict(
        router=init_dense(ks[0], D, E, jnp.float32),
        w_up=(jax.random.normal(ks[1], (E, D, F), jnp.float32) * s).astype(dt),
        w_down=(jax.random.normal(ks[2], (E, F, D), jnp.float32)
                * (1.0 / math.sqrt(F))).astype(dt),
    )
    if cfg.act in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(ks[3], (E, D, F), jnp.float32)
                       * s).astype(dt)
    return p


def moe_block(p, cfg, x):
    """x: [B, S, D] -> [B, S, D] plus aux load-balance loss."""
    b, s, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = b * s
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"])          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)            # [T, K]
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(0)
    ce = jnp.zeros(E, jnp.float32).at[gate_idx.reshape(-1)].add(
        jnp.ones(T * K, jnp.float32)) / (T * K)
    aux = E * jnp.sum(me * ce)

    # ---- sort-based capacity dispatch ------------------------------------
    # floor keeps small (decode-sized) batches effectively dropless
    C = max(int(math.ceil(T * K / E * cfg.capacity_factor)), min(T * K, 16), 1)
    flat_e = gate_idx.reshape(-1)                            # [T*K]
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    t_sorted = flat_t[order]
    g_sorted = flat_g[order]
    # rank within expert
    onehot_pos = (e_sorted[:, None] == jnp.arange(E)[None, :]).astype(jnp.int32)
    rank = jnp.cumsum(onehot_pos, axis=0)[jnp.arange(T * K), e_sorted] - 1
    keep = rank < C
    slot = e_sorted * C + jnp.clip(rank, 0, C - 1)           # [T*K]

    gathered = jnp.zeros((E * C, d), x.dtype).at[
        jnp.where(keep, slot, E * C - 1)].set(
        jnp.where(keep[:, None], xt[t_sorted], 0), mode="drop")
    ex = gathered.reshape(E, C, d)

    up = jnp.einsum("ecd,edf->ecf", ex, p["w_up"])
    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ex, p["w_gate"])) * up
    elif cfg.act == "geglu":
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", ex, p["w_gate"]),
                        approximate=True) * up
    else:
        h = jax.nn.gelu(up, approximate=True)
    eo = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * C, d)

    out = jnp.zeros((T, d), x.dtype).at[t_sorted].add(
        jnp.where(keep[:, None], eo[slot] * g_sorted[:, None].astype(x.dtype),
                  0))
    return out.reshape(b, s, d), aux
