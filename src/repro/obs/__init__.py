"""Telemetry core (DESIGN.md section 13): unified metrics registry,
merge-pipeline trace spans, and the retrace/recompile watchdog.

This is the instrumentation contract everything reports through:
engines carry a `Telemetry`, the facade times ops into it,
`OnlineIndex`/the engines trace their merge pipelines with the fixed
`MERGE_SPANS` taxonomy, and `benchmarks/run.py --metrics-json` exports
`LearnedIndex.metrics()` snapshots per workload section.
"""

from .metrics import (LatencyHistogram, MetricsRegistry, PERCENTILES,
                      latency_summary)
from .telemetry import NULL_TELEMETRY, OPS, SCHEMA_VERSION, Telemetry
from .trace_export import (TRACE_SCHEMA_VERSION, TraceBuffer,
                           current_trace_ids, mint_trace_id, trace_context)
from .tracing import (MERGE_SPANS, RECOVERY_SPANS, SERVE_SPANS, Span,
                      SpanRecorder)
from .inspect import INSPECT_SCHEMA_VERSION, build_inspect
from . import watchdog

__all__ = [
    "LatencyHistogram", "MetricsRegistry", "PERCENTILES", "latency_summary",
    "NULL_TELEMETRY", "OPS", "SCHEMA_VERSION", "Telemetry",
    "TRACE_SCHEMA_VERSION", "TraceBuffer", "current_trace_ids",
    "mint_trace_id", "trace_context",
    "MERGE_SPANS", "RECOVERY_SPANS", "SERVE_SPANS", "Span", "SpanRecorder",
    "INSPECT_SCHEMA_VERSION", "build_inspect",
    "watchdog",
]
