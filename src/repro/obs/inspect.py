"""Index-health introspection: the `dili.inspect/1` schema
(DESIGN.md section 13).

DILI's search cost is governed by tree height and leaf-model accuracy
(the paper's central trade-off; the PGM-index's multicriteria framing is
the same surface), but until now neither was observable on a live index
— only their downstream effect on latency.  `build_inspect` computes a
stable, engine-independent key tree from the flattened snapshot(s):

  tree        — node/slot/pair counts, depth histogram, fanout summary
  leaves      — leaf count, slot-size + fill-factor summaries, dense frac
  model_error — |predicted - actual| slot offset per pair, overall and
                per-leaf-mean summaries (stride-sampled, bounded cost)
  segments    — splice-segment counts + dirty-row breakdown from the
                incremental flattener's last merge
  heat        — per-leaf write/delete/hot-streak summaries from the
                maintain accounting
  overlay     — pending write/tombstone footprint
  wal         — durability footprint (WAL + checkpoint bytes on disk)

Everything is computed from numpy columns already in host memory — no
tree walk, no device sync — so `LearnedIndex.inspect()` is safe to call
on a serving index.  The schema (key tree) is identical across
local/pallas/sharded, pinned by tests/test_inspect_trace.py; values
differ (a sharded index has one flat per shard — arrays are concatenated
before summarizing, counters summed).
"""

from __future__ import annotations

import numpy as np

from ..core.flat import TAG_CHILD, TAG_PAIR

INSPECT_SCHEMA_VERSION = "dili.inspect/1"

#: stride-sample the per-pair model-error computation down to this many
#: pairs — keeps inspect() O(bounded) on the 10M+ rungs
ERROR_SAMPLE_CAP = 65536

_SUMMARY_PCTS = ((50.0, "p50"), (95.0, "p95"), (99.0, "p99"))


def _summary(xs) -> dict:
    """Fixed-key numeric summary (count/mean/p50/p95/p99/max) — the
    inspect-schema analogue of `latency_summary`, unit-free."""
    xs = np.asarray(xs, np.float64)
    if xs.size == 0:
        out = dict(count=0, mean=0.0)
        for _, name in _SUMMARY_PCTS:
            out[name] = 0.0
        out["max"] = 0.0
        return out
    qs = np.percentile(xs, [q for q, _ in _SUMMARY_PCTS])
    out = dict(count=int(xs.size), mean=float(xs.mean()))
    for (_, name), v in zip(_SUMMARY_PCTS, qs):
        out[name] = float(v)
    out["max"] = float(xs.max())
    return out


def _collect_flat(flat, error_cap: int):
    """Raw per-node / per-pair columns for ONE FlatDILI snapshot.

    Returns (depths[n_nodes], leaf_mask[n_nodes], fo, pairs_per_node,
    errors[sampled], err_leaf_ids[sampled], dense) — callers concatenate
    across shards before summarizing."""
    n_nodes = flat.n_nodes
    fo = np.asarray(flat.fo, np.int64)
    tag = flat.tag
    # slot row i belongs to node owner[i]: preorder flatten emits each
    # node's fo slots contiguously in node-id order
    owner = np.repeat(np.arange(n_nodes), fo)

    child_mask = tag == TAG_CHILD
    edge_parent = owner[child_mask]
    edge_child = np.asarray(flat.val[child_mask], np.int64)
    n_child = np.bincount(edge_parent, minlength=n_nodes)
    # an internal node's slots are ALL child pointers; anything else
    # (pairs, empties, or a childless root) is a leaf-class node
    internal = (n_child == fo) & (fo > 0)
    leaf_mask = ~internal

    # depth by level propagation over the child edges: depth[root]=0,
    # each sweep settles one level, max_depth sweeps total
    depth = np.full(n_nodes, -1, np.int64)
    depth[flat.root] = 0
    for _ in range(max(int(flat.max_depth), 1)):
        src = depth[edge_parent]
        ready = src >= 0
        if not ready.any():
            break
        before = depth[edge_child[ready]]
        depth[edge_child[ready]] = src[ready] + 1
        if (before == src[ready] + 1).all():
            break

    pair_mask = tag == TAG_PAIR
    pairs_per_node = np.bincount(owner[pair_mask], minlength=n_nodes)

    # model prediction error per pair: the leaf model maps key -> local
    # slot offset (search.py: off = clip(floor(a + b*k), 0, fo-1)); the
    # pair's actual offset is its slot-table row minus the node base
    n_pairs = flat.n_pairs
    stride = max(1, -(-n_pairs // error_cap)) if n_pairs else 1
    ps = np.asarray(flat.pair_slot[::stride], np.int64)
    pk = np.asarray(flat.pair_key[::stride], np.float64)
    nid = owner[ps] if len(ps) else np.zeros(0, np.int64)
    if len(ps):
        pred = np.floor(np.asarray(flat.a, np.float64)[nid]
                        + np.asarray(flat.b, np.float64)[nid] * pk)
        pred = np.clip(pred, 0, fo[nid] - 1)
        actual = ps - np.asarray(flat.base, np.int64)[nid]
        errors = np.abs(pred - actual)
    else:
        errors = np.zeros(0)
    return (depth, leaf_mask, fo, pairs_per_node, errors, nid,
            np.asarray(flat.dense, np.int64))


def _zero_overlay() -> dict:
    return dict(pending=0, live=0, tombstones=0, cap=0, fill=0.0)


def _zero_wal() -> dict:
    return dict(armed=False, n_shards=0, wal_bytes=0, n_wal_files=0,
                ckpt_bytes=0, n_ckpt_files=0)


def build_inspect(*, engine: str, epoch: int, flats,
                  flatteners=(), accounts=(), overlay: dict | None = None,
                  wal: dict | None = None,
                  error_sample_cap: int = ERROR_SAMPLE_CAP) -> dict:
    """The `dili.inspect/1` document for one index.

    `flats` is the list of published FlatDILI snapshots (one per shard);
    `flatteners` the live IncrementalFlattener instances (may be empty —
    maintenance off); `accounts` the LeafAccount records from the
    maintain accounting; `overlay`/`wal` pre-aggregated footprint dicts
    (None -> zero-filled, same keys)."""
    flats = [f for f in flats if f is not None]
    depths, leaf_masks, fos, ppn, errs, err_nids, denses = [], [], [], [], [], [], []
    nid_off = 0
    for f in flats:
        d, lm, fo, pp, e, en, dn = _collect_flat(f, error_sample_cap)
        depths.append(d)
        leaf_masks.append(lm)
        fos.append(fo)
        ppn.append(pp)
        errs.append(e)
        err_nids.append(en + nid_off)      # shard-unique leaf ids
        denses.append(dn)
        nid_off += f.n_nodes
    cat = (lambda xs, dt=np.int64: np.concatenate(xs)
           if xs else np.zeros(0, dt))
    depth = cat(depths)
    leaf_mask = cat(leaf_masks, bool)
    fo = cat(fos)
    pairs_per_node = cat(ppn)
    errors = cat(errs, np.float64)
    err_nid = cat(err_nids)
    dense = cat(denses)

    n_nodes = int(depth.size)
    max_depth = int(depth.max()) + 1 if n_nodes else 0
    depth_hist = (np.bincount(depth[depth >= 0],
                              minlength=max_depth).tolist()
                  if n_nodes else [])

    leaf_fo = fo[leaf_mask]
    leaf_pairs = pairs_per_node[leaf_mask]
    fill = (leaf_pairs / np.maximum(leaf_fo, 1)) if leaf_fo.size else leaf_fo

    # per-leaf mean |error| over the sampled pairs
    if errors.size:
        sums = np.zeros(nid_off)
        cnts = np.zeros(nid_off)
        np.add.at(sums, err_nid, errors)
        np.add.at(cnts, err_nid, 1.0)
        hit = cnts > 0
        per_leaf_mean = sums[hit] / cnts[hit]
    else:
        per_leaf_mean = np.zeros(0)

    seg = dict(n_segments=int(sum(f.n_segments for f in flats)),
               dirty_segments=0, total_segments=0,
               dirty_rows=0, total_rows=0, dirty_fraction=0.0,
               incremental=False, n_fallback_full=0,
               rows=_summary(()))
    fls = [fl for fl in (flatteners or ()) if fl is not None]
    if fls:
        seg["dirty_segments"] = int(sum(fl.last_dirty_segments for fl in fls))
        seg["total_segments"] = int(sum(fl.last_total_segments for fl in fls))
        seg["dirty_rows"] = int(sum(fl.last_dirty_rows for fl in fls))
        seg["total_rows"] = int(sum(fl.last_total_rows for fl in fls))
        seg["dirty_fraction"] = (seg["dirty_rows"] / seg["total_rows"]
                                 if seg["total_rows"] else 0.0)
        seg["incremental"] = bool(all(fl.last_incremental for fl in fls))
        seg["n_fallback_full"] = int(sum(fl.n_fallback_full for fl in fls))
        seg["rows"] = _summary([blk.n_slots for fl in fls
                                for blk in fl._cache.values()])

    accounts = list(accounts or ())
    heat = dict(n_tracked=len(accounts),
                writes=_summary([ac.writes for ac in accounts]),
                deletes=_summary([ac.deletes for ac in accounts]),
                hot_streak=_summary([ac.hot_streak for ac in accounts]))

    return dict(
        schema=INSPECT_SCHEMA_VERSION,
        engine=engine,
        epoch=int(epoch),
        n_shards=len(flats),
        n_keys=int(sum(f.n_pairs for f in flats)),
        tree=dict(n_nodes=n_nodes,
                  n_slots=int(sum(f.n_slots for f in flats)),
                  n_pairs=int(sum(f.n_pairs for f in flats)),
                  max_depth=max_depth,
                  depth_hist=depth_hist,
                  fanout=_summary(fo)),
        leaves=dict(n_leaves=int(leaf_mask.sum()),
                    n_internal=int((~leaf_mask).sum()),
                    slots=_summary(leaf_fo),
                    fill=_summary(fill),
                    dense_frac=(float(dense[leaf_mask].mean())
                                if leaf_mask.any() else 0.0)),
        model_error=dict(sampled=int(errors.size),
                         overall=_summary(errors),
                         per_leaf_mean=_summary(per_leaf_mean)),
        segments=seg,
        heat=heat,
        overlay=dict(_zero_overlay(), **(overlay or {})),
        wal=dict(_zero_wal(), **(wal or {})),
    )
