"""Metrics primitives: counters, gauges, log-bucketed latency histograms,
and THE one percentile implementation (DESIGN.md section 13).

The histogram is HDR-style: fixed geometric bucket edges (32 sub-buckets
per power-of-two octave, so quantile estimates carry at most ~3.2%
relative error) held in one shared numpy array.  Recording a sample is a
single `searchsorted` into that fixed table plus an integer increment —
no per-sample allocation, no stored samples — which is what lets the
serving hot path keep a histogram per op without a measurable cost.

`latency_summary` is the single percentile recipe (p50/p95/p99/p999/max,
milliseconds) shared by every consumer: histogram export here, the
workload runner's per-batch op latencies, and the benchmark harness's
merge/publish percentiles all emit the same keys from the same code, so
the numbers can never disagree on methodology.
"""

from __future__ import annotations

import warnings

import numpy as np

# the percentile set every latency surface exports: the tail levels a
# serving deployment is judged on (ROADMAP's p50/p99/p999 plus the
# historical p95 the bench artifact already records)
PERCENTILES = ((50.0, "p50"), (95.0, "p95"), (99.0, "p99"), (99.9, "p999"))


def latency_summary(seconds, prefix: str = "", *,
                    scale: float = 1e3) -> dict:
    """Percentile summary of raw duration samples (seconds -> ms keys).

    Returns `{<prefix>_ms_p50, ..., _ms_p999, _ms_max, _ms_mean}` plus
    `<prefix>_count` (prefix-less keys when `prefix` is empty).  Empty
    input returns the same key set, all-zero, so every consumer emits a
    stable schema without special-casing quiet ops."""
    p = f"{prefix}_" if prefix else ""
    xs = np.asarray(list(seconds), np.float64) * scale
    out: dict = {f"{p}count": int(xs.size)}
    if xs.size == 0:
        for _, name in PERCENTILES:
            out[f"{p}ms_{name}"] = 0.0
        out[f"{p}ms_max"] = 0.0
        out[f"{p}ms_mean"] = 0.0
        return out
    qs = np.percentile(xs, [q for q, _ in PERCENTILES])
    for (_, name), v in zip(PERCENTILES, qs):
        out[f"{p}ms_{name}"] = float(v)
    out[f"{p}ms_max"] = float(xs.max())
    out[f"{p}ms_mean"] = float(xs.mean())
    return out


_T_MIN = 1e-7                      # 100 ns: below any timeable op
_N_OCTAVES = 32
_SUBS = 32
# one shared immutable edge table: T_MIN * 2**k * (1 + j/SUBS)
_EDGES = _T_MIN * np.concatenate(
    [2.0 ** k * (1.0 + np.arange(1, _SUBS + 1) / _SUBS)
     for k in range(_N_OCTAVES)])
_EDGES.setflags(write=False)


class LatencyHistogram:
    """Log-bucketed duration histogram with fixed, shared bucket edges.

    Buckets span 100ns .. ~400s in 32 octaves x 32 linear sub-buckets
    (1025 counters incl. overflow).  `record` is O(log n_buckets)
    with zero allocation; `summary()` reports quantiles at the bucket
    upper edge (a conservative <=1/32 relative overestimate)."""

    T_MIN = _T_MIN
    N_OCTAVES = _N_OCTAVES
    SUBS = _SUBS
    EDGES = _EDGES

    __slots__ = ("counts", "n", "total_s", "max_s")

    def __init__(self):
        self.counts = np.zeros(len(self.EDGES) + 1, np.int64)
        self.n = 0
        self.total_s = 0.0
        self.max_s = 0.0

    def record(self, seconds: float) -> None:
        self.counts[int(np.searchsorted(self.EDGES, seconds))] += 1
        self.n += 1
        self.total_s += seconds
        if seconds > self.max_s:
            self.max_s = seconds

    def merge(self, other: "LatencyHistogram") -> None:
        self.counts += other.counts
        self.n += other.n
        self.total_s += other.total_s
        self.max_s = max(self.max_s, other.max_s)

    def quantile(self, q: float) -> float:
        """Value (seconds) at quantile q in [0, 1]: the upper edge of the
        bucket holding the q-th sample (0.0 when empty)."""
        if self.n == 0:
            return 0.0
        rank = q * self.n
        i = int(np.searchsorted(np.cumsum(self.counts), rank, side="left"))
        if i >= len(self.EDGES):            # overflow bucket
            return self.max_s
        return float(self.EDGES[i])

    def summary(self, prefix: str = "") -> dict:
        """Same key layout as `latency_summary` (the shared percentile
        contract), estimated from the buckets."""
        p = f"{prefix}_" if prefix else ""
        out: dict = {f"{p}count": self.n}
        if self.n == 0:
            for _, name in PERCENTILES:
                out[f"{p}ms_{name}"] = 0.0
            out[f"{p}ms_max"] = 0.0
            out[f"{p}ms_mean"] = 0.0
            return out
        for q, name in PERCENTILES:
            out[f"{p}ms_{name}"] = self.quantile(q / 100.0) * 1e3
        out[f"{p}ms_max"] = self.max_s * 1e3
        out[f"{p}ms_mean"] = self.total_s / self.n * 1e3
        return out


class MetricsRegistry:
    """Named counters + gauges + latency histograms with one JSON-able
    export.  Creation is lazy; `declare_histogram` pre-registers names so
    every engine exports an identical schema even for ops it never ran."""

    def __init__(self):
        self.counters: dict[str, int] = {}
        self.gauges: dict[str, float] = {}
        self.histograms: dict[str, LatencyHistogram] = {}
        self._warn_calls: dict[str, int] = {}   # warn() rate-limit state

    def count(self, name: str, n: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def declare_counter(self, *names: str) -> None:
        for name in names:
            self.counters.setdefault(name, 0)

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def declare_gauge(self, *names: str) -> None:
        for name in names:
            self.gauges.setdefault(name, 0.0)

    def warn(self, name: str, message: str, *, count: int = 1,
             limit: int = 1) -> None:
        """Rate-limited structured warning: `warn.<name>` counts every
        occurrence (floods stay visible in snapshots), but the Python
        warning itself is emitted only for the first `limit` call sites
        per registry, so a per-batch condition can't spam stderr.
        stacklevel=3 points the warning at the engine caller's caller
        (the user's write), matching what a bare warnings.warn showed."""
        calls = self._warn_calls.get(name, 0)
        self._warn_calls[name] = calls + 1
        self.count(f"warn.{name}", count)
        if calls < limit:
            warnings.warn(message, UserWarning, stacklevel=3)

    def declare_histogram(self, *names: str) -> None:
        for name in names:
            self.histograms.setdefault(name, LatencyHistogram())

    def observe(self, name: str, seconds: float) -> None:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = LatencyHistogram()
        h.record(seconds)

    def snapshot(self) -> dict:
        """Stable JSON-able export: plain ints/floats only.

        Safe to sample while another thread records: each dict is copied
        atomically (`dict()` over a live dict is one bytecode) before the
        sorted iteration, so a concurrent counter/gauge/histogram
        registration can't RuntimeError the export — it simply lands in
        this snapshot or the next.  Histogram summaries read live bucket
        counts; a race there skews one sample at most."""
        hists = dict(self.histograms)
        return dict(
            counters=dict(sorted(dict(self.counters).items())),
            gauges=dict(sorted(dict(self.gauges).items())),
            histograms={k: hists[k].summary() for k in sorted(hists)})
