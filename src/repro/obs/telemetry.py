"""`Telemetry`: the per-index bundle every engine carries (DESIGN.md
section 13) — one `MetricsRegistry` + one `SpanRecorder` + a retrace
watchdog window, behind a single `enabled` flag.

Cost contract: with `enabled=False` (the default) the read/write hot path
pays exactly one attribute check plus one integer op-count increment per
facade call — the op count must keep flowing even when latency capture is
off, because `retraces_per_1k_ops` (the PR-4 regression number) is
meaningful either way and the watchdog's trace counters are fed by jax's
own compile hooks, not by the hot path.  With `enabled=True` each facade
call additionally pays one perf_counter pair and one histogram bucket
increment (<= 3% on the ycsb_c point-lookup loop, pinned by a test).

Snapshot schema (`snapshot()`) is identical across engines — fixed op
set, fixed merge-span taxonomy, fixed retrace keys — pinned by the
engine-equivalence suite so downstream consumers (BENCH_PR2.json, the
serving front-end to come) can rely on it.
"""

from __future__ import annotations

import time

from . import watchdog
from .metrics import MetricsRegistry
from .trace_export import TraceBuffer
from .tracing import MERGE_SPANS, RECOVERY_SPANS, SpanRecorder

# the facade op set: every engine serves exactly these through
# `repro.api.LearnedIndex`, so per-op histograms share one name space
OPS = ("lookup", "range", "upsert", "delete", "flush")

SCHEMA_VERSION = "dili.metrics/1"


class _NullSpan:
    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class Telemetry:
    """Metrics + spans + retrace window for ONE index instance."""

    def __init__(self, enabled: bool = False):
        self.enabled = bool(enabled)
        self.metrics = MetricsRegistry()
        self.metrics.declare_histogram(*(f"op.{op}" for op in OPS))
        self.metrics.declare_counter("publish.retraced", "maint.errors",
                                     "maint.reclusters",
                                     "recovery.count",
                                     "recovery.replayed_records",
                                     # structured warning counters
                                     # (MetricsRegistry.warn): declared so
                                     # the counter key tree is identical on
                                     # engines that never warn
                                     "warn.pallas_f32_collision")
        # last merge-publish health sample (obs.inspect feeds the full
        # picture; these gauges are the cheap always-on trend lines)
        self.metrics.declare_gauge("inspect.n_segments",
                                   "inspect.dirty_rows",
                                   "inspect.total_rows",
                                   "inspect.dirty_fraction")
        self.spans = SpanRecorder(declare=MERGE_SPANS + RECOVERY_SPANS)
        self.trace = TraceBuffer()
        self.ops_total = 0
        # watchdog window: the build mark anchors "traces since build";
        # mark_warm() anchors the post-warmup (regression) window
        self._build_mark = watchdog.TraceMark.now()
        self._warm_mark: watchdog.TraceMark | None = None
        self._ops_at_warm = 0

    # -- hot path -------------------------------------------------------------

    def count_ops(self, n: int) -> None:
        """Unconditional op accounting (one int add; keeps
        retraces_per_1k_ops meaningful with latency capture off)."""
        self.ops_total += n

    def record_op(self, op: str, dur_s: float, n: int = 1) -> None:
        """Enabled-path per-call record: one histogram increment."""
        self.ops_total += n
        self.metrics.observe(f"op.{op}", dur_s)

    # -- merge pipeline -------------------------------------------------------

    def span(self, name: str, **attrs):
        """Context manager timing one pipeline stage; no-op when
        disabled (merge-path only — never on the per-op hot path)."""
        if not self.enabled:
            return _NULL_SPAN
        return self.spans.span(name, **attrs)

    def record_span(self, name: str, dur_s: float, **attrs) -> None:
        if self.enabled:
            self.spans.record(name, dur_s, **attrs)

    # -- causal tracing -------------------------------------------------------

    def start_trace(self) -> None:
        """Arm causal request tracing: every span the recorder sees is
        tee'd into the trace buffer (tagged with the recording thread's
        trace context), alongside the facade/WAL events the hot path adds
        directly.  Requires `enabled` for the serve/merge spans to be
        recorded at all."""
        self.trace.arm()
        self.spans.sink = self.trace.span_sink

    def stop_trace(self) -> None:
        self.spans.sink = None
        self.trace.disarm()

    # -- merge-publish health sample ------------------------------------------

    def sample_publish(self, *, n_segments: int, dirty_rows: int,
                       total_rows: int) -> None:
        """Cheap index-health gauges refreshed at every merge publish
        from flattener segment metadata (no tree walk; the full picture
        is `LearnedIndex.inspect()`)."""
        if not self.enabled:
            return
        m = self.metrics
        m.gauge("inspect.n_segments", n_segments)
        m.gauge("inspect.dirty_rows", dirty_rows)
        m.gauge("inspect.total_rows", total_rows)
        m.gauge("inspect.dirty_fraction",
                dirty_rows / total_rows if total_rows else 0.0)

    # -- retrace watchdog -----------------------------------------------------

    def mark_warm(self) -> None:
        """Declare warmup over: every executable the steady state needs
        exists now, so any further trace is a retrace regression."""
        self._warm_mark = watchdog.TraceMark.now()
        self._ops_at_warm = self.ops_total

    @property
    def warmed(self) -> bool:
        return self._warm_mark is not None

    def retrace_report(self) -> dict:
        since_build = self._build_mark.delta()
        if self._warm_mark is None:
            post = dict(traces=0, compiles=0)
            post_ops = 0
        else:
            post = self._warm_mark.delta()
            post_ops = self.ops_total - self._ops_at_warm
        return dict(
            warmed=self.warmed,
            traces_since_build=since_build["traces"],
            compiles_since_build=since_build["compiles"],
            post_warmup_traces=post["traces"],
            post_warmup_compiles=post["compiles"],
            post_warmup_ops=post_ops,
            retraces_per_1k_ops=(1000.0 * post["traces"] / post_ops
                                 if post_ops else 0.0),
            jit_cache_entries=watchdog.jit_cache_sizes())

    # -- export ---------------------------------------------------------------

    def snapshot(self) -> dict:
        """The stable JSON-able metrics snapshot (same schema on every
        engine; `LearnedIndex.metrics()` is a thin wrapper)."""
        m = self.metrics.snapshot()
        return dict(
            schema=SCHEMA_VERSION,
            enabled=self.enabled,
            ops_total=self.ops_total,
            ops={op: m["histograms"][f"op.{op}"] for op in OPS},
            # serving-front-end histograms (e2e latency per op, batch
            # sizes) appear only once a `RequestBatcher` attached and
            # declared them — {} on a bare index, same on every engine
            serve={k: v for k, v in m["histograms"].items()
                   if k.startswith("serve.")},
            counters=m["counters"],
            gauges=m["gauges"],
            spans=self.spans.summary(),
            retrace=self.retrace_report())


#: shared disabled instance for call sites that accept an optional
#: telemetry (never enable this one — make your own)
NULL_TELEMETRY = Telemetry(enabled=False)


def timed(fn, *args, **kw):
    """(result, dur_s) convenience for one-off stage timing."""
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0
