"""Causal request tracing: trace IDs, thread-propagated trace context,
and Chrome-trace-event export (DESIGN.md section 13).

The metrics side of `repro.obs` answers "how slow was X on average" —
this module answers "what happened to THIS request": a trace id is minted
per client request at submit (`repro.serve.frontend`), the batcher
installs the coalesced batch's id set as the worker thread's *trace
context* while it executes, and every causal stage recorded underneath —
serve queue/exec spans, the facade op, the WAL append, and any merge or
recovery pipeline the write triggered — lands in a bounded `TraceBuffer`
ring tagged with those ids.

Export is the Chrome trace-event JSON format (`TraceBuffer.to_chrome`),
loadable in Perfetto (https://ui.perfetto.dev) or chrome://tracing:
stages are complete ("X") slices on named tracks (one per client, plus
serve/facade/wal/merge/recovery), and requests are connected to the
stages that served them with flow arrows ("s" at the request slice,
"t" steps at each linked stage).

Threading model: trace ids are minted from one process-global counter
(atomic via the GIL); the context is a thread-local, installed by the
single batcher worker (and re-installed on the maintenance worker for
background merges, see `online.merge`); `TraceBuffer.add` is a deque
append — safe under the same one-writer-per-stage model the span
recorder already assumes.  Everything is disabled (one flag check) until
`arm()`.
"""

from __future__ import annotations

import itertools
import json
import threading
from collections import deque

TRACE_SCHEMA_VERSION = "dili.trace/1"

#: process-global request id mint — `next()` on a count iterator is atomic
_MINT = itertools.count(1)


def mint_trace_id() -> int:
    """A fresh process-unique trace id (one per client request)."""
    return next(_MINT)


_CTX = threading.local()


def current_trace_ids() -> tuple:
    """The trace ids causally responsible for work on THIS thread right
    now (empty outside any traced dispatch)."""
    return getattr(_CTX, "ids", ())


class trace_context:
    """Install `trace_ids` as this thread's causal context for the
    duration of the `with` block (re-entrant: the previous context is
    restored on exit).  The batcher wraps each coalesced dispatch in one;
    background merge submission captures the writer's context and
    re-enters it on the worker."""

    __slots__ = ("ids", "_prev")

    def __init__(self, trace_ids):
        self.ids = tuple(trace_ids)

    def __enter__(self) -> "trace_context":
        self._prev = getattr(_CTX, "ids", ())
        _CTX.ids = self.ids
        return self

    def __exit__(self, *exc) -> bool:
        _CTX.ids = self._prev
        return False


class TraceBuffer:
    """Bounded ring of causal trace events with Chrome-trace export.

    One buffer per `Telemetry` bundle (so per index).  Events are
    `(name, track, t0, dur_s, trace_ids, anchor, attrs)`; `anchor=True`
    marks the *request* slice that OWNS a trace id (flow arrows start
    there), every other event carrying ids is a linked stage (flow
    steps).  Unarmed, `add` is a single flag check."""

    def __init__(self, maxlen: int = 65536):
        self.ring: deque = deque(maxlen=maxlen)
        self.enabled = False
        self.n_events = 0          # total added (ring may have dropped)

    def arm(self) -> None:
        self.enabled = True

    def disarm(self) -> None:
        self.enabled = False

    def clear(self) -> None:
        self.ring.clear()
        self.n_events = 0

    def add(self, name: str, *, t0: float, dur_s: float, track: str,
            trace_ids=None, anchor: bool = False, **attrs) -> None:
        """Record one causal stage.  `trace_ids=None` (the common case)
        links the event to the thread's current trace context."""
        if not self.enabled:
            return
        if trace_ids is None:
            trace_ids = current_trace_ids()
        self.ring.append((name, track, float(t0), float(dur_s),
                          tuple(trace_ids), bool(anchor), attrs))
        self.n_events += 1

    def span_sink(self, name: str, t0: float, dur_s: float,
                  attrs: dict) -> None:
        """`SpanRecorder.sink` adapter: every span the recorder sees
        (merge.*, recovery.*, serve.*) becomes a trace event on the track
        named by its prefix, linked to the current trace context."""
        self.add(name, t0=t0, dur_s=dur_s, track=name.split(".", 1)[0],
                 **attrs)

    # -- export ---------------------------------------------------------------

    def to_chrome(self, process_name: str = "dili") -> dict:
        """The ring as a Chrome trace-event JSON object.

        Slices are "X" (complete) events on per-track tids; each anchor
        slice emits a flow start ("s") per owned trace id and each linked
        stage emits a flow step ("t"), so Perfetto draws request ->
        stage arrows.  Timestamps are microseconds relative to the
        earliest event (perf_counter origin is arbitrary)."""
        events = list(self.ring)
        pid = 1
        out = [dict(ph="M", pid=pid, name="process_name",
                    args=dict(name=process_name))]
        tids: dict[str, int] = {}
        base = min((e[2] for e in events), default=0.0)
        for name, track, t0, dur_s, ids, anchor, attrs in events:
            tid = tids.get(track)
            if tid is None:
                tid = tids[track] = len(tids) + 1
                out.append(dict(ph="M", pid=pid, tid=tid,
                                name="thread_name", args=dict(name=track)))
            ts = round((t0 - base) * 1e6, 3)
            dur = round(max(dur_s, 1e-7) * 1e6, 3)
            args = {k: (v if isinstance(v, (int, float, str, bool))
                        else repr(v)) for k, v in attrs.items()}
            if ids:
                args["trace_ids"] = list(ids)
            out.append(dict(name=name, ph="X", ts=ts, dur=dur,
                            pid=pid, tid=tid, cat=track, args=args))
            # flow events must bind INSIDE their slice: anchor starts the
            # per-request flow, linked stages step it
            mid = round(ts + dur / 2, 3)
            for trace_id in ids:
                out.append(dict(ph=("s" if anchor else "t"), cat="request",
                                id=int(trace_id), name="req", ts=mid,
                                pid=pid, tid=tid))
        return dict(displayTimeUnit="ms", traceEvents=out,
                    otherData=dict(schema=TRACE_SCHEMA_VERSION,
                                   n_events=self.n_events,
                                   n_exported=len(events)))

    def dump(self, path: str, process_name: str = "dili") -> dict:
        """Write `to_chrome()` JSON to `path` (open in Perfetto);
        returns the document's `otherData` summary block."""
        doc = self.to_chrome(process_name)
        with open(path, "w") as fh:
            json.dump(doc, fh, indent=1)
        return doc["otherData"]
