"""Trace spans over the merge pipeline (DESIGN.md section 13).

A span is one timed stage of a pipeline run: `(name, t0, dur_s, attrs)`.
The recorder keeps a bounded ring of recent spans (for debugging "what did
the last merge do") plus running per-name duration lists (for percentile
export), and is safe for the one-writer-plus-maintenance-worker threading
model the merge pipeline already guarantees: each span is recorded by
whichever single thread ran that stage, and list.append is atomic.

The merge span taxonomy is fixed (`MERGE_SPANS`) so every engine exports
the same span names:

  merge.queue_wait   — submit -> worker pickup (background scheduler only)
  merge.fold         — overlay fold through the host tree (Alg. 7/8)
  merge.retrain      — drift/tombstone-triggered subtree rebuilds
  merge.recluster    — heat-triggered locality splits of hot leaf segments
  merge.flatten      — full or incremental-splice flatten
  merge.publish      — device upload + epoch flip
  merge.frozen_dwell — overlay freeze -> frozen drop (reads resolve the
                       frozen overlay for this long; background only)
  merge.failed       — one failed merge attempt (duration = time spent in
                       the pipeline before it died; see the bounded-retry
                       loop in `online.merge`)

Engines that run a stage synchronously inside another (e.g. the sharded
engine's per-shard fold) record one span per shard with a `shard` attr.

`RECOVERY_SPANS` is the crash-recovery taxonomy (DESIGN.md section 14):
load (checkpoint walk + npz read), replay (WAL tail through the fold
path), publish (fresh base checkpoint + WAL re-arm).  Recovery spans are
recorded unconditionally — bypassing the telemetry `enabled` gate —
because recovery is rare and always worth seeing.
"""

from __future__ import annotations

import time
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass, field

from .metrics import latency_summary

MERGE_SPANS = ("merge.queue_wait", "merge.fold", "merge.retrain",
               "merge.recluster", "merge.flatten", "merge.publish",
               "merge.frozen_dwell", "merge.failed")

RECOVERY_SPANS = ("recovery.load", "recovery.replay", "recovery.publish")

# Serving front-end taxonomy (DESIGN.md section 15).  NOT part of the
# default declaration: a bare index exports exactly the merge + recovery
# span set (pinned by the telemetry schema tests); the serve spans join a
# Telemetry bundle only when a `RequestBatcher` attaches to the index,
# via `SpanRecorder.declare`.
#
#   serve.queue_wait — head request's submit -> worker dispatch (the
#                      admission-queue delay component of e2e latency)
#   serve.exec       — one coalesced facade batch, dispatch -> results
#                      sliced back to clients (attr `op`)
SERVE_SPANS = ("serve.queue_wait", "serve.exec")


@dataclass(frozen=True)
class Span:
    name: str
    t0: float                  # perf_counter timestamp at stage start
    dur_s: float
    attrs: dict = field(default_factory=dict)


class SpanRecorder:
    """Bounded span ring + per-name duration accumulators."""

    def __init__(self, maxlen: int = 2048,
                 declare: tuple[str, ...] = MERGE_SPANS + RECOVERY_SPANS):
        self.ring: deque[Span] = deque(maxlen=maxlen)
        self._durations: dict[str, list[float]] = {n: [] for n in declare}
        # optional causal-trace tap: when set (see Telemetry.start_trace)
        # every recorded span is also forwarded as
        # `sink(name, t0, dur_s, attrs)` — the TraceBuffer adapter
        self.sink = None

    def record(self, name: str, dur_s: float, t0: float | None = None,
               **attrs) -> None:
        if t0 is None:
            t0 = time.perf_counter() - dur_s
        self.ring.append(Span(name, t0, dur_s, attrs))
        self._durations.setdefault(name, []).append(dur_s)
        if self.sink is not None:
            self.sink(name, t0, dur_s, attrs)

    @contextmanager
    def span(self, name: str, **attrs):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.record(name, time.perf_counter() - t0, t0=t0, **attrs)

    def declare(self, *names: str) -> None:
        """Add span names to the exported taxonomy (zero-count until
        recorded).  Late opt-in for subsystems that aren't part of every
        index — e.g. the serving front-end declares `SERVE_SPANS` on
        attach, so only served indexes export them."""
        for name in names:
            self._durations.setdefault(name, [])

    def spans(self, name: str | None = None) -> list[Span]:
        return [s for s in self.ring if name is None or s.name == name]

    def count(self, name: str) -> int:
        return len(self._durations.get(name, ()))

    def summary(self) -> dict:
        """{span name: shared percentile summary} over every declared or
        recorded span name — JSON-able, stable key set per taxonomy.

        Safe to call while another thread records: the name dict and each
        duration list are snapshotted atomically (`dict()`/`list()` are
        single bytecodes over the live object), so a concurrent append
        lands in this summary or the next, never in a RuntimeError."""
        return {name: latency_summary(list(durs))
                for name, durs in sorted(dict(self._durations).items())}
