"""Retrace/recompile watchdog (DESIGN.md section 13).

The worst perf-bug class this repo has hit is the silent retrace: PR 4
found the sharded collectives re-tracing their shard_map EVERY batch
(~50x per-batch cost) because a fresh closure was jitted per call.  The
jit caches hide this completely — results stay correct, only wall time
explodes — so the watchdog turns it into a number:

  * process-global trace/compile counters fed by `jax.monitoring`'s
    compile-event hooks (one int increment per trace — nothing on the op
    hot path, which never traces after warmup);
  * a registry of named jitted entry points (`register_jit`) and cache
    providers (`register_jit_provider`) so `jit_cache_sizes()` can report
    traced-executable counts per entry point;
  * `TraceMark` deltas: snapshot the counters at build and after warmup,
    and any post-warmup growth is a retrace regression
    (`retraces_per_1k_ops` is the failing number CI asserts on).

Counters are process-wide: deltas attribute every trace in the window to
the index being measured, so measure one index at a time (exactly what
the workload runner and CI do).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass

TRACE_EVENT = "/jax/core/compile/jaxpr_trace_duration"
COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_counts = {"traces": 0, "compiles": 0}
_installed = False
_install_lock = threading.Lock()


def _on_compile_event(event: str, duration_secs: float, **kw) -> None:
    # racy += is tolerable for a monotone diagnostic counter only if it
    # never loses the increments we assert on; traces happen on the one
    # writer/worker thread in practice, but stay correct anyway
    if event == TRACE_EVENT:
        _counts["traces"] += 1
    elif event == COMPILE_EVENT:
        _counts["compiles"] += 1


def install() -> None:
    """Install the (idempotent, process-global) compile-event listener.
    Registered once; jax offers no per-listener removal, so the hook
    stays for the process lifetime — it is two dict increments per
    TRACE, which only happens when an executable is minted."""
    global _installed
    with _install_lock:
        if _installed:
            return
        import jax.monitoring
        jax.monitoring.register_event_duration_secs_listener(
            _on_compile_event)
        _installed = True


def trace_counts() -> dict:
    """Process-global {traces, compiles} so far (installs the hook)."""
    install()
    return dict(_counts)


# -- named jit-cache registry -------------------------------------------------

_JIT_REGISTRY: dict[str, object] = {}
_PROVIDERS: dict[str, object] = {}


def register_jit(name: str, fn) -> None:
    """Register a module-level jitted callable under a stable name; its
    `_cache_size()` (traced executables) shows up in `jit_cache_sizes`."""
    _JIT_REGISTRY[name] = fn


def register_jit_provider(name: str, provider) -> None:
    """Register a zero-arg callable returning an int cache size — or a
    {name: size} dict — for entry points whose jits are minted dynamically
    (e.g. the sharded collective trace cache)."""
    _PROVIDERS[name] = provider


def jit_cache_sizes() -> dict:
    """{entry point name: traced executables} for every registered jit."""
    out: dict = {}
    for name, fn in _JIT_REGISTRY.items():
        size = getattr(fn, "_cache_size", None)
        out[name] = int(size()) if callable(size) else -1
    for name, provider in _PROVIDERS.items():
        try:
            got = provider()
        except Exception:
            out[name] = -1
            continue
        if isinstance(got, dict):
            out.update({k: int(v) for k, v in got.items()})
        else:
            out[name] = int(got)
    return dict(sorted(out.items()))


# -- windowed deltas ----------------------------------------------------------


@dataclass(frozen=True)
class TraceMark:
    traces: int
    compiles: int

    @classmethod
    def now(cls) -> "TraceMark":
        c = trace_counts()
        return cls(traces=c["traces"], compiles=c["compiles"])

    def delta(self) -> dict:
        c = trace_counts()
        return dict(traces=c["traces"] - self.traces,
                    compiles=c["compiles"] - self.compiles)
