"""Epoch-based online-update subsystem (DESIGN.md section 8).

Owns the full update lifecycle between the mutable host DILI (writer) and
the immutable device snapshot (reader):

  * `overlay`  — tombstone-capable sorted run absorbing upserts/deletes,
    with a fused snapshot+overlay device lookup;
  * `epoch`    — epoch-versioned double-buffered snapshot publisher;
  * `merge`    — merge policy (fill / lag / λ-pressure / flush) folding the
    overlay through Algorithms 7-8, and the `OnlineIndex` facade.
"""

from .overlay import (LIVE, TOMBSTONE, TombstoneOverlay, fold_overlay,
                      overlay_device_arrays, search_with_updates)
from .epoch import EpochStats, SnapshotStore
from .merge import MergePolicy, OnlineIndex, adjust_pressure
from ..maintain import MaintenanceConfig

__all__ = [
    "LIVE", "TOMBSTONE", "TombstoneOverlay", "fold_overlay",
    "overlay_device_arrays", "search_with_updates", "EpochStats",
    "SnapshotStore", "MergePolicy", "OnlineIndex", "adjust_pressure",
    "MaintenanceConfig",
]
