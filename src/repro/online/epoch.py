"""Epoch-versioned snapshot publisher (DESIGN.md section 8).

`SnapshotStore` owns the immutable device snapshots the read path serves
from.  Publishing is double-buffered: epoch N+1's arrays are built and
uploaded into the *back* buffer while epoch N keeps serving from the front
buffer, then a single reference flip makes N+1 current.  Snapshots are
typed `api.DeviceSnapshot` pytrees (immutable jax arrays + static
`max_depth`/`has_dense`), so a reader that captured epoch N's snapshot
mid-batch keeps a consistent view even after the flip — the flip only
retargets new readers — and never threads `max_depth` by hand.

Shapes are padded to powers of two (`DeviceSnapshot.from_flat(pad=True)`),
so a republish re-traces the compiled search executable only when a table
crosses a pow2 boundary; `EpochStats.retraced` records when that happened.
Per-epoch stats also record overlay fill and merge lag at publish time and
bytes uploaded — the observability surface for tuning `MergePolicy`.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from ..core.flat import FlatDILI


@dataclass(frozen=True)
class EpochStats:
    epoch: int
    n_keys: int              # pairs in the snapshot
    n_nodes: int             # unpadded node-table rows
    n_slots: int             # unpadded slot-table rows
    bytes_uploaded: int      # device bytes of this epoch's tables
    overlay_fill: float      # overlay full_fraction at publish time
    merge_lag: int           # writes absorbed since the previous publish
    publish_s: float         # wall time: upload + block_until_ready
    retraced: bool           # padded shapes changed vs previous epoch
    # maintenance observability (DESIGN.md section 12); defaults describe a
    # legacy monolithic merge
    merge_s: float = 0.0     # wall time: fold + retrain + flatten
    incremental: bool = False  # splice-flatten (vs full flatten())
    dirty_frac: float = 1.0  # slot rows re-materialized / total rows
    n_retrains: int = 0      # subtree rebuilds during this merge


@dataclass
class SnapshotStore:
    dtype: object = jnp.float64
    pad: bool = True
    epoch: int = 0
    history: list = field(default_factory=list)
    _buf: list = field(default_factory=lambda: [None, None])  # (flat, idx)
    _active: int = -1

    # -- read side -----------------------------------------------------------

    @property
    def flat(self) -> FlatDILI:
        return self._buf[self._active][0]

    @property
    def idx(self):
        """The current epoch's `api.DeviceSnapshot` (immutable; safe to
        capture mid-batch — a flip only retargets new readers)."""
        return self._buf[self._active][1]

    @property
    def max_depth(self) -> int:
        return self.flat.max_depth

    @property
    def stats(self) -> EpochStats:
        return self.history[-1]

    # -- write side ----------------------------------------------------------

    def publish(self, flat: FlatDILI, *, overlay_fill: float = 0.0,
                merge_lag: int = 0, merge_s: float = 0.0,
                incremental: bool = False, dirty_frac: float = 1.0,
                n_retrains: int = 0) -> EpochStats:
        """Upload `flat` into the back buffer, flip, bump the epoch."""
        from ..api.snapshot import DeviceSnapshot   # lazy: api imports online

        t0 = time.perf_counter()
        snap = DeviceSnapshot.from_flat(flat, self.dtype, pad=self.pad)
        jax.block_until_ready(snap.arrays)
        publish_s = time.perf_counter() - t0

        back = 1 - self._active if self._active >= 0 else 0
        prev = self._buf[self._active][1] if self._active >= 0 else None
        retraced = not snap.same_shapes(prev)
        self._buf[back] = (flat, snap)
        self._active = back            # the flip: new readers see epoch N+1
        self.epoch += 1

        n_pairs = int((flat.tag == 1).sum())
        st = EpochStats(
            epoch=self.epoch, n_keys=n_pairs,
            n_nodes=flat.n_nodes, n_slots=flat.n_slots,
            bytes_uploaded=snap.nbytes,
            overlay_fill=overlay_fill, merge_lag=merge_lag,
            publish_s=publish_s, retraced=retraced, merge_s=merge_s,
            incremental=incremental, dirty_frac=dirty_frac,
            n_retrains=n_retrains)
        self.history.append(st)
        return st
