"""Merge policy + the OnlineIndex facade (DESIGN.md section 8).

The merge is the only place writes cross the writer/reader boundary: the
overlay is folded through the host DILI with the paper's own machinery —
upserts via Algorithm 7 (insert, with the λ-triggered node adjustment of
lines 20-26), tombstones via Algorithm 8 (delete) — then ONE `flatten()`
produces the next epoch's snapshot and `SnapshotStore.publish` flips it in.
Between merges the read path serves snapshot+overlay fused lookups, so
results are exact at every point in time.

Merge triggers (`MergePolicy.should_merge`):
  * `max_fill`      — overlay `full_fraction` reached (bounded write buffer);
  * `max_writes`    — merge lag: writes absorbed since the last publish
                      (bounds staleness-repair cost, BLI-style);
  * adjustment pressure — a λ-style per-leaf trigger: if any single host leaf
    has pending writes exceeding `pressure_lambda ×` its current pair count,
    merging early lets Algorithm 7's adjustment re-spread that region instead
    of letting the overlay degenerate into a hot sorted run;
  * explicit `flush()`.
"""

from __future__ import annotations

import random
import time
from collections import Counter
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from ..core.dili import DILI, LAMBDA, bulk_load
from ..core.flat import flatten
from ..maintain import (IncrementalFlattener, LeafAccounting,
                        MaintenanceConfig, MaintenanceScheduler,
                        fold_with_accounting, run_reclusters, run_retrains)
from ..obs import NULL_TELEMETRY
from ..obs.trace_export import current_trace_ids, trace_context
from .epoch import EpochStats, SnapshotStore
from .overlay import (TombstoneOverlay, LIVE, TOMBSTONE, fold_overlay,
                      overlay_device_arrays)


@dataclass(frozen=True)
class MergePolicy:
    max_fill: float = 0.5          # overlay full_fraction trigger
    max_writes: int = 4096         # merge-lag trigger (writes since publish)
    pressure_lambda: float = LAMBDA  # per-leaf pending/omega trigger
    pressure_check_every: int = 256  # amortize the host-side leaf walk
    # absolute floor for the pressure trigger: a leaf only counts toward a
    # λ-pressure merge once it holds this many pending writes — a tiny
    # leaf with a handful of pending entries trivially exceeds any ratio
    # and would otherwise force a global publish for a few rows' worth of
    # degradation (pathological once retrains keep frontier leaves small)
    pressure_min_pending: int = 64


def adjust_pressure(dili: DILI, ov: TombstoneOverlay,
                    min_pending: int = 1) -> float:
    """max over host leaves of pending-writes / current-pairs — the overlay
    analogue of Alg. 7's Δ/Ω > λκ adjustment test.  Leaves with fewer than
    `min_pending` pending writes are ignored (policy floor)."""
    if ov.count == 0:
        return 0.0
    keys, _, _ = ov.entries()
    hits: Counter = Counter()
    omega: dict[int, int] = {}
    for k in keys:
        leaf, _ = dili.locate_leaf(float(k))
        lid = id(leaf)
        hits[lid] += 1
        omega[lid] = leaf.omega
    return max((c / max(omega[lid], 1)
                for lid, c in hits.items() if c >= min_pending),
               default=0.0)


class OnlineIndex:
    """Snapshot + overlay + merge lifecycle behind one read/write API.

    Writes land in the (host) tombstone overlay; reads run the fused
    snapshot+overlay device lookup; the merge policy decides when to fold the
    overlay through the host DILI and publish a fresh epoch.  `flatten()` runs
    exactly once per merge — never per write.

    With a `MaintenanceConfig` the merge becomes adaptive (DESIGN.md
    section 12): folding feeds per-leaf accounting, drifted/tombstone-heavy
    subtrees are locally retrained, the flatten is the incremental splice
    (bit-identical, O(dirty)), and — with `background=True` — the whole
    merge runs on a `MaintenanceScheduler` worker so the writer never
    blocks on a publish.  During a background merge the folding overlay is
    kept frozen under the live one and reads resolve live > frozen >
    snapshot, so results stay exact at every instant; the frozen overlay is
    dropped only AFTER the publish flip (re-applying already-folded entries
    is idempotent, so readers are exact on either side of the flip).

    Threading contract: ONE writer thread (writes, flush, stats) plus any
    number of reader threads (`lookup` / `get`); the background worker only
    ever runs one merge at a time.
    """

    def __init__(self, keys=None, vals=None, *, dili: DILI | None = None,
                 policy: MergePolicy | None = None, overlay_cap: int = 4096,
                 dtype=jnp.float64, pad: bool = True, early_exit: bool = True,
                 maintenance: MaintenanceConfig | None = None,
                 telemetry=None,
                 **bulk_kw):
        if dili is None:
            dili = bulk_load(np.asarray(keys, np.float64), vals, **bulk_kw)
        self.dili = dili
        self.tel = telemetry if telemetry is not None else NULL_TELEMETRY
        self.policy = policy or MergePolicy()
        self.early_exit = early_exit
        self.store = SnapshotStore(dtype=dtype, pad=pad)
        self.overlay = TombstoneOverlay.empty(overlay_cap)
        self._overlay_cap0 = self.overlay.cap
        # maintenance subsystem (all None => legacy monolithic merges)
        self.maint = maintenance
        m = maintenance
        self.flattener = (IncrementalFlattener()
                          if m is not None and m.incremental else None)
        # accounting carries BOTH the retrain plan and the write-heat
        # re-clustering plan; reclustering additionally needs the
        # incremental flattener (its segment row counts are the size
        # signal), so with incremental=False it plans nothing
        self.accounting = (LeafAccounting(m)
                           if m is not None and (m.retrain or m.recluster)
                           else None)
        self.scheduler = (MaintenanceScheduler(m.max_queue)
                          if m is not None and m.background else None)
        self.on_publish = None         # post-publish hook (durability
        #                                checkpoints ride it; runs on
        #                                whichever thread published)
        self.maint_degraded = False    # background retries exhausted ->
        #                                merges run synchronously now
        self._merging: TombstoneOverlay | None = None   # frozen, folding
        self._merge_failed = False           # frozen needs writer reclaim
        self._ov_cache: tuple | None = None  # (overlay, merging, arrays)
        self._writes_since_publish = 0
        self._writes_since_pressure = 0
        # incremental λ-pressure state: between merges the host DILI is never
        # mutated (writes only touch the overlay), so leaf identities are
        # stable and each written key needs locating exactly once
        self._leaf_hits: Counter = Counter()    # id(leaf) -> pending writes
        self._leaf_omega: dict[int, int] = {}   # id(leaf) -> omega
        self._unlocated_keys: list[float] = []  # written since last check
        self.n_flattens = 0
        self.n_full_flattens = 0
        self.n_incremental_flattens = 0
        self.n_merges = 0
        self.n_retrains = 0
        self.n_reclusters = 0
        self.last_dirty_frac = 1.0
        self.merge_reasons: Counter = Counter()
        self._publish()

    # -- write path ----------------------------------------------------------

    def upsert(self, key: float, val: int) -> None:
        self.upsert_batch([key], [val])

    def upsert_batch(self, keys, vals) -> None:
        self.overlay = self.overlay.upsert_batch(keys, vals)
        self._unlocated_keys.extend(np.atleast_1d(keys).tolist())
        self._note_writes(len(np.atleast_1d(keys)))

    def delete(self, key: float) -> None:
        self.delete_batch([key])

    def delete_batch(self, keys) -> None:
        self.overlay = self.overlay.delete_batch(keys)
        self._unlocated_keys.extend(np.atleast_1d(keys).tolist())
        self._note_writes(len(np.atleast_1d(keys)))

    def _note_writes(self, n: int) -> None:
        self._writes_since_publish += n
        self._writes_since_pressure += n
        reason = self.should_merge()
        if reason:
            self.merge(reason)

    # -- merge trigger -------------------------------------------------------

    def should_merge(self) -> str | None:
        p = self.policy
        if self.overlay.full_fraction >= p.max_fill:
            return "fill"
        if self._writes_since_publish >= p.max_writes:
            return "lag"
        if self._writes_since_pressure >= p.pressure_check_every:
            self._writes_since_pressure = 0
            # while a background merge is folding, the host tree is being
            # mutated by the worker — skip the λ-pressure walk until it
            # finishes (the fill/lag triggers above stay live)
            if self._merging is None \
                    and self._incremental_pressure() > p.pressure_lambda:
                return "pressure"
        return None

    def _incremental_pressure(self) -> float:
        """λ-pressure over O(writes since last check) tree walks, not the
        whole overlay (duplicate writes to one key count once per write —
        a slight overestimate that only merges a hot region earlier)."""
        for k in self._unlocated_keys:
            leaf, _ = self.dili.locate_leaf(float(k))
            lid = id(leaf)
            self._leaf_hits[lid] += 1
            self._leaf_omega[lid] = leaf.omega
        self._unlocated_keys.clear()
        if not self._leaf_hits:
            return 0.0
        floor = self.policy.pressure_min_pending
        return max((c / max(self._leaf_omega[lid], 1)
                    for lid, c in self._leaf_hits.items() if c >= floor),
                   default=0.0)

    def flush(self) -> EpochStats:
        """Explicit merge+publish; with an empty overlay nothing is folded or
        republished and the current epoch's stats are returned.  With
        background maintenance this is the synchronous barrier: it drains
        the worker and folds everything pending before returning."""
        if self.scheduler is None:
            return self.merge("flush")
        while True:
            self.scheduler.drain()
            if self.overlay.count == 0 and self._merging is None:
                return self.store.stats
            n_err = len(self.scheduler.errors)
            self.merge("flush")
            self.scheduler.drain()
            if len(self.scheduler.errors) > n_err and (
                    self.overlay.count or self._merging is not None):
                # the retry died too: surface it instead of spinning (the
                # pending writes stay readable through the overlay chain)
                raise RuntimeError(
                    "background merge keeps failing; pending writes "
                    "retained in the overlay:\n"
                    + self.scheduler.errors[-1])

    def merge(self, reason: str = "explicit") -> EpochStats:
        """Fold the overlay through the host DILI (Alg. 7/8) and publish —
        inline, or on the maintenance worker when background is on."""
        if self._merging is not None:
            if not self._merge_failed:
                return self.store.stats   # one merge in flight: coalesce
            # a previous merge died mid-pipeline: reclaim its frozen
            # writes HERE, on the writer thread (the worker must never
            # touch self.overlay — it races writer assignments), newest
            # entries winning, and retry below.  Reads were exact the
            # whole time: the frozen overlay stayed visible.
            self.overlay = self._merging.merged_with(self.overlay)
            self._merging = None
            self._merge_failed = False
        if self.overlay.count == 0:    # nothing pending: keep current epoch
            return self.store.stats
        frozen = self.overlay
        self._merging = frozen         # readers: live > frozen > snapshot
        self._frozen_t0 = time.perf_counter()   # -> merge.frozen_dwell
        self.overlay = TombstoneOverlay.empty(self._overlay_cap0)
        # trigger-counter resets happen HERE, on the writer thread, at
        # freeze time: the frozen writes are on their way into the next
        # epoch, and the worker must never write these fields (a worker
        # reset would race the writer's own `+= n` updates).  The stale
        # λ-pressure leaf cache goes with them (the fold invalidates it).
        lag = self._writes_since_publish
        self._writes_since_publish = 0
        self._writes_since_pressure = 0
        self._leaf_hits = Counter()
        self._leaf_omega = {}
        self._unlocated_keys = []
        t_sub = time.perf_counter()    # -> merge.queue_wait (submit -> start)
        # causal tracing: the submitting thread's trace context (the
        # client requests whose writes triggered this merge) rides to the
        # maintenance worker, so background merge.* spans still link back
        # to the requests that caused them
        tids = current_trace_ids()
        if (self.scheduler is not None and not self.maint_degraded
                and self.scheduler.submit(
                    lambda: self._merge_on_worker(frozen, reason, lag,
                                                  t_sub, tids))):
            return self.store.stats
        return self._merge_impl(frozen, reason, lag, t_sub)  # sync/closed

    def _merge_on_worker(self, frozen, reason, lag, t_sub, tids):
        with trace_context(tids):
            return self._merge_impl(frozen, reason, lag, t_sub, retry=True)

    def _merge_impl(self, frozen: TombstoneOverlay, reason: str,
                    lag: int, t_sub: float,
                    retry: bool = False) -> EpochStats:
        """The merge pipeline: fold (+accounting) -> retrain -> flatten ->
        publish.  Runs on the caller's thread or the maintenance worker.

        On the worker path (`retry=True`) a failed attempt is retried up
        to `MaintenanceConfig.max_merge_retries` times with jittered
        exponential backoff — re-running the pipeline over the same frozen
        overlay is idempotent (a partially-applied fold re-applies
        last-write-wins), though pipeline counters/spans from the dead
        attempt do double-count.  Each failed attempt bumps the
        `maint.errors` counter and records a `merge.failed` span on the
        index's own registry.

        After exhaustion (or a sync-path failure) the frozen overlay STAYS
        installed (reads keep resolving it — exactness holds) and is
        flagged; the next merge on the writer thread reclaims it into the
        live overlay (newer wins) and retries.  Exhaustion also degrades
        the index to synchronous merges (`maint_degraded`) so a persistent
        worker-side fault stops burning the scheduler.  The worker never
        assigns self.overlay or the trigger counters — that would race
        the writer's own updates."""
        m = self.maint
        attempts = 1 + (m.max_merge_retries if retry and m is not None
                        else 0)
        for attempt in range(attempts):
            t0 = time.perf_counter()
            try:
                return self._merge_steps(frozen, reason, lag, t_sub)
            except BaseException:
                # failure visibility is unconditional (not gated on
                # `enabled`) but only on the index's OWN registry —
                # NULL_TELEMETRY is a shared module global
                if self.tel is not NULL_TELEMETRY:
                    self.tel.metrics.count("maint.errors")
                    self.tel.spans.record("merge.failed",
                                          time.perf_counter() - t0,
                                          reason=reason, attempt=attempt)
                if attempt == attempts - 1:
                    self._merge_failed = True
                    if retry:
                        self.maint_degraded = True
                    raise
                backoff = m.retry_backoff_s * (2 ** attempt)
                time.sleep(backoff * (0.5 + random.random()))
        raise AssertionError("unreachable")

    def _merge_steps(self, frozen: TombstoneOverlay, reason: str,
                     lag: int, t_sub: float) -> EpochStats:
        t0 = time.perf_counter()
        self.tel.record_span("merge.queue_wait", t0 - t_sub, reason=reason)
        if self.accounting is not None:
            with self.tel.span("merge.fold", reason=reason,
                               pending=frozen.count):
                fold_with_accounting(self.dili, frozen, self.accounting)
            with self.tel.span("merge.retrain"):
                retrains = run_retrains(self.dili, self.accounting)
            with self.tel.span("merge.recluster"):
                reclusters = run_reclusters(self.dili, self.accounting,
                                            self.flattener)
            if reclusters:
                self.n_reclusters += reclusters
                if self.tel.enabled:
                    self.tel.metrics.count("maint.reclusters", reclusters)
        else:
            with self.tel.span("merge.fold", reason=reason,
                               pending=frozen.count):
                fold_overlay(self.dili, frozen)
            retrains = 0
        merge_s = time.perf_counter() - t0
        self.n_merges += 1
        self.n_retrains += retrains
        self.merge_reasons[reason] += 1
        st = self._publish(overlay_fill=frozen.full_fraction,
                           merge_s=merge_s, n_retrains=retrains,
                           merge_lag=lag)
        # drop the frozen overlay only AFTER the flip: between publish and
        # here readers re-apply already-folded entries — idempotent
        self._merging = None
        self.tel.record_span("merge.frozen_dwell",
                             time.perf_counter() - self._frozen_t0,
                             reason=reason)
        if self.on_publish is not None:   # durability checkpoints ride here
            self.on_publish()
        return st

    def _publish(self, overlay_fill: float = 0.0, merge_s: float = 0.0,
                 n_retrains: int = 0, merge_lag: int = 0) -> EpochStats:
        t0 = time.perf_counter()
        with self.tel.span("merge.flatten"):
            if self.flattener is not None:
                flat = self.flattener.flatten(self.dili,
                                              self.dili.take_dirty())
                incremental = self.flattener.last_incremental
                dirty_frac = (self.flattener.last_dirty_rows
                              / max(self.flattener.last_total_rows, 1))
            else:
                flat = flatten(self.dili)  # the ONE full flatten per epoch
                self.dili.take_dirty()     # drain: nothing is dirty vs a
                incremental = False        # fresh full materialization
                dirty_frac = 1.0
        merge_s += time.perf_counter() - t0
        self.n_flattens += 1
        if incremental:
            self.n_incremental_flattens += 1
        else:
            self.n_full_flattens += 1
        self.last_dirty_frac = dirty_frac
        self.tel.sample_publish(
            n_segments=flat.n_segments,
            dirty_rows=(self.flattener.last_dirty_rows
                        if self.flattener is not None else flat.n_slots),
            total_rows=(self.flattener.last_total_rows
                        if self.flattener is not None else flat.n_slots))
        with self.tel.span("merge.publish", epoch=self.store.epoch + 1):
            st = self.store.publish(flat, overlay_fill=overlay_fill,
                                    merge_lag=merge_lag,
                                    merge_s=merge_s, incremental=incremental,
                                    dirty_frac=dirty_frac,
                                    n_retrains=n_retrains)
        if st.retraced and self.tel.enabled:
            self.tel.metrics.count("publish.retraced")
        return st

    def close(self) -> None:
        """Stop the background worker (if any).  Does NOT flush: pending
        overlay writes stay readable, they are just no longer folded."""
        if self.scheduler is not None:
            self.scheduler.close()

    # -- read path -----------------------------------------------------------

    @property
    def epoch(self) -> int:
        return self.store.epoch

    def pending_entries(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(keys, vals, tomb) of every pending write — the live overlay
        over the frozen (merging) one.  Callers composing this with the
        published snapshot must capture it BEFORE reading the snapshot:
        if the background publish lands in between, the newer snapshot
        already contains the frozen entries and re-applying them is
        idempotent; the other order can lose them."""
        ov, mg = self.overlay, self._merging
        if mg is None:
            return ov.entries()
        return mg.merged_with(ov).entries()

    def _overlay_arrays(self) -> dict:
        ov, mg = self.overlay, self._merging
        c = self._ov_cache
        if c is not None and c[0] is ov and c[1] is mg:
            return c[2]
        eff = ov if mg is None else mg.merged_with(ov)
        arrs = overlay_device_arrays(eff, self.store.dtype)
        self._ov_cache = (ov, mg, arrs)
        return arrs

    def lookup(self, queries) -> tuple[np.ndarray, np.ndarray]:
        """Batched fused snapshot+overlay lookup -> (vals, found): one jitted
        dispatch, depth-exact (trip count from the `DeviceSnapshot`, no
        manual threading), query buffer donated (it is freshly uploaded
        here, so the read path never copies it back)."""
        from ..core import search as S
        # overlay BEFORE snapshot (see pending_entries for the ordering)
        ova = self._overlay_arrays()
        idx = self.store.idx
        q = jnp.asarray(queries, self.store.dtype)
        v, f = S.search_with_overlay(idx, ova,
                                     q, early_exit=self.early_exit,
                                     donate_queries=q is not queries)
        return np.asarray(v), np.asarray(f)

    def get(self, key: float) -> int | None:
        """Host-side exact point read (overlay state wins).  Resolves
        live overlay > frozen overlay > published pair table — never the
        mutable host tree, which a background merge may be folding."""
        key = float(key)
        ov, mg = self.overlay, self._merging
        for o in ((ov,) if mg is None else (ov, mg)):
            state, v = o.get(key)
            if state == LIVE:
                return v
            if state == TOMBSTONE:
                return None
        flat = self.store.flat
        i = int(np.searchsorted(flat.pair_key, key))
        if i < flat.n_pairs and flat.pair_key[i] == key:
            return int(flat.pair_val[i])
        return None
