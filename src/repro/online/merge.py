"""Merge policy + the OnlineIndex facade (DESIGN.md section 8).

The merge is the only place writes cross the writer/reader boundary: the
overlay is folded through the host DILI with the paper's own machinery —
upserts via Algorithm 7 (insert, with the λ-triggered node adjustment of
lines 20-26), tombstones via Algorithm 8 (delete) — then ONE `flatten()`
produces the next epoch's snapshot and `SnapshotStore.publish` flips it in.
Between merges the read path serves snapshot+overlay fused lookups, so
results are exact at every point in time.

Merge triggers (`MergePolicy.should_merge`):
  * `max_fill`      — overlay `full_fraction` reached (bounded write buffer);
  * `max_writes`    — merge lag: writes absorbed since the last publish
                      (bounds staleness-repair cost, BLI-style);
  * adjustment pressure — a λ-style per-leaf trigger: if any single host leaf
    has pending writes exceeding `pressure_lambda ×` its current pair count,
    merging early lets Algorithm 7's adjustment re-spread that region instead
    of letting the overlay degenerate into a hot sorted run;
  * explicit `flush()`.
"""

from __future__ import annotations

from collections import Counter
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from ..core.dili import DILI, LAMBDA, bulk_load
from ..core.flat import flatten
from .epoch import EpochStats, SnapshotStore
from .overlay import (TombstoneOverlay, LIVE, TOMBSTONE, fold_overlay,
                      overlay_device_arrays)


@dataclass(frozen=True)
class MergePolicy:
    max_fill: float = 0.5          # overlay full_fraction trigger
    max_writes: int = 4096         # merge-lag trigger (writes since publish)
    pressure_lambda: float = LAMBDA  # per-leaf pending/omega trigger
    pressure_check_every: int = 256  # amortize the host-side leaf walk


def adjust_pressure(dili: DILI, ov: TombstoneOverlay) -> float:
    """max over host leaves of pending-writes / current-pairs — the overlay
    analogue of Alg. 7's Δ/Ω > λκ adjustment test."""
    if ov.count == 0:
        return 0.0
    keys, _, _ = ov.entries()
    hits: Counter = Counter()
    omega: dict[int, int] = {}
    for k in keys:
        leaf, _ = dili.locate_leaf(float(k))
        lid = id(leaf)
        hits[lid] += 1
        omega[lid] = leaf.omega
    return max(c / max(omega[lid], 1) for lid, c in hits.items())


class OnlineIndex:
    """Snapshot + overlay + merge lifecycle behind one read/write API.

    Writes land in the (host) tombstone overlay; reads run the fused
    snapshot+overlay device lookup; the merge policy decides when to fold the
    overlay through the host DILI and publish a fresh epoch.  `flatten()` runs
    exactly once per merge — never per write.
    """

    def __init__(self, keys=None, vals=None, *, dili: DILI | None = None,
                 policy: MergePolicy | None = None, overlay_cap: int = 4096,
                 dtype=jnp.float64, pad: bool = True, early_exit: bool = True,
                 **bulk_kw):
        if dili is None:
            dili = bulk_load(np.asarray(keys, np.float64), vals, **bulk_kw)
        self.dili = dili
        self.policy = policy or MergePolicy()
        self.early_exit = early_exit
        self.store = SnapshotStore(dtype=dtype, pad=pad)
        self.overlay = TombstoneOverlay.empty(overlay_cap)
        self._overlay_cap0 = self.overlay.cap
        self._ov_arrays: dict | None = None     # device mirror cache
        self._writes_since_publish = 0
        self._writes_since_pressure = 0
        # incremental λ-pressure state: between merges the host DILI is never
        # mutated (writes only touch the overlay), so leaf identities are
        # stable and each written key needs locating exactly once
        self._leaf_hits: Counter = Counter()    # id(leaf) -> pending writes
        self._leaf_omega: dict[int, int] = {}   # id(leaf) -> omega
        self._unlocated_keys: list[float] = []  # written since last check
        self.n_flattens = 0
        self.n_merges = 0
        self.merge_reasons: Counter = Counter()
        self._publish()

    # -- write path ----------------------------------------------------------

    def upsert(self, key: float, val: int) -> None:
        self.upsert_batch([key], [val])

    def upsert_batch(self, keys, vals) -> None:
        self.overlay = self.overlay.upsert_batch(keys, vals)
        self._unlocated_keys.extend(np.atleast_1d(keys).tolist())
        self._note_writes(len(np.atleast_1d(keys)))

    def delete(self, key: float) -> None:
        self.delete_batch([key])

    def delete_batch(self, keys) -> None:
        self.overlay = self.overlay.delete_batch(keys)
        self._unlocated_keys.extend(np.atleast_1d(keys).tolist())
        self._note_writes(len(np.atleast_1d(keys)))

    def _note_writes(self, n: int) -> None:
        self._ov_arrays = None
        self._writes_since_publish += n
        self._writes_since_pressure += n
        reason = self.should_merge()
        if reason:
            self.merge(reason)

    # -- merge trigger -------------------------------------------------------

    def should_merge(self) -> str | None:
        p = self.policy
        if self.overlay.full_fraction >= p.max_fill:
            return "fill"
        if self._writes_since_publish >= p.max_writes:
            return "lag"
        if self._writes_since_pressure >= p.pressure_check_every:
            self._writes_since_pressure = 0
            if self._incremental_pressure() > p.pressure_lambda:
                return "pressure"
        return None

    def _incremental_pressure(self) -> float:
        """λ-pressure over O(writes since last check) tree walks, not the
        whole overlay (duplicate writes to one key count once per write —
        a slight overestimate that only merges a hot region earlier)."""
        for k in self._unlocated_keys:
            leaf, _ = self.dili.locate_leaf(float(k))
            lid = id(leaf)
            self._leaf_hits[lid] += 1
            self._leaf_omega[lid] = leaf.omega
        self._unlocated_keys.clear()
        if not self._leaf_hits:
            return 0.0
        return max(c / max(self._leaf_omega[lid], 1)
                   for lid, c in self._leaf_hits.items())

    def flush(self) -> EpochStats:
        """Explicit merge+publish; with an empty overlay nothing is folded or
        republished and the current epoch's stats are returned."""
        return self.merge("flush")

    def merge(self, reason: str = "explicit") -> EpochStats:
        """Fold the overlay through the host DILI (Alg. 7/8) and publish."""
        if self.overlay.count == 0:    # nothing pending: keep current epoch
            return self.store.stats
        fold_overlay(self.dili, self.overlay)
        fill = self.overlay.full_fraction
        self.overlay = TombstoneOverlay.empty(self._overlay_cap0)
        self._ov_arrays = None
        self._leaf_hits.clear()         # merge mutates the tree: leaf ids
        self._leaf_omega.clear()        # and omegas are stale now
        self._unlocated_keys.clear()
        self.n_merges += 1
        self.merge_reasons[reason] += 1
        return self._publish(overlay_fill=fill)

    def _publish(self, overlay_fill: float = 0.0) -> EpochStats:
        flat = flatten(self.dili)      # the ONE flatten per epoch
        self.n_flattens += 1
        st = self.store.publish(flat, overlay_fill=overlay_fill,
                                merge_lag=self._writes_since_publish)
        self._writes_since_publish = 0
        self._writes_since_pressure = 0
        return st

    # -- read path -----------------------------------------------------------

    @property
    def epoch(self) -> int:
        return self.store.epoch

    def _overlay_arrays(self) -> dict:
        if self._ov_arrays is None:
            self._ov_arrays = overlay_device_arrays(self.overlay,
                                                    self.store.dtype)
        return self._ov_arrays

    def lookup(self, queries) -> tuple[np.ndarray, np.ndarray]:
        """Batched fused snapshot+overlay lookup -> (vals, found): one jitted
        dispatch, depth-exact (trip count from the `DeviceSnapshot`, no
        manual threading), query buffer donated (it is freshly uploaded
        here, so the read path never copies it back)."""
        from ..core import search as S
        q = jnp.asarray(queries, self.store.dtype)
        v, f = S.search_with_overlay(self.store.idx, self._overlay_arrays(),
                                     q, early_exit=self.early_exit,
                                     donate_queries=q is not queries)
        return np.asarray(v), np.asarray(f)

    def get(self, key: float) -> int | None:
        """Host-side exact point read (overlay state wins)."""
        state, v = self.overlay.get(float(key))
        if state == LIVE:
            return v
        if state == TOMBSTONE:
            return None
        return self.dili.search(float(key))
