"""Tombstone-capable delta overlay: the write buffer of the online-update
subsystem (DESIGN.md section 8).

A `TombstoneOverlay` is an immutable sorted run of pending writes — upserts
AND deletes — sitting in front of an immutable device snapshot, LSM-style
(PGM-index's snapshot+delta composition; BLI's buffered write path).  Each
entry is (key, val, tomb): `tomb != 0` marks a delete of a key that may still
exist in the snapshot.  Semantics:

  * last-write-wins: applying a batch dedupes by key keeping the newest
    entry, so upsert-then-delete leaves a tombstone and delete-then-upsert
    leaves a live pair;
  * capacity doubling: the backing arrays grow by powers of two, so the
    padded device mirror only changes shape (and re-traces the fused lookup)
    on a doubling, never on a plain write;
  * reads resolve overlay-hit / overlay-tombstone / snapshot-hit in one
    fused jitted pass (`core.search.search_with_overlay`), reusing
    `core.search.search_batch` for the snapshot side.

The structure is persistent (every write returns a new overlay) so a reader
holding epoch N's overlay mirror is never invalidated mid-lookup.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from ..core import search as S
from ..core.flat import merge_sorted_runs

LIVE, TOMBSTONE = 0, 1


@dataclass(frozen=True)
class TombstoneOverlay:
    keys: np.ndarray    # f64 [cap], padded with +inf
    vals: np.ndarray    # i64 [cap]
    tomb: np.ndarray    # i8  [cap], 1 = tombstone
    count: int
    cap: int

    @staticmethod
    def empty(cap: int = 4096) -> "TombstoneOverlay":
        cap = max(int(cap), 1)
        return TombstoneOverlay(np.full(cap, np.inf),
                                np.zeros(cap, np.int64),
                                np.zeros(cap, np.int8), 0, cap)

    # -- writes (persistent: return a new overlay) --------------------------

    def _apply(self, k: np.ndarray, v: np.ndarray,
               t: np.ndarray) -> "TombstoneOverlay":
        if len(k) == 0 and self.count == 0:
            return self
        # the buffer is a sorted run: merge the batch in (last-write-wins)
        # instead of re-sorting the whole buffer on every write batch
        nk, (nv, nt) = merge_sorted_runs(
            self.keys[: self.count],
            (self.vals[: self.count], self.tomb[: self.count]),
            np.asarray(k, np.float64),
            (np.asarray(v, np.int64), np.asarray(t, np.int8)))
        cap = self.cap
        while len(nk) > cap:
            cap *= 2
        keys = np.full(cap, np.inf)
        vals = np.zeros(cap, np.int64)
        tomb = np.zeros(cap, np.int8)
        keys[: len(nk)] = nk
        vals[: len(nk)] = nv
        tomb[: len(nk)] = nt
        return TombstoneOverlay(keys, vals, tomb, len(nk), cap)

    def upsert_batch(self, k, v) -> "TombstoneOverlay":
        k = np.atleast_1d(np.asarray(k, np.float64))
        v = np.atleast_1d(np.asarray(v, np.int64))
        return self._apply(k, v, np.zeros(len(k), np.int8))

    def delete_batch(self, k) -> "TombstoneOverlay":
        k = np.atleast_1d(np.asarray(k, np.float64))
        return self._apply(k, np.zeros(len(k), np.int64),
                           np.ones(len(k), np.int8))

    def merged_with(self, newer: "TombstoneOverlay") -> "TombstoneOverlay":
        """One overlay equivalent to `self` with `newer` applied on top
        (newer wins per key).  Used by the background-merge read path: the
        frozen (merging) overlay under the live one."""
        return self._apply(*newer.entries())

    # -- host-side point state ----------------------------------------------

    def get(self, key: float) -> tuple[int, int | None]:
        """(state, val): state in {LIVE, TOMBSTONE, -1 absent}."""
        i = int(np.searchsorted(self.keys[: self.count], key))
        if i < self.count and self.keys[i] == key:
            if self.tomb[i]:
                return TOMBSTONE, None
            return LIVE, int(self.vals[i])
        return -1, None

    # -- introspection -------------------------------------------------------

    @property
    def full_fraction(self) -> float:
        return self.count / max(self.cap, 1)

    @property
    def n_tombstones(self) -> int:
        return int(self.tomb[: self.count].sum())

    @property
    def n_live(self) -> int:
        return self.count - self.n_tombstones

    def entries(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(keys, vals, tomb) of the populated prefix, sorted by key."""
        return (self.keys[: self.count], self.vals[: self.count],
                self.tomb[: self.count])


def fold_overlay(dili, ov: TombstoneOverlay) -> None:
    """Fold pending writes through the host DILI — the writer-boundary
    crossing shared by `OnlineIndex.merge` and `sharded_merge`: tombstones
    via Algorithm 8 (delete), live entries via Algorithm 7 (upsert)."""
    keys, vals, tomb = ov.entries()
    for k, v, t in zip(keys, vals, tomb):
        if t:
            dili.delete(float(k))
        else:
            dili.upsert(float(k), int(v))


# ---------------------------------------------------------------------------
# Device mirror + fused combined lookup
# ---------------------------------------------------------------------------


def overlay_device_arrays(ov: TombstoneOverlay, dtype=jnp.float64) -> dict:
    """Upload the overlay.  Shapes are the (pow2) capacity, so the fused
    lookup only re-traces when the overlay doubles."""
    return dict(keys=jnp.asarray(ov.keys, dtype),
                vals=jnp.asarray(ov.vals, jnp.int64),
                tomb=jnp.asarray(ov.tomb, jnp.int8))


def search_with_updates(idx: dict, ov: dict, queries: jnp.ndarray,
                        max_depth: int | None = None):
    """DEPRECATED alias of `core.search.search_with_overlay` (kept from the
    PR-2 rename).  Use `search_with_overlay` directly, or go through the
    `repro.api.LearnedIndex` facade, which fuses the overlay automatically.
    """
    import warnings
    warnings.warn(
        "repro.online.search_with_updates is deprecated; call "
        "core.search.search_with_overlay or use repro.api.LearnedIndex",
        DeprecationWarning, stacklevel=2)
    return S.search_with_overlay(idx, ov, queries, max_depth)
