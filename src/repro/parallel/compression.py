"""Gradient compression: int8 quantized all-reduce with error feedback.

pjit hides the DP all-reduce inside the partitioner, so compressed
collectives need shard_map: `psum_int8` quantizes each shard's gradient to
int8 with a per-tensor scale, psums the int8 payload (as int32 to avoid
overflow at 512 participants), and dequantizes.  `ErrorFeedback` carries the
quantization residual into the next step (Karimireddy et al. 2019) so
convergence is preserved — validated in tests/test_compression.py on a
quadratic problem and in the example driver.

Traffic: 1 byte/element vs 2 (bf16) or 4 (f32) — a 2-4x cut of the DP
all-reduce term in the roofline (see EXPERIMENTS.md section Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def psum_int8(x, axis_name):
    """Compressed psum of a float tensor along `axis_name` (inside
    shard_map).  Scales are psum-maxed so every participant dequantizes
    consistently."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    scale = jax.lax.pmax(scale, axis_name)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return s.astype(jnp.float32) * scale


def ef_compress(grads, residual):
    """Error feedback: g' = Q(g + r); r' = (g + r) - g'."""
    def one(g, r):
        t = g.astype(jnp.float32) + r
        q, s = quantize_int8(t)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), t - deq
    flat = jax.tree.map(one, grads, residual)
    comp = jax.tree.map(lambda x: x[0], flat,
                        is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda x: x[1], flat,
                       is_leaf=lambda x: isinstance(x, tuple))
    return comp, res


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
