"""Pipeline parallelism (GPipe schedule) over the multi-pod "pod" axis.

The layer stack is split into |pod| contiguous stages (stacked params get a
leading stage dim sharded over "pod").  A shard_map runs the classic GPipe
loop: M microbatches flow stage-to-stage via `ppermute`; each device step
computes its stage on the microbatch it currently holds.  Bubble fraction =
(S-1)/(M+S-1).  Used for the dense family; exercised by
tests/test_pipeline_pp.py and available to the dry-run via --set pp=1
(multi-pod mesh).

This is deliberately forward-oriented (training uses it through jax.grad —
autodiff of ppermute reverses the ring).  DP/TP compose: the body below only
touches the "pod" axis; batch stays sharded over "data" and TP over "model"
inside each stage exactly as in the non-PP path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import layers as L
from ..models import model as MDL
from ..models.config import ModelConfig


def split_stages(params, n_stages: int):
    """Reshape stacked layer params [L, ...] -> [S, L/S, ...]."""
    def one(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])
    return jax.tree.map(one, params)


def pipeline_forward(cfg: ModelConfig, mesh: Mesh, params, tokens,
                     n_micro: int = 8):
    """Embedding + PP layer stack + head.  tokens: [B, S_len].

    params: full model params (layers stacked [L, ...]); embedding/head are
    replicated across stages (computed on stage 0 / last stage and passed
    through the ring with the activations).
    """
    n_stages = mesh.shape["pod"]
    staged = split_stages(params["layers"], n_stages)
    b, s = tokens.shape
    assert b % n_micro == 0

    x = L.embed(params["embed"], cfg, tokens)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def stage_fn(stage_params, h):
        """Run this stage's layers on one microbatch of activations."""
        def body(carry, pl_):
            hh, _ = MDL._attn_block(pl_, cfg, carry, positions_mb)
            return hh, None
        positions_mb = jnp.broadcast_to(jnp.arange(h.shape[1]),
                                        (h.shape[0], h.shape[1]))
        h, _ = jax.lax.scan(body, h, stage_params)
        return h

    mb = x.reshape(n_micro, b // n_micro, s, -1)

    def spmd(staged_params, mb):
        stage = jax.lax.axis_index("pod")
        sp = jax.tree.map(lambda t: t[0], staged_params)  # this stage's slice
        n_steps = n_micro + n_stages - 1
        buf = jnp.zeros_like(mb)            # outputs accumulated on last stage

        def step(carry, t):
            inflight, buf = carry
            # stage 0 injects microbatch t (if valid); others use inflight
            mb_idx = jnp.clip(t, 0, n_micro - 1)
            injected = mb[mb_idx]
            h_in = jnp.where(stage == 0, injected, inflight)
            h_out = stage_fn(sp, h_in)
            # pass down the ring: stage i -> i+1
            perm = [(i, i + 1) for i in range(n_stages - 1)]
            passed = jax.lax.ppermute(h_out, "pod", perm)
            # last stage writes its finished microbatch t-(S-1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            is_valid = (t >= n_stages - 1) & (stage == n_stages - 1)
            buf = jnp.where(
                is_valid,
                jax.lax.dynamic_update_index_in_dim(buf, h_out, out_idx,
                                                    axis=0),
                buf)
            return (passed, buf), None

        inflight0 = jnp.zeros_like(mb[0])
        (_, buf), _ = jax.lax.scan(step, (inflight0, buf),
                                   jnp.arange(n_steps))
        # broadcast the last stage's buffer to everyone
        buf = jax.lax.psum(
            jnp.where(stage == n_stages - 1, buf, jnp.zeros_like(buf)),
            "pod")
        return buf

    from jax.experimental.shard_map import shard_map
    fn = shard_map(
        spmd, mesh=mesh,
        in_specs=(P("pod"), P()),
        out_specs=P(),
        check_rep=False)
    out = fn(staged, mb)                    # [n_micro, b/m, s, d]
    x = out.reshape(b, s, -1)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.lm_logits(params["embed"], cfg, x)
