"""Logical -> physical sharding rules (FSDP / TP / EP / SP / DP).

Mesh axes: ("data", "model") single-pod 16x16; ("pod", "data", "model")
multi-pod 2x16x16.  FSDP shards parameters (and optimizer states) over the
data-parallel axes; TP shards heads / d_ff / vocab over "model"; MoE experts
shard over "model" when divisible (EP) else expert-TP; long-context KV caches
shard their sequence dim over "model" (SP, flash-decode style partial
softmax handled by the SPMD partitioner on the contracting einsum).

Every spec is passed through `fit_spec` which drops mesh axes that do not
divide the corresponding dimension (e.g. whisper's vocab 51865) — degrading
to replication instead of failing.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig, ShapeConfig


def dp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def fit_spec(shape: tuple, spec: P, mesh: Mesh) -> P:
    """Drop axes that don't evenly divide their dimension."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            out.append(None)
            continue
        if dim % axis_size(mesh, ax) == 0:
            out.append(ax)
        else:
            # try single-axis fallback for composite axes
            if isinstance(ax, tuple):
                kept = tuple(a for a in ax if dim % mesh.shape[a] == 0)
                out.append(kept[0] if kept else None)
            else:
                out.append(None)
    return P(*out)


def param_spec(path: tuple, shape: tuple, cfg: ModelConfig,
               mesh: Mesh) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = names[-1]
    fsdp = dp_axes(mesh)
    stacked = names[0] in ("layers", "encoder", "cross")
    lead = (None,) if stacked else ()
    model = "model"
    ep_ok = cfg.n_experts > 0 and cfg.n_experts % mesh.shape["model"] == 0

    def S(*spec):
        return fit_spec(shape, P(*(lead + spec)), mesh)

    # heads-aware attention TP: sharding the packed (H*hd) dim when the head
    # count does not divide |model| makes the (B,S,H,hd) reshape cross shard
    # boundaries — the SPMD partitioner then ALL-GATHERS the activations
    # inside the layer loop (found via the roofline walker on internvl2:
    # 4.2 GiB/layer redundant all-gather).  "auto" degrades to FSDP-only.
    nmod = mesh.shape["model"]
    q_tp_ok = cfg.n_heads % nmod == 0 if cfg.n_heads else False
    kv_tp_ok = cfg.n_kv_heads % nmod == 0 if cfg.n_kv_heads else False
    attn_tp = {"packed": (True, True), "off": (False, False),
               "auto": (q_tp_ok, kv_tp_ok and q_tp_ok)}[cfg.attn_tp]

    if name == "tok":
        return fit_spec(shape, P(model, fsdp), mesh)
    if name == "head":
        return fit_spec(shape, P(fsdp, model), mesh)
    if name == "wq":
        return S(fsdp, model) if attn_tp[0] else S(fsdp, None)
    if name in ("wk", "wv"):
        return S(fsdp, model) if attn_tp[1] else S(fsdp, None)
    if name == "wo":
        return S(model, fsdp) if attn_tp[0] else S(None, fsdp)
    if name in ("w_up", "w_gate") and "moe" not in names:
        return S(fsdp, model)
    if name == "w_down" and "moe" not in names:
        return S(model, fsdp)
    if name == "router":
        return S(fsdp, None)
    if name in ("w_up", "w_gate") and "moe" in names:
        return S(model, fsdp, None) if ep_ok else S(None, fsdp, model)
    if name == "w_down" and "moe" in names:
        return S(model, fsdp, None) if ep_ok else S(None, model, fsdp)
    if name == "w_in":
        return S(fsdp, model)
    if name == "conv_w":
        return S(None, model)
    if name in ("conv_b", "dt_bias", "d_skip", "norm_w"):
        return S(model)
    if name == "w_xbc":
        return S(model, None)
    if name == "w_dt":
        return S(None, model)
    if name == "a_log" and len(shape) >= 2 + len(lead):
        return S(model, None)
    if name == "w_out":
        return S(model, fsdp)
    if name == "patch_proj":
        return fit_spec(shape, P(fsdp, model), mesh)
    # norms & scalars: replicated
    return P(*([None] * len(shape)))


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_shape) -> Any:
    def one(path, leaf):
        spec = param_spec(path, leaf.shape, cfg, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params_shape)


# ---------------------------------------------------------------------------
# batch / cache shardings
# ---------------------------------------------------------------------------


def batch_spec(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
               accum: int = 1) -> dict:
    """Input specs for one step.  Token arrays are [B, S] (or [A, B/A, S]
    with grad accumulation).  Batch sharded over dp axes when divisible."""
    dp = dp_axes(mesh)
    b = shape.global_batch
    lead = (None,) if accum > 1 else ()

    def tok_spec(bdim):
        return fit_spec((bdim, shape.seq_len),
                        P(*(lead + (dp, None))), mesh) \
            if accum <= 1 else fit_spec((accum, bdim, shape.seq_len),
                                        P(None, dp, None), mesh)
    return dict(dp=dp, tok=tok_spec(b // max(accum, 1)))


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int,
                long_context: bool = False) -> dict:
    """PartitionSpecs for the KV/SSM cache pytree (see model.make_cache)."""
    dp = dp_axes(mesh)
    out = {}
    if cfg.family == "ssm":
        out["conv"] = P(None, dp, None, "model")
        out["ssm"] = P(None, dp, "model", None)
        out["pos"] = P()
        return out
    if cfg.family == "hybrid":
        out["conv"] = P(None, dp, None, "model")
        out["ssm"] = P(None, dp, "model", None, None)
        out["pos"] = P()
        if cfg.shared_attn_every:
            # SP: shard the (huge) shared-site KV over seq; batch=1 in the
            # long-context shape, so the seq dim takes the "data" axis
            if long_context:
                out["shared_k"] = P(None, None, "data", "model", None)
            else:
                out["shared_k"] = P(None, dp, None, "model", None)
            out["shared_v"] = out["shared_k"]
        return out
    # attention families: [L, B, S, Hkv, hd] — SP on seq over "model"
    # (flash-decode style; the partitioner renormalizes the sharded softmax)
    out["k"] = P(None, dp, "model", None, None)
    out["v"] = out["k"]
    out["pos"] = P()
    if cfg.is_encdec:
        out["enc_out"] = P(None, dp, None)
    return out


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_shape,
                    long_context: bool = False):
    specs = cache_specs(cfg, mesh, 0, long_context)

    def one(path, leaf):
        key = getattr(path[0], "key", None)
        spec = specs.get(key, P())
        return NamedSharding(mesh, fit_spec(leaf.shape, spec, mesh))
    return jax.tree_util.tree_map_with_path(one, cache_shape)
