"""Concurrent serving front-end over `repro.api.LearnedIndex`
(DESIGN.md section 15): request batching/coalescing, admission control,
adaptive batch sizing, open-loop load generation, and the LLM-serving
session table.
"""

from .batcher import (AdaptiveBatchSizer, RejectedError, Request,
                      RequestBatcher, SERVE_OPS, ServeConfig, coalesce,
                      compatible, pow2_bucket)
from .frontend import ServeClient, ServeFrontend
from .loadgen import LoadReport, open_loop, saturation_search
from .sessions import SessionTable

__all__ = [
    "AdaptiveBatchSizer", "RejectedError", "Request", "RequestBatcher",
    "SERVE_OPS", "ServeConfig", "coalesce", "compatible", "pow2_bucket",
    "ServeClient", "ServeFrontend",
    "LoadReport", "open_loop", "saturation_search",
    "SessionTable",
]
