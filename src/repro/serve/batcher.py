"""Request batcher/coalescer + admission control (DESIGN.md section 15).

The serving front-end's core: many concurrent client streams submit
single-op requests; ONE worker thread dequeues them in arrival order,
coalesces runs of compatible requests (same op type, and for ranges the
same `max_hits`) into one facade batch, executes it through
`repro.api.LearnedIndex`, and completes each request's future with its
slice of the batched result.

Why this shape:

  * FIFO + prefix coalescing preserves a TOTAL order over all client
    streams — a strict superset of the per-client program order the
    consistency contract requires — and that total order is journaled as
    plain `OpBatch`es, so the exact serialization the concurrent run
    applied can be replayed through `WorkloadRunner` for the oracle
    equivalence check.
  * The worker thread is the facade's single caller, so the engines'
    one-writer threading contract holds by construction; clients never
    touch the index.
  * Admission control is a bounded pending-op queue: a submit that would
    exceed the bound fails immediately with `RejectedError` (load
    shedding — the op is never executed, never journaled, never
    acknowledged), instead of letting queue delay grow without bound.
  * Batch sizing is AIMD over the facade's pow2 padding buckets: the
    coalescer fills up to the bucket boundary (padding makes the extra
    lanes free), grows the target additively under queue pressure, and
    halves it when a batch's service time blows the latency target.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass

import numpy as np

from ..obs.trace_export import mint_trace_id, trace_context
from ..obs.tracing import SERVE_SPANS  # noqa: F401  (re-export convenience)
from ..workloads.generator import OpBatch

#: ops a request may carry — the facade's batched entry points
SERVE_OPS = ("lookup", "range", "upsert", "delete")


class RejectedError(RuntimeError):
    """Admission control shed this request: the queue bound was hit.  The
    op was NOT executed and NOT acknowledged — retry later or back off."""


def pow2_bucket(n: int, floor: int = 64) -> int:
    """The facade's pow2 padding bucket for an n-lane batch (the same
    recipe as `LearnedIndex._pad_batch`): lanes between a bucket boundary
    and the next are free, so the coalescer fills to the boundary."""
    if n <= 0:
        return floor
    return 1 << max(int(np.log2(floor)), int(n - 1).bit_length())


@dataclass
class ServeConfig:
    """Knobs for the serving front-end (batcher + admission + sizing).

    queue_cap_ops    : admission bound — max pending (queued, unexecuted)
                       ops; a submit past it sheds with `RejectedError`.
    min_batch_ops    : AIMD floor = the facade's smallest pow2 pad bucket.
    max_batch_ops    : AIMD ceiling for one coalesced facade batch.
    dwell_s          : how long the worker waits for the batch to fill
                       toward the target before dispatching what it has.
    latency_slo_s    : service-time target per facade batch; one batch
                       over it halves the size target (the MD step).
    aimd_add_ops     : additive size-target increase per pressured batch.
    max_hits         : range window bound all front-end range requests
                       share (compatibility key for coalescing).
    """

    queue_cap_ops: int = 8192
    min_batch_ops: int = 64
    max_batch_ops: int = 2048
    dwell_s: float = 0.0005
    latency_slo_s: float = 0.050
    aimd_add_ops: int = 64
    max_hits: int = 64


class Request:
    """One client op in flight: payload arrays + completion future.

    `t_arrival` is the *intended* arrival time (open-loop load generators
    set it to the scheduled arrival so queueing delay from a late submit
    is charged to the system, not hidden — no coordinated omission);
    it defaults to the submit time.  `wait()` blocks until the batcher
    completed (or failed) the op and returns the op's result."""

    __slots__ = ("op", "keys", "vals", "lo", "hi", "max_hits", "client_id",
                 "t_submit", "t_arrival", "t_done", "result", "error",
                 "trace_id", "_event")

    def __init__(self, op: str, *, keys=None, vals=None, lo=None, hi=None,
                 max_hits: int = 64, client_id: str = "",
                 t_arrival: float | None = None):
        if op not in SERVE_OPS:
            raise ValueError(f"unknown op {op!r}; expected one of "
                             f"{SERVE_OPS}")
        self.op = op
        self.keys = (None if keys is None
                     else np.atleast_1d(np.asarray(keys, np.float64)))
        self.vals = (None if vals is None
                     else np.atleast_1d(np.asarray(vals, np.int64)))
        self.lo = (None if lo is None
                   else np.atleast_1d(np.asarray(lo, np.float64)))
        self.hi = (None if hi is None
                   else np.atleast_1d(np.asarray(hi, np.float64)))
        self.max_hits = int(max_hits)
        self.client_id = client_id
        self.t_submit = time.perf_counter()
        self.t_arrival = self.t_submit if t_arrival is None else t_arrival
        self.t_done: float | None = None
        self.result = None
        self.error: BaseException | None = None
        # causal trace id: minted at construction (i.e. at client submit —
        # `ServeFrontend.submit` builds the Request inline), carried through
        # coalescing so every downstream stage can link back to this request
        self.trace_id = mint_trace_id()
        self._event = threading.Event()

    @property
    def n_ops(self) -> int:
        if self.op == "range":
            return len(self.lo)
        return len(self.keys)

    @property
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def latency_s(self) -> float | None:
        """End-to-end seconds from (intended) arrival to completion."""
        if self.t_done is None:
            return None
        return self.t_done - self.t_arrival

    def wait(self, timeout: float | None = None):
        if not self._event.wait(timeout):
            raise TimeoutError(f"{self.op} request not served in "
                               f"{timeout}s")
        if self.error is not None:
            raise self.error
        return self.result

    def _complete(self, result=None, error: BaseException | None = None,
                  t_done: float | None = None) -> None:
        self.result = result
        self.error = error
        self.t_done = time.perf_counter() if t_done is None else t_done
        self._event.set()


def compatible(a: Request, b: Request) -> bool:
    """Can these requests share one facade batch?  Same op type, and
    ranges must agree on the window bound (one `max_hits` per call)."""
    return a.op == b.op and (a.op != "range" or a.max_hits == b.max_hits)


def coalesce(pending, cap_ops: int) -> list[Request]:
    """Pop the longest prefix of mutually-compatible requests totalling
    <= `cap_ops` lanes from the deque (the head request is always taken,
    even oversized — it must make progress).  Prefix-only grouping is
    what preserves the cross-client total order."""
    first = pending.popleft()
    group = [first]
    total = first.n_ops
    while pending and compatible(first, pending[0]) \
            and total + pending[0].n_ops <= cap_ops:
        r = pending.popleft()
        group.append(r)
        total += r.n_ops
    return group


class AdaptiveBatchSizer:
    """AIMD target for coalesced batch lanes.

    Observation per dispatched batch: (queue depth in ops at dispatch,
    service seconds).  Service time over the SLO halves the target
    (multiplicative decrease — the batch is too big for the latency
    budget); queue depth above the current target grows it additively
    (there is demand the current size leaves queued).  `cap` rounds the
    target up to the facade's pow2 pad bucket, because lanes up to the
    bucket boundary cost nothing extra."""

    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg
        self.target = cfg.min_batch_ops

    def observe(self, queue_depth_ops: int, service_s: float) -> None:
        if service_s > self.cfg.latency_slo_s:
            self.target = max(self.target // 2, self.cfg.min_batch_ops)
        elif queue_depth_ops > self.target:
            self.target = min(self.target + self.cfg.aimd_add_ops,
                              self.cfg.max_batch_ops)

    @property
    def cap(self) -> int:
        return min(pow2_bucket(self.target, self.cfg.min_batch_ops),
                   self.cfg.max_batch_ops)


class RequestBatcher:
    """The serving worker: bounded FIFO queue + coalescing dispatch loop.

    One instance owns one `LearnedIndex` (or anything duck-typed with
    lookup/range/upsert/delete — the batcher unit tests drive a stub).
    `submit()` is called from any number of client threads; everything
    engine-side happens on the single worker thread.  `journal` holds the
    executed facade batches in commit order as `OpBatch`es — feed it to
    `WorkloadRunner.run` to replay the exact serialization."""

    def __init__(self, index, config: ServeConfig | None = None,
                 telemetry=None, journal: bool = True):
        self.index = index
        self.cfg = config or ServeConfig()
        self.sizer = AdaptiveBatchSizer(self.cfg)
        self.tel = telemetry if telemetry is not None \
            else getattr(index, "telemetry", None)
        if self.tel is not None:
            # serve taxonomy lives in the SAME per-index telemetry bundle,
            # so `LearnedIndex.metrics()` exports it alongside merge spans
            self.tel.spans.declare(*SERVE_SPANS)
            self.tel.metrics.declare_histogram(
                *(f"serve.e2e.{op}" for op in SERVE_OPS), "serve.batch.ops")
        self.journal: list[OpBatch] | None = [] if journal else None
        self._lock = threading.Lock()
        self._nonempty = threading.Condition(self._lock)
        from collections import deque
        self._pending: deque[Request] = deque()
        self._pending_ops = 0
        self._inflight = 0                  # ops dequeued, not yet done
        self._idle = threading.Condition(self._lock)
        self._stop = False
        # counters (ops unless named otherwise); written by one thread
        # each, read by anyone — plain ints are atomic enough to sample
        self.n_accepted = 0
        self.n_shed = 0
        self.n_completed = 0
        self.n_failed = 0
        self.n_batches = 0
        self.batch_ops: list[int] = []      # per dispatched batch
        self._worker = threading.Thread(target=self._run,
                                        name="serve-batcher", daemon=True)
        self._worker.start()

    # -- client side ---------------------------------------------------------

    def submit(self, req: Request) -> Request:
        """Enqueue or shed.  Raises `RejectedError` when the pending-op
        bound is hit (the fast path: one lock, no allocation beyond the
        request itself)."""
        with self._nonempty:
            if self._stop:
                raise RuntimeError("batcher is closed")
            if self._pending_ops + req.n_ops > self.cfg.queue_cap_ops:
                self.n_shed += req.n_ops
                raise RejectedError(
                    f"admission queue full ({self._pending_ops} pending "
                    f"ops, cap {self.cfg.queue_cap_ops})")
            self._pending.append(req)
            self._pending_ops += req.n_ops
            self.n_accepted += req.n_ops
            self._nonempty.notify()
        return req

    @property
    def queue_depth_ops(self) -> int:
        return self._pending_ops

    def drain(self, timeout: float = 60.0) -> None:
        """Block until every accepted request has completed."""
        deadline = time.perf_counter() + timeout
        with self._idle:
            while self._pending or self._inflight:
                left = deadline - time.perf_counter()
                if left <= 0:
                    raise TimeoutError("batcher did not drain in time")
                self._idle.wait(left)

    def close(self) -> None:
        """Stop the worker after serving everything already accepted.
        Idempotent; the queue rejects new submits immediately."""
        with self._nonempty:
            if self._stop:
                return
            self._stop = True
            self._nonempty.notify_all()
        self._worker.join(timeout=60.0)

    def stats(self) -> dict:
        """Racy-but-safe counter sample (plain int reads)."""
        n_b = self.n_batches
        return dict(accepted_ops=self.n_accepted, shed_ops=self.n_shed,
                    completed_ops=self.n_completed,
                    failed_ops=self.n_failed,
                    shed_frac=self.n_shed
                    / max(self.n_accepted + self.n_shed, 1),
                    n_batches=n_b,
                    queue_depth_ops=self._pending_ops,
                    batch_ops_mean=(sum(self.batch_ops[:n_b]) / n_b
                                    if n_b else 0.0),
                    batch_target_ops=self.sizer.target,
                    journal_batches=(len(self.journal)
                                     if self.journal is not None else 0))

    # -- worker side ---------------------------------------------------------

    def _run(self) -> None:
        while True:
            with self._nonempty:
                while not self._pending and not self._stop:
                    self._nonempty.wait()
                if not self._pending:
                    return                          # stopped and drained
                # dwell: give the batch a bounded chance to fill toward
                # the AIMD target before dispatching a fragment
                if (self._pending_ops < self.sizer.target
                        and not self._stop and self.cfg.dwell_s > 0):
                    self._nonempty.wait(self.cfg.dwell_s)
                    if not self._pending:
                        continue
                depth_at_dispatch = self._pending_ops
                group = coalesce(self._pending, self.sizer.cap)
                n = sum(r.n_ops for r in group)
                self._pending_ops -= n
                self._inflight += n
            self._dispatch(group, n, depth_at_dispatch)
            with self._idle:
                self._inflight -= n
                if not self._pending and not self._inflight:
                    self._idle.notify_all()

    def _dispatch(self, group: list[Request], n: int,
                  depth_ops: int) -> None:
        tel = self.tel
        tracing = tel is not None and tel.enabled and tel.trace.enabled
        # the member requests' ids become the worker thread's trace
        # context: every span/event recorded while this batch executes —
        # serve.queue_wait/exec, the facade op, the WAL append, a merge
        # the batch triggers — links back to these requests
        with trace_context(tuple(r.trace_id for r in group) if tracing
                           else ()):
            self._dispatch_traced(group, n, depth_ops, tracing)

    def _dispatch_traced(self, group: list[Request], n: int,
                         depth_ops: int, tracing: bool) -> None:
        tel = self.tel
        t0 = time.perf_counter()
        if tel is not None and tel.enabled:
            tel.record_span("serve.queue_wait", t0 - group[0].t_submit)
            tel.metrics.gauge("serve.queue_depth_ops", depth_ops)
            tel.metrics.gauge("serve.batch_target_ops", self.sizer.target)
            # batch-size histogram: lanes recorded on the ms scale, i.e.
            # `serve.batch.ops` summary reads ms_* keys AS lane counts
            tel.metrics.observe("serve.batch.ops", n * 1e-3)
        try:
            self._execute(group)
            err = None
        except BaseException as e:          # noqa: BLE001 — fan the error
            err = e                         # out to every waiting client
        service_s = time.perf_counter() - t0
        if tel is not None and tel.enabled:
            tel.record_span("serve.exec", service_s, op=group[0].op,
                            n_ops=n, n_requests=len(group))
        self.n_batches += 1
        self.batch_ops.append(n)
        self.sizer.observe(depth_ops, service_s)
        t_done = time.perf_counter()
        for r in group:
            if err is not None and not r.done:
                # requests `_execute` already completed keep their result
                r._complete(error=err, t_done=t_done)
            if r.error is not None:
                self.n_failed += r.n_ops
            else:
                self.n_completed += r.n_ops
            if tel is not None and tel.enabled:
                tel.metrics.observe(f"serve.e2e.{r.op}",
                                    t_done - r.t_arrival)
                if tracing:
                    # the request's anchor slice: one per trace id, on the
                    # owning client's track; flow arrows start here
                    tel.trace.add(
                        "serve.request", t0=r.t_submit,
                        dur_s=(r.t_done or t_done) - r.t_submit,
                        track=f"client:{r.client_id or 'anon'}",
                        trace_ids=(r.trace_id,), anchor=True,
                        op=r.op, n_ops=r.n_ops,
                        ok=r.error is None)

    def _execute(self, group: list[Request]) -> None:
        """Run one coalesced facade batch and slice results back out.
        Commit order == execution order == journal order."""
        op = group[0].op
        ix = self.index
        t_done: float | None = None
        if op == "lookup":
            q = np.concatenate([r.keys for r in group])
            v, f = ix.lookup(q)
            self._journal(OpBatch("lookup", keys=q))
            t_done = time.perf_counter()
            i = 0
            for r in group:
                j = i + r.n_ops
                r._complete((v[i:j], f[i:j]), t_done=t_done)
                i = j
        elif op == "range":
            lo = np.concatenate([r.lo for r in group])
            hi = np.concatenate([r.hi for r in group])
            ks, vs, cnt = ix.range(lo, hi, max_hits=group[0].max_hits)
            self._journal(OpBatch("range", lo=lo, hi=hi))
            t_done = time.perf_counter()
            i = 0
            for r in group:
                j = i + r.n_ops
                r._complete((ks[i:j], vs[i:j], cnt[i:j]), t_done=t_done)
                i = j
        elif op == "upsert":
            keys = np.concatenate([r.keys for r in group])
            vals = np.concatenate([r.vals for r in group])
            # within-batch order = request order, so a later request's
            # write to the same key wins (overlay merge is last-write-wins
            # in array order — the same rule the oracle replay applies)
            ix.upsert(keys, vals)
            self._journal(OpBatch("upsert", keys=keys, vals=vals))
            t_done = time.perf_counter()
            for r in group:
                # the ack: WAL append (when armed) + overlay apply are done
                r._complete(t_done=t_done)
        else:                                        # delete
            keys = np.concatenate([r.keys for r in group])
            ix.delete(keys)
            self._journal(OpBatch("delete", keys=keys))
            t_done = time.perf_counter()
            for r in group:
                r._complete(t_done=t_done)

    def _journal(self, batch: OpBatch) -> None:
        if self.journal is not None:
            self.journal.append(batch)
