"""`ServeFrontend` + `ServeClient`: the serving surface over one
`repro.api.LearnedIndex` (DESIGN.md section 15).

The frontend owns a `RequestBatcher` (one worker thread, bounded
admission queue) and hands out lightweight per-client handles.  A client
handle is the unit of the ordering contract:

  * ops submitted through ONE client are enqueued in program order (the
    handle serializes its own submits), so the batcher's FIFO total
    order contains each client's program order as a subsequence;
  * a synchronous write returns only after the facade call returned —
    i.e. after the WAL append (when durability is armed) and the overlay
    apply — so the client's next read observes it: read-your-
    acknowledged-writes;
  * no ordering is promised BETWEEN clients beyond the single
    serialization the journal records.

Usage:

    with ServeFrontend(index) as fe:
        c = fe.client("tenant-a")
        c.upsert(keys, vals)            # acknowledged on return
        vals, found = c.lookup(keys)    # sees the upsert
    # fe.journal_batches() -> the exact committed interleaving,
    # replayable through WorkloadRunner for the oracle check
"""

from __future__ import annotations

import threading

from .batcher import (RejectedError, Request, RequestBatcher,  # noqa: F401
                      ServeConfig)

#: default client-blocking timeout — generous; a healthy batcher answers
#: in milliseconds, so hitting this means the serving loop is wedged
WAIT_S = 120.0


class ServeClient:
    """One logical client stream.  Sync methods block until the op is
    served (acknowledged); `*_async` return the `Request` future for
    open-loop load generation.  A handle may be driven by one thread at
    a time (the load generator gives each client thread its own)."""

    __slots__ = ("frontend", "client_id", "_lock")

    def __init__(self, frontend: "ServeFrontend", client_id: str):
        self.frontend = frontend
        self.client_id = client_id
        # serializes submits from this handle so the per-client program
        # order is well-defined even if a handle is shared across threads
        self._lock = threading.Lock()

    # -- async (open-loop) ----------------------------------------------------

    def submit(self, op: str, *, t_arrival: float | None = None,
               **payload) -> Request:
        # constructing the Request here is also where its causal trace id
        # is minted (Request.__init__) — one id per client submit, carried
        # through coalescing so `LearnedIndex.dump_trace` can draw the
        # request -> batch -> facade -> WAL -> merge chain
        req = Request(op, client_id=self.client_id,
                      max_hits=self.frontend.cfg.max_hits,
                      t_arrival=t_arrival, **payload)
        with self._lock:
            return self.frontend.batcher.submit(req)

    def lookup_async(self, keys, *, t_arrival=None) -> Request:
        return self.submit("lookup", keys=keys, t_arrival=t_arrival)

    def range_async(self, lo, hi, *, t_arrival=None) -> Request:
        return self.submit("range", lo=lo, hi=hi, t_arrival=t_arrival)

    def upsert_async(self, keys, vals, *, t_arrival=None) -> Request:
        return self.submit("upsert", keys=keys, vals=vals,
                           t_arrival=t_arrival)

    def delete_async(self, keys, *, t_arrival=None) -> Request:
        return self.submit("delete", keys=keys, t_arrival=t_arrival)

    # -- sync (acknowledged on return) ----------------------------------------

    def lookup(self, keys):
        return self.lookup_async(keys).wait(WAIT_S)

    def range(self, lo, hi):
        return self.range_async(lo, hi).wait(WAIT_S)

    def upsert(self, keys, vals) -> None:
        self.upsert_async(keys, vals).wait(WAIT_S)

    def delete(self, keys) -> None:
        self.delete_async(keys).wait(WAIT_S)

    def get(self, key) -> int | None:
        """Point read through the batched lookup path (facade-`get`
        shaped: value or None)."""
        vals, found = self.lookup([key])
        return int(vals[0]) if bool(found[0]) else None


class ServeFrontend:
    """Owns the batcher; hands out client handles; exports serve stats.

    The frontend is the index's ONLY caller while serving — clients go
    through `client()`, never touch the facade — which is how the
    engines' single-writer threading contract holds under N client
    threads."""

    def __init__(self, index, config: ServeConfig | None = None,
                 journal: bool = True):
        self.index = index
        self.cfg = config or ServeConfig()
        self.batcher = RequestBatcher(index, self.cfg, journal=journal)
        self._clients: dict[str, ServeClient] = {}
        self._clients_lock = threading.Lock()

    def client(self, client_id: str) -> ServeClient:
        with self._clients_lock:
            c = self._clients.get(client_id)
            if c is None:
                c = self._clients[client_id] = ServeClient(self, client_id)
            return c

    def journal_batches(self):
        """The committed facade batches in execution order (`OpBatch`
        list) — the deterministic interleaving.  Replaying it through
        `WorkloadRunner` on a fresh index with the same initial content
        must reproduce this run's final `items()` bit-exactly."""
        j = self.batcher.journal
        if j is None:
            raise RuntimeError("frontend built with journal=False")
        return list(j)

    def drain(self, timeout: float = WAIT_S) -> None:
        self.batcher.drain(timeout)

    def flush(self) -> dict:
        """Drain in-flight requests, then fold+republish the index (the
        sync/durability barrier).  Call between load legs, not during."""
        self.drain()
        return self.index.flush()

    def stats(self) -> dict:
        return self.batcher.stats()

    def close(self) -> None:
        self.batcher.close()

    def __enter__(self) -> "ServeFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
