"""Open-loop load generator + saturation search (DESIGN.md section 15).

Open loop means arrivals are scheduled by a clock, not by completions:
request i's arrival time is `t0 + (ops before i) / rate`, fixed up
front.  A client thread that falls behind (because the system is slow)
does NOT slow the schedule down — it submits late, and the request's
latency is still measured from the *scheduled* arrival.  This is the
standard guard against coordinated omission: a closed loop would let a
stalled server throttle its own load and report flattering tails.

Requests are dealt round-robin to `n_clients` client threads, each
driving its own `ServeClient` handle in schedule order.  Admission
rejections (`RejectedError`) count as shed ops — shed is a *result* (the
system refusing load), never an error.

`saturation_search` ramps the offered rate geometrically until the
system stops keeping up (achieved < keep_up_frac x offered, or shed
above tolerance) and returns the last sustained rate — the knee the
50%/80%/95% latency legs in `benchmarks/run.py --serve` hang off.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field

from ..obs import latency_summary
from .batcher import RejectedError, Request

#: request-payload field mapping per op (OpBatch -> submit kwargs)
_PAYLOAD = {
    "lookup": lambda b: dict(keys=b.keys),
    "upsert": lambda b: dict(keys=b.keys, vals=b.vals),
    "delete": lambda b: dict(keys=b.keys),
    "range": lambda b: dict(lo=b.lo, hi=b.hi),
}


@dataclass
class LoadReport:
    """One open-loop leg: offered vs achieved rate + e2e latency tails."""
    offered_ops_per_s: float
    n_clients: int
    n_reqs: int = 0
    n_ops: int = 0
    done_ops: int = 0
    shed_ops: int = 0
    failed_ops: int = 0
    wall_s: float = 0.0
    late_submits: int = 0          # reqs submitted > 1ms past schedule
    latency_s: dict = field(default_factory=dict)   # op -> [seconds]

    @property
    def achieved_ops_per_s(self) -> float:
        return self.done_ops / max(self.wall_s, 1e-12)

    @property
    def shed_frac(self) -> float:
        return self.shed_ops / max(self.n_ops, 1)

    def latency_ms(self) -> dict:
        """{op: p50/p95/p99/p999/max/mean ms end-to-end (scheduled
        arrival -> completion)} via the shared percentile recipe."""
        return {op: latency_summary(xs)
                for op, xs in sorted(self.latency_s.items())}

    def to_json_dict(self) -> dict:
        return dict(offered_ops_per_s=self.offered_ops_per_s,
                    achieved_ops_per_s=self.achieved_ops_per_s,
                    n_clients=self.n_clients, n_reqs=self.n_reqs,
                    n_ops=self.n_ops, done_ops=self.done_ops,
                    shed_ops=self.shed_ops, shed_frac=self.shed_frac,
                    failed_ops=self.failed_ops, wall_s=round(self.wall_s, 4),
                    late_submits=self.late_submits,
                    latency_ms=self.latency_ms())


def open_loop(frontend, batches, rate_ops_per_s: float,
              n_clients: int = 4, timeout_s: float = 120.0,
              trace_path: str | None = None) -> LoadReport:
    """Drive `batches` (each one request) through the frontend at a fixed
    offered rate from `n_clients` concurrent client threads.

    Returns after every accepted request completed (the batcher is
    drained) with per-op end-to-end latency samples measured from each
    request's SCHEDULED arrival.  Raises nothing on shed/failed requests
    — they are counted in the report.

    `trace_path` arms causal tracing for this leg (requires the index's
    telemetry to be enabled) and writes the Chrome-trace-event JSON there
    after the drain — open it in Perfetto to see each request's
    queue/exec/facade/WAL/merge chain."""
    tel = getattr(frontend.index, "telemetry", None)
    tracing = trace_path is not None and tel is not None and tel.enabled
    if tracing:
        tel.start_trace()
    report = LoadReport(offered_ops_per_s=float(rate_ops_per_s),
                        n_clients=n_clients)
    report.n_reqs = len(batches)
    # global open-loop schedule: request i arrives after the ops of all
    # earlier requests were offered at the target rate
    offsets, acc = [], 0.0
    for b in batches:
        offsets.append(acc / rate_ops_per_s)
        acc += b.n_ops
    report.n_ops = int(acc)
    lanes = [[] for _ in range(n_clients)]      # (batch, offset) per client
    for i, b in enumerate(batches):
        lanes[i % n_clients].append((b, offsets[i]))
    t0 = time.perf_counter()
    results: list[list[Request]] = [[] for _ in range(n_clients)]
    sheds = [0] * n_clients
    lates = [0] * n_clients

    def drive(ci: int) -> None:
        client = frontend.client(f"lg-{ci}")
        out, shed, late = results[ci], 0, 0
        for b, off in lanes[ci]:
            t_arr = t0 + off
            now = time.perf_counter()
            if t_arr > now:
                time.sleep(t_arr - now)
            elif now - t_arr > 1e-3:
                late += 1
            try:
                out.append(client.submit(b.op, t_arrival=t_arr,
                                         **_PAYLOAD[b.op](b)))
            except RejectedError:
                shed += b.n_ops
        sheds[ci], lates[ci] = shed, late

    threads = [threading.Thread(target=drive, args=(ci,), daemon=True,
                                name=f"loadgen-{ci}")
               for ci in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout_s)
    frontend.drain(timeout_s)
    report.wall_s = time.perf_counter() - t0
    if tracing:
        tel.trace.dump(trace_path)
        tel.stop_trace()
    report.shed_ops = sum(sheds)
    report.late_submits = sum(lates)
    for reqs in results:
        for r in reqs:
            if r.error is not None:
                report.failed_ops += r.n_ops
                continue
            report.done_ops += r.n_ops
            report.latency_s.setdefault(r.op, []).append(r.latency_s)
    return report


def saturation_search(frontend, make_batches, start_rate: float,
                      factor: float = 1.7, max_legs: int = 8,
                      n_clients: int = 4, keep_up_frac: float = 0.9,
                      shed_tol: float = 0.01,
                      timeout_s: float = 120.0) -> tuple[float, list]:
    """Geometric offered-rate ramp until the system stops keeping up.

    `make_batches(leg_index)` supplies a fresh request list per leg (legs
    mutate the index, so streams must continue, not repeat).  A leg
    "keeps up" when achieved >= keep_up_frac x offered AND shed_frac <=
    shed_tol.  Returns `(saturation_ops_per_s, leg_reports)` where
    saturation is the best *achieved* rate across legs — the classic
    open-loop throughput ceiling even when the last leg over-offered."""
    legs: list[LoadReport] = []
    rate = float(start_rate)
    for leg in range(max_legs):
        rep = open_loop(frontend, make_batches(leg), rate,
                        n_clients=n_clients, timeout_s=timeout_s)
        legs.append(rep)
        kept_up = (rep.achieved_ops_per_s >= keep_up_frac * rate
                   and rep.shed_frac <= shed_tol)
        if not kept_up:
            break
        rate *= factor
    saturation = max(l.achieved_ops_per_s for l in legs)
    return saturation, legs
