"""Serving-side session table: session-id -> KV-cache slot, through a DILI.

Since the api redesign this sits on the public facade
(`repro.api.LearnedIndex`): admissions are upserts, evictions are deletes,
reads are the engine's fused snapshot+overlay lookup, and the merge policy
decides when pending writes fold through the host tree (Alg. 7/8) and a
fresh epoch publishes — ONE `flatten()` per merge, never per admit/evict
(DESIGN.md sections 8-10).  The engine is a config choice; the default
local engine serves a session table fine, but a sharded deployment only
changes the `IndexConfig`.
"""

from __future__ import annotations

import threading

import numpy as np

from ..api import IndexConfig, LearnedIndex, MergePolicy


class SessionTable:
    """Thread-safe from any number of frontend threads: slot allocation
    and the admit/evict check-then-act pairs serialize on one RLock, so
    two concurrent admits of the same session id cannot both pass the
    duplicate check, and a slot is never handed out twice.  Index I/O
    goes either straight to the facade (standalone) or — after
    `serve_through(frontend)` — through a `ServeClient`, so session
    traffic coalesces with everything else the batcher serves."""

    def __init__(self, n_slots: int, warm_ids=None,
                 policy: MergePolicy | None = None,
                 config: IndexConfig | None = None):
        self.n_slots = n_slots
        self._lock = threading.RLock()
        self.free = list(range(n_slots))[::-1]
        warm = np.asarray(sorted(warm_ids or [1.0, 2.0]), np.float64)
        slots = np.array([self._take() for _ in warm], np.int64)
        if policy is not None and config is not None:
            raise ValueError("pass the merge policy inside `config` "
                             "(IndexConfig(merge=...)), not both")
        # small default buffer: a session table sees bursty admit/evict, so
        # merge on fill (64 pending) or 256 writes of lag
        cfg = config or IndexConfig(
            overlay_cap=64,
            merge=policy or MergePolicy(max_fill=1.0, max_writes=256))
        self.index = LearnedIndex.build(warm, slots, config=cfg)
        self._frontend = None
        self._io = self.index      # facade, or a ServeClient once served

    def serve_through(self, frontend) -> "SessionTable":
        """Route this table's index traffic through a serving front-end
        (`repro.serve.ServeFrontend` over the SAME index).  After this,
        admits/evicts/lookups are batcher requests — coalesced with
        other clients, admission-controlled, journaled — and the table
        may be driven from many threads."""
        if frontend.index is not self.index:
            raise ValueError("frontend serves a different index")
        with self._lock:
            self._frontend = frontend
            self._io = frontend.client("sessions")
        return self

    def _take(self) -> int:
        if not self.free:
            raise RuntimeError("no free KV slots")
        return self.free.pop()

    @property
    def publish_count(self) -> int:
        """flatten+upload count — one per merge epoch (acceptance metric)."""
        return self.index.n_flattens

    @property
    def dili(self):
        """The host writer (stats/introspection; may lag the overlay)."""
        return self.index.host

    def admit(self, session_id: float) -> int:
        # the whole check-take-write sequence holds the lock: a racing
        # admit of the same id must see either the KeyError or the slot,
        # never a double allocation (the upsert ack is the batcher's or
        # facade's business; both return only once the write is applied)
        sid = float(session_id)
        with self._lock:
            if self._io.get(sid) is not None:
                raise KeyError(f"session {session_id} already admitted")
            slot = self._take()
            self._io.upsert(sid, slot)
        return slot

    def evict(self, session_id: float) -> None:
        sid = float(session_id)
        with self._lock:
            slot = self._io.get(sid)
            if slot is None:
                raise KeyError(session_id)
            self._io.delete(sid)
            self.free.append(int(slot))

    def flush(self):
        """Force a merge+publish (e.g. before a latency-critical window);
        when served, drains in-flight requests first."""
        if self._frontend is not None:
            return self._frontend.flush()
        return self.index.flush()

    def lookup_batch(self, session_ids) -> tuple[np.ndarray, np.ndarray]:
        # lock-free: reads need no slot-allocation consistency
        return self._io.lookup(np.asarray(session_ids, np.float64))
