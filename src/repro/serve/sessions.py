"""Serving-side session table: session-id -> KV-cache slot, through a DILI.

Admission upserts and eviction tombstones go through the online-update
subsystem (`repro.online`): writes land in the tombstone overlay and the
merge policy decides when to fold them through the host DILI (Algorithms
7/8) and publish a fresh snapshot epoch — ONE `flatten()` per merge, never
per admit/evict.  The hot lookup path is the fused snapshot+overlay device
search (`core.search.search_with_overlay`): one jitted dispatch per query
batch, depth-exact with batch-convergence early exit, query buffer donated —
exact at every point between merges (DESIGN.md sections 8-9).
"""

from __future__ import annotations

import numpy as np

from ..online import MergePolicy, OnlineIndex


class SessionTable:
    def __init__(self, n_slots: int, warm_ids=None,
                 policy: MergePolicy | None = None):
        self.n_slots = n_slots
        self.free = list(range(n_slots))[::-1]
        warm = np.asarray(sorted(warm_ids or [1.0, 2.0]), np.float64)
        slots = np.array([self._take() for _ in warm], np.int64)
        # small default buffer: a session table sees bursty admit/evict, so
        # merge on fill (64 pending) or 256 writes of lag
        self.index = OnlineIndex(
            warm, slots, overlay_cap=64,
            policy=policy or MergePolicy(max_fill=1.0, max_writes=256))

    def _take(self) -> int:
        if not self.free:
            raise RuntimeError("no free KV slots")
        return self.free.pop()

    @property
    def publish_count(self) -> int:
        """flatten+upload count — one per merge epoch (acceptance metric)."""
        return self.index.n_flattens

    @property
    def dili(self):
        """The host writer (stats/introspection; may lag the overlay)."""
        return self.index.dili

    def admit(self, session_id: float) -> int:
        sid = float(session_id)
        if self.index.get(sid) is not None:
            raise KeyError(f"session {session_id} already admitted")
        slot = self._take()
        self.index.upsert(sid, slot)
        return slot

    def evict(self, session_id: float) -> None:
        sid = float(session_id)
        slot = self.index.get(sid)
        if slot is None:
            raise KeyError(session_id)
        self.index.delete(sid)
        self.free.append(int(slot))

    def flush(self):
        """Force a merge+publish (e.g. before a latency-critical window)."""
        return self.index.flush()

    def lookup_batch(self, session_ids) -> tuple[np.ndarray, np.ndarray]:
        return self.index.lookup(np.asarray(session_ids, np.float64))
