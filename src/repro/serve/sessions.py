"""Serving-side session table: session-id -> KV-cache slot, through a DILI.

Admission inserts (Algorithm 7), eviction deletes (Algorithm 8) — the
serving control path exercises the paper's update machinery; the hot lookup
path is the batched device search on the published snapshot.
"""

from __future__ import annotations

import numpy as np

from ..core import search as S
from ..core.dili import bulk_load
from ..core.flat import flatten


class SessionTable:
    def __init__(self, n_slots: int, warm_ids=None):
        self.n_slots = n_slots
        self.free = list(range(n_slots))[::-1]
        warm = np.asarray(sorted(warm_ids or [1.0, 2.0]), np.float64)
        slots = np.array([self._take() for _ in warm], np.int64)
        self.dili = bulk_load(warm, slots)
        self._publish()

    def _take(self) -> int:
        if not self.free:
            raise RuntimeError("no free KV slots")
        return self.free.pop()

    def _publish(self):
        self.flat = flatten(self.dili)
        self.idx = S.device_arrays(self.flat)

    def admit(self, session_id: float) -> int:
        slot = self._take()
        if not self.dili.insert(float(session_id), slot):
            self.free.append(slot)
            raise KeyError(f"session {session_id} already admitted")
        self._publish()
        return slot

    def evict(self, session_id: float) -> None:
        slot = self.dili.search(float(session_id))
        if slot is None:
            raise KeyError(session_id)
        self.dili.delete(float(session_id))
        self.free.append(int(slot))
        self._publish()

    def lookup_batch(self, session_ids) -> tuple[np.ndarray, np.ndarray]:
        import jax.numpy as jnp
        v, f = S.search_batch(self.idx,
                              jnp.asarray(session_ids, jnp.float64),
                              max_depth=self.flat.max_depth + 2)
        return np.asarray(v), np.asarray(f)
