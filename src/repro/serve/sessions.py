"""Serving-side session table: session-id -> KV-cache slot, through a DILI.

Since the api redesign this sits on the public facade
(`repro.api.LearnedIndex`): admissions are upserts, evictions are deletes,
reads are the engine's fused snapshot+overlay lookup, and the merge policy
decides when pending writes fold through the host tree (Alg. 7/8) and a
fresh epoch publishes — ONE `flatten()` per merge, never per admit/evict
(DESIGN.md sections 8-10).  The engine is a config choice; the default
local engine serves a session table fine, but a sharded deployment only
changes the `IndexConfig`.
"""

from __future__ import annotations

import numpy as np

from ..api import IndexConfig, LearnedIndex, MergePolicy


class SessionTable:
    def __init__(self, n_slots: int, warm_ids=None,
                 policy: MergePolicy | None = None,
                 config: IndexConfig | None = None):
        self.n_slots = n_slots
        self.free = list(range(n_slots))[::-1]
        warm = np.asarray(sorted(warm_ids or [1.0, 2.0]), np.float64)
        slots = np.array([self._take() for _ in warm], np.int64)
        if policy is not None and config is not None:
            raise ValueError("pass the merge policy inside `config` "
                             "(IndexConfig(merge=...)), not both")
        # small default buffer: a session table sees bursty admit/evict, so
        # merge on fill (64 pending) or 256 writes of lag
        cfg = config or IndexConfig(
            overlay_cap=64,
            merge=policy or MergePolicy(max_fill=1.0, max_writes=256))
        self.index = LearnedIndex.build(warm, slots, config=cfg)

    def _take(self) -> int:
        if not self.free:
            raise RuntimeError("no free KV slots")
        return self.free.pop()

    @property
    def publish_count(self) -> int:
        """flatten+upload count — one per merge epoch (acceptance metric)."""
        return self.index.n_flattens

    @property
    def dili(self):
        """The host writer (stats/introspection; may lag the overlay)."""
        return self.index.host

    def admit(self, session_id: float) -> int:
        sid = float(session_id)
        if self.index.get(sid) is not None:
            raise KeyError(f"session {session_id} already admitted")
        slot = self._take()
        self.index.upsert(sid, slot)
        return slot

    def evict(self, session_id: float) -> None:
        sid = float(session_id)
        slot = self.index.get(sid)
        if slot is None:
            raise KeyError(session_id)
        self.index.delete(sid)
        self.free.append(int(slot))

    def flush(self):
        """Force a merge+publish (e.g. before a latency-critical window)."""
        return self.index.flush()

    def lookup_batch(self, session_ids) -> tuple[np.ndarray, np.ndarray]:
        return self.index.lookup(np.asarray(session_ids, np.float64))
