"""Optimizers (AdamW, Adafactor) as minimal pure-JAX (init, update) pairs.

Adafactor's factored second moment keeps optimizer state O(d) instead of
O(d^2-ish), which is what lets the 104B/314B configs fit a v5e-256 pod with
FSDP (DESIGN.md section 5); AdamW is the default for <= 14B.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable      # (grads, state, params) -> (new_params, new_state)


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(tree, max_norm):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        tree), n


def adamw(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.01,
          clip_norm=1.0, schedule=None):
    lr_fn = schedule or (lambda s: lr)

    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return dict(mu=zeros,
                    nu=jax.tree.map(jnp.zeros_like, zeros),
                    step=jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        step = state["step"] + 1
        grads, gn = clip_by_global_norm(grads, clip_norm)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["nu"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = lr_fn(step)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)
        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, dict(mu=mu, nu=nu, step=step), dict(grad_norm=gn)

    return Optimizer(init, update)


def adafactor(lr=1e-2, decay=0.8, eps=1e-30, clip_norm=1.0,
              weight_decay=0.0, schedule=None, min_dim_factored=128):
    """Factored second-moment optimizer (Shazeer & Stern 2018), simplified."""
    lr_fn = schedule or (lambda s: lr)

    def _factored(shape):
        return len(shape) >= 2 and shape[-1] >= min_dim_factored and \
            shape[-2] >= min_dim_factored

    def init(params):
        def one(p):
            if _factored(p.shape):
                return dict(
                    vr=jnp.zeros(p.shape[:-1], jnp.float32),
                    vc=jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))
            return dict(v=jnp.zeros_like(p, jnp.float32))
        return dict(v=jax.tree.map(one, params,
                                   is_leaf=lambda x: hasattr(x, "shape")),
                    step=jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        step = state["step"] + 1
        grads, gn = clip_by_global_norm(grads, clip_norm)
        beta = 1.0 - (step.astype(jnp.float32) + 1) ** (-decay)
        lr_t = lr_fn(step)

        def one(p, g, v):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if "vr" in v:
                vr = beta * v["vr"] + (1 - beta) * g2.mean(-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(-1)[..., None, None], eps))
                u = g * jax.lax.rsqrt(denom + eps)
                nv = dict(vr=vr, vc=vc)
            else:
                nv = dict(v=beta * v["v"] + (1 - beta) * g2)
                u = g * jax.lax.rsqrt(nv["v"] + eps)
            # update clipping (RMS <= 1)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), nv

        flat_p, tdef = jax.tree_util.tree_flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        new = [one(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        new_params = tdef.unflatten([n[0] for n in new])
        new_v = tdef.unflatten([n[1] for n in new])
        return new_params, dict(v=new_v, step=step), dict(grad_norm=gn)

    return Optimizer(init, update)


def get_optimizer(name: str, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise ValueError(name)
