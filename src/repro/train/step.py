"""Train/serve step builders: grad accumulation, optimizer application,
serve prefill/decode.  Pure functions of (state, batch) suitable for pjit."""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..models import model as MDL
from ..models.config import ModelConfig
from .optim import Optimizer


def init_state(rng, cfg: ModelConfig, opt: Optimizer):
    params = MDL.init_params(rng, cfg)
    return dict(params=params, opt=opt.init(params),
                step=jnp.zeros((), jnp.int32))


def state_shape(cfg: ModelConfig, opt: Optimizer):
    return jax.eval_shape(lambda: init_state(jax.random.PRNGKey(0), cfg, opt))


def make_train_step(cfg: ModelConfig, opt: Optimizer):
    """batch: dict(tokens, labels[, extra_embeds, enc_frames]).
    With cfg.accum_steps > 1 the arrays carry a leading accumulation dim."""

    def loss_for(params, mb):
        return MDL.loss_fn(params, cfg, mb["tokens"], mb["labels"],
                           extra_embeds=mb.get("extra_embeds"),
                           enc_frames=mb.get("enc_frames"))

    def train_step(state, batch):
        params = state["params"]
        if cfg.accum_steps > 1:
            def micro(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_for)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (g, loss), _ = jax.lax.scan(micro, (g0, jnp.float32(0.0)), batch)
            inv = 1.0 / cfg.accum_steps
            g = jax.tree.map(lambda x: x * inv, g)
            loss = loss * inv
        else:
            loss, g = jax.value_and_grad(loss_for)(params, batch)
        new_params, new_opt, metrics = opt.update(g, state["opt"], params)
        new_state = dict(params=new_params, opt=new_opt,
                         step=state["step"] + 1)
        return new_state, dict(loss=loss, **metrics)

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch, cache):
        return MDL.prefill(params, cfg, batch["tokens"], cache,
                           extra_embeds=batch.get("extra_embeds"),
                           enc_frames=batch.get("enc_frames"))
    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def serve_step(params, token, cache):
        logits, cache = MDL.decode_step(params, cfg, token, cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1)[:, None] \
            .astype(jnp.int32)
        return next_tok, logits, cache
    return serve_step
