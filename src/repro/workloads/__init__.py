"""YCSB-style workload engine + differential oracle (DESIGN.md section 11).

Three pieces, composable but separable:

  * `generator`     — seeded, replayable op streams (`WorkloadSpec`,
                      `OpBatch`, `generate_stream`, `PRESETS`:
                      ycsb_a/b/c/e + dili_paper) over configurable
                      key-popularity distributions.
  * `oracle`        — `SortedOracle`, the ground-truth sorted-array model
                      speaking the facade's exact output shapes.
  * `runner`        — `WorkloadRunner` / `run_preset`, replaying a stream
                      through any `repro.api.LearnedIndex` engine with
                      per-batch oracle diffing and off-the-clock checking.
"""

from .distributions import DISTRIBUTIONS, sample_indices
from .generator import (OPS, PRESETS, OpBatch, WorkloadSpec,
                        generate_stream, stream_op_counts)
from .oracle import SortedOracle
from .runner import (WorkloadDivergence, WorkloadReport, WorkloadRunner,
                     run_preset)

__all__ = [
    "DISTRIBUTIONS", "OPS", "PRESETS", "OpBatch", "SortedOracle",
    "WorkloadDivergence", "WorkloadReport", "WorkloadRunner",
    "WorkloadSpec", "generate_stream", "run_preset", "sample_indices",
    "stream_op_counts",
]
