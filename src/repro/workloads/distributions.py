"""Key-popularity distributions for the workload generator.

Every sampler answers one question — "which of the n currently-live keys
does this operation touch?" — by returning *indices into a popularity
ordering* of the live set.  The generator owns the mapping from those
indices to actual keys (hashed scatter for uniform/zipfian/hotspot,
recency order for latest), so the samplers stay pure: (rng, n, B) -> idx.

  * uniform — every live key equally likely (YCSB default request
    distribution for load phases).
  * zipfian — rank-frequency skew with parameter theta (YCSB's
    ZipfianGenerator, Gray et al. "Quickly Generating Billion-Record
    Synthetic Databases"): rank r is drawn in O(1) from the closed-form
    inverse CDF, no O(n) table per batch.  The harmonic normalizer
    zeta(n, theta) is memoized incrementally, so growing live sets only
    pay for the new terms.
  * latest — zipfian over recency ranks (rank 0 = newest key), YCSB's
    "latest" request distribution for feeds/timelines.
  * hotspot — a hot_frac fraction of the key space receives hot_weight
    of the traffic (YCSB hotspot), uniform within each side.

All sampling is vectorized and driven by a caller-owned
`np.random.Generator`, so a stream is exactly replayable from its seed.
"""

from __future__ import annotations

import numpy as np

DISTRIBUTIONS = ("uniform", "zipfian", "latest", "hotspot")

# YCSB's default zipfian constant: ~80% of accesses hit ~20% of keys.
DEFAULT_THETA = 0.99


class ZetaCache:
    """Incrementally-extended harmonic sums zeta(n, theta) = sum 1/i^theta.

    The live-set size n changes as the workload inserts and deletes, and
    zipfian sampling needs zeta(n) for the current n; recomputing the sum
    per batch would be O(n).  We keep the full prefix array so any n seen
    so far (including shrinks) is O(1), and growth appends only the new
    terms."""

    def __init__(self, theta: float):
        self.theta = float(theta)
        self._prefix = np.zeros(1)          # prefix[i] = zeta(i, theta)

    def __call__(self, n: int) -> float:
        if n >= len(self._prefix):
            i = np.arange(len(self._prefix), n + 1, dtype=np.float64)
            new = np.cumsum(i ** -self.theta) + self._prefix[-1]
            self._prefix = np.concatenate([self._prefix, new])
        return float(self._prefix[n])


def zipfian_ranks(rng: np.random.Generator, n: int, size: int,
                  theta: float, zeta: ZetaCache) -> np.ndarray:
    """Draw `size` ranks in [0, n) with P(rank=r) proportional to
    1/(r+1)^theta — the YCSB ZipfianGenerator recurrence, vectorized."""
    if n <= 1:
        return np.zeros(size, np.int64)
    zetan = zeta(n)
    alpha = 1.0 / (1.0 - theta)
    eta = ((1.0 - (2.0 / n) ** (1.0 - theta))
           / (1.0 - zeta(2) / zetan))
    u = rng.random(size)
    uz = u * zetan
    ranks = (n * (eta * u - eta + 1.0) ** alpha).astype(np.int64)
    ranks = np.where(uz < 1.0, 0, np.where(uz < 1.0 + 0.5 ** theta, 1,
                                           ranks))
    return np.clip(ranks, 0, n - 1)


def sample_indices(rng: np.random.Generator, dist: str, n: int, size: int,
                   *, theta: float = DEFAULT_THETA,
                   hot_frac: float = 0.2, hot_weight: float = 0.8,
                   zeta: ZetaCache | None = None) -> np.ndarray:
    """Popularity-rank indices in [0, n) for `size` operations."""
    if n <= 0:
        raise ValueError("cannot sample from an empty live set")
    if dist == "uniform":
        return rng.integers(0, n, size)
    if dist in ("zipfian", "latest"):
        # "latest" is zipfian over recency ranks; the generator maps rank 0
        # to the newest key instead of a hashed position
        return zipfian_ranks(rng, n, size, theta,
                             zeta if zeta is not None else ZetaCache(theta))
    if dist == "hotspot":
        n_hot = max(1, int(np.ceil(hot_frac * n)))
        hot = rng.random(size) < hot_weight
        idx = rng.integers(0, max(n - n_hot, 1), size) + n_hot
        idx[hot] = rng.integers(0, n_hot, int(hot.sum()))
        return np.clip(idx, 0, n - 1)
    raise ValueError(f"unknown distribution {dist!r}; "
                     f"expected one of {DISTRIBUTIONS}")


def scatter_ranks(ranks: np.ndarray, n: int) -> np.ndarray:
    """Map popularity ranks to positions in the live-key array with a
    multiplicative hash (Knuth's 2654435761), YCSB's scrambled-zipfian
    idea: hot keys are spread across the key space instead of clustering
    at one end, so skew stresses the whole tree, not one subtree."""
    if n <= 0:
        return ranks
    return (ranks.astype(np.uint64) * np.uint64(2654435761)
            % np.uint64(n)).astype(np.int64)
