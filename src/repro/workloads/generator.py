"""Deterministic YCSB-style op-stream generator.

A `WorkloadSpec` names a mix (lookup / upsert / delete / range fractions),
a key-popularity distribution, and sizing; `generate_stream(spec, keys)`
expands it into a concrete list of `OpBatch`es — plain numpy arrays, no
index state — that any consumer (the differential `WorkloadRunner`, a
benchmark loop, a soak test) can replay byte-identically from the spec's
seed.

The generator tracks its own model of the live key set (loaded keys plus
its inserts minus its deletes) so op targets stay meaningful as the stream
mutates the index: lookups mostly hit live keys (a `miss_frac` slice
deliberately probes deleted/never-inserted keys), deletes always name live
keys, inserts draw fresh keys from a disjoint pool, and range scans start
at live keys.  Popularity is applied over that live set per the spec's
distribution (see `distributions`).

Named presets mirror the standard YCSB core workloads plus the paper's
read-heavy evaluation point:

  ycsb_a      50% lookup / 50% upsert-update, zipfian   (session store)
  ycsb_b      95% lookup /  5% upsert-update, zipfian   (photo tagging)
  ycsb_c     100% lookup,                     zipfian   (profile cache)
  ycsb_e      95% range  /  5% insert,        zipfian   (threaded feed)
  dili_paper  85% lookup / 5% upsert / 5% delete / 5% range, uniform —
              the read-heavy mixed point the DILI paper evaluates
              (Fig. 7/8: read-heavy with inserts AND deletes).
  shift_fb_logn  write-heavy with a mid-stream key-distribution shift:
              the first half inserts uniform fresh keys over the loaded
              range ("fb"-like), the second half draws from a disjoint
              lognormal-gap cluster beyond it ("logn"-like) while lookups
              chase the newest keys — the Fig. 9b/10 drift scenario as a
              replayable stream (exercises drift-triggered retrains).
  ttl_storm   insert waves followed by correlated delete storms: a
              deterministic wave schedule (wave_len) cycles upsert-only
              batches then delete batches whose victims are the OLDEST
              live keys (TTL expiry), stressing tombstone-density
              compaction and merge/publish latency.

Keys are integer-valued floats: exactly representable in f64 and — when
the universe stays below 2^24 — in f32 too, so one stream can drive the
pallas engine and a float oracle with zero quantization divergence
(the engine-equivalence convention, tests/test_api_engines.py).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np

from .distributions import (DEFAULT_THETA, DISTRIBUTIONS, ZetaCache,
                            sample_indices, scatter_ranks)

OPS = ("lookup", "upsert", "delete", "range")


@dataclass(frozen=True)
class OpBatch:
    """One batch of homogeneous operations (replayed engine-batch-wise).

    op == "lookup": `keys` are the point queries.
    op == "upsert": `keys`/`vals` are the written pairs (inserts and
                    updates).
    op == "delete": `keys` name the victims (live at generation time).
    op == "range":  `lo`/`hi` are per-query [lo, hi) bounds.
    """
    op: str
    keys: np.ndarray | None = None
    vals: np.ndarray | None = None
    lo: np.ndarray | None = None
    hi: np.ndarray | None = None

    @property
    def n_ops(self) -> int:
        if self.op == "range":
            return len(self.lo)
        return len(self.keys)


@dataclass(frozen=True)
class WorkloadSpec:
    """A named, seeded, replayable workload definition.

    Mix fractions pick each *batch*'s op type (batches are homogeneous so
    the runner can drive engines with their natural batched calls); they
    must sum to 1.  `insert_frac` splits upsert batches between fresh-key
    inserts and updates of existing keys.  `miss_frac` of lookup lanes
    probe keys guaranteed absent (deleted or never inserted).  `scan_len`
    bounds the rank-span of range scans; `max_hits` is the per-query range
    window the runner requests (both sides of the diff truncate at it).

    Scenario shaping (PR 5):
      * `shift_frac` > 0 shifts the insert-key distribution mid-stream:
        after that fraction of batches, fresh keys come from a disjoint
        lognormal-gap cluster beyond the loaded range instead of the
        uniform odd-integer pool (fb -> logn drift).
      * `delete_policy` — "popular" samples victims by the spec's
        distribution; "oldest" expires the oldest live keys (TTL).
      * `wave_len` > 0 replaces the per-batch random op draw with a
        deterministic cycle of `wave_len` batches apportioned by the mix
        (insert waves, then delete storms — correlated, not interleaved).
    """
    name: str = "custom"
    n_ops: int = 10000
    batch_size: int = 256
    lookup: float = 1.0
    upsert: float = 0.0
    delete: float = 0.0
    range_: float = 0.0
    distribution: str = "zipfian"
    theta: float = DEFAULT_THETA
    hot_frac: float = 0.2
    hot_weight: float = 0.8
    insert_frac: float = 0.0
    miss_frac: float = 0.05
    scan_len: int = 100
    max_hits: int = 64
    shift_frac: float = 0.0
    delete_policy: str = "popular"
    wave_len: int = 0
    seed: int = 0

    def __post_init__(self):
        if self.distribution not in DISTRIBUTIONS:
            raise ValueError(f"unknown distribution {self.distribution!r}; "
                             f"expected one of {DISTRIBUTIONS}")
        total = self.lookup + self.upsert + self.delete + self.range_
        if not np.isclose(total, 1.0):
            raise ValueError(f"mix fractions must sum to 1, got {total}")
        if self.n_ops < 1 or self.batch_size < 1:
            raise ValueError("n_ops and batch_size must be >= 1")
        if self.delete_policy not in ("popular", "oldest"):
            raise ValueError(f"unknown delete_policy "
                             f"{self.delete_policy!r}")
        if not 0.0 <= self.shift_frac < 1.0:
            raise ValueError("shift_frac must be in [0, 1)")
        if self.wave_len < 0:
            raise ValueError("wave_len must be >= 0")

    @property
    def mix(self) -> np.ndarray:
        return np.array([self.lookup, self.upsert, self.delete, self.range_])

    def scaled(self, n_ops: int | None = None,
               batch_size: int | None = None,
               seed: int | None = None) -> "WorkloadSpec":
        """The same workload at a different size/seed (presets are resized
        per consumer: CI smoke vs full bench vs tier-1 grid)."""
        return replace(self,
                       n_ops=self.n_ops if n_ops is None else n_ops,
                       batch_size=(self.batch_size if batch_size is None
                                   else batch_size),
                       seed=self.seed if seed is None else seed)


PRESETS: dict[str, WorkloadSpec] = {
    "ycsb_a": WorkloadSpec(name="ycsb_a", lookup=0.5, upsert=0.5,
                           distribution="zipfian"),
    "ycsb_b": WorkloadSpec(name="ycsb_b", lookup=0.95, upsert=0.05,
                           distribution="zipfian"),
    "ycsb_c": WorkloadSpec(name="ycsb_c", lookup=1.0,
                           distribution="zipfian"),
    "ycsb_e": WorkloadSpec(name="ycsb_e", lookup=0.0, range_=0.95,
                           upsert=0.05, insert_frac=1.0,
                           distribution="zipfian"),
    "dili_paper": WorkloadSpec(name="dili_paper", lookup=0.85, upsert=0.05,
                               delete=0.05, range_=0.05, insert_frac=0.5,
                               distribution="uniform"),
    "shift_fb_logn": WorkloadSpec(name="shift_fb_logn", lookup=0.4,
                                  upsert=0.5, delete=0.05, range_=0.05,
                                  insert_frac=0.8, distribution="latest",
                                  shift_frac=0.5, miss_frac=0.02),
    "ttl_storm": WorkloadSpec(name="ttl_storm", lookup=0.2, upsert=0.5,
                              delete=0.3, insert_frac=1.0,
                              distribution="uniform",
                              delete_policy="oldest", wave_len=10),
}


class _LiveSet:
    """The generator's model of the index content: a sorted key array for
    range endpoints/delete routing plus a recency array for the `latest`
    distribution.  O(n) per mutated batch — generation-time only, never on
    the serving path."""

    def __init__(self, keys: np.ndarray):
        self.sorted = np.sort(np.asarray(keys, np.float64))
        self.by_age = self.sorted.copy()        # loaded keys: age order
        self.dead: list[float] = []             # recently deleted (for
                                                # deliberate miss probes)

    def __len__(self) -> int:
        return len(self.sorted)

    def insert(self, keys: np.ndarray) -> None:
        self.sorted = np.union1d(self.sorted, keys)
        self.by_age = np.concatenate([self.by_age, keys])

    def delete(self, keys: np.ndarray) -> None:
        keys = np.unique(keys)
        self.sorted = self.sorted[~np.isin(self.sorted, keys)]
        self.by_age = self.by_age[~np.isin(self.by_age, keys)]
        self.dead.extend(keys.tolist())
        self.dead = self.dead[-4096:]           # bounded miss pool


def generate_stream(spec: WorkloadSpec, loaded_keys: np.ndarray,
                    insert_pool: np.ndarray | None = None,
                    val_base: int = 1_000_000) -> list[OpBatch]:
    """Expand `spec` into a replayable list of `OpBatch`es over an index
    bulk-loaded with `loaded_keys`.

    `insert_pool` supplies fresh keys for insert-flavored upserts, in pop
    order; it must be disjoint from `loaded_keys` (default: the odd
    integers between the loaded keys' min and beyond their max — with the
    even-integer universe convention the two never collide).  Values are a
    deterministic running sequence from `val_base`, so every written pair
    is attributable to its op position when a diff fires.

    The realized op count can fall marginally short of `spec.n_ops`:
    delete batches dedupe their victims (skewed sampling repeats keys, and
    a batch of deletes of one key is one delete), so consumers should
    treat `n_ops` as a target, not an exact invariant.
    """
    loaded_keys = np.asarray(loaded_keys, np.float64)
    if len(loaded_keys) < 2:
        raise ValueError("need >= 2 loaded keys to shape a workload")
    if insert_pool is None:
        lo = int(loaded_keys.min())
        insert_pool = np.arange(lo | 1, int(loaded_keys.max()) + 2 * spec.n_ops,
                                2, dtype=np.float64)
        insert_pool = insert_pool[~np.isin(insert_pool, loaded_keys)]
    else:
        insert_pool = np.asarray(insert_pool, np.float64)

    rng = np.random.default_rng(spec.seed)
    zeta = ZetaCache(spec.theta)
    live = _LiveSet(loaded_keys)
    batches: list[OpBatch] = []
    n_batches = max(1, -(-spec.n_ops // spec.batch_size))
    ops_left = spec.n_ops
    pool_i = 0
    val_seq = val_base

    # mid-stream distribution shift: after `shift_frac` of the batches,
    # fresh keys come from a disjoint odd-integer cluster beyond the
    # phase-1 pool, with lognormal gaps (the "logn" key shape) — still
    # integer-valued, so the f32 bit-exactness convention holds
    shift_at = (int(round(n_batches * spec.shift_frac))
                if spec.shift_frac > 0 else n_batches + 1)
    if spec.shift_frac > 0:
        base = (int(insert_pool.max()) if len(insert_pool)
                else int(loaded_keys.max()) + 2 * spec.n_ops) + 1 | 1
        gaps = np.maximum(rng.lognormal(0.0, 1.0, spec.n_ops), 1.0)
        shift_pool = base + 2 * np.cumsum(gaps.astype(np.int64))
        shift_pool = shift_pool.astype(np.float64)
        shift_pool = shift_pool[~np.isin(shift_pool, loaded_keys)]
    else:
        shift_pool = np.zeros(0, np.float64)
    shift_i = 0

    # deterministic wave schedule: `wave_len` batches per cycle,
    # apportioned by the mix in OPS order (upsert waves before the
    # correlated delete storm), every nonzero op class represented
    wave: list[str] = []
    if spec.wave_len:
        counts = np.floor(spec.mix * spec.wave_len).astype(int)
        counts[(spec.mix > 0) & (counts == 0)] = 1
        for op_name, c in zip(OPS, counts):
            wave += [op_name] * int(c)

    def pick_keys(size: int) -> np.ndarray:
        """Distribution-weighted live keys for this batch."""
        n = len(live)
        ranks = sample_indices(rng, spec.distribution, n, size,
                               theta=spec.theta, hot_frac=spec.hot_frac,
                               hot_weight=spec.hot_weight, zeta=zeta)
        if spec.distribution == "latest":
            # rank 0 = newest
            return live.by_age[len(live.by_age) - 1 - ranks]
        return live.sorted[scatter_ranks(ranks, n)]

    for b_i in range(n_batches):
        B = min(spec.batch_size, ops_left)
        ops_left -= B
        shifted = b_i >= shift_at
        op = (wave[b_i % len(wave)] if wave
              else OPS[rng.choice(4, p=spec.mix)])
        if op == "lookup":
            q = pick_keys(B)
            n_miss = int(round(B * spec.miss_frac))
            if n_miss:
                # absent keys: recently deleted first, else unseen pool keys
                pool = np.asarray(live.dead[-n_miss:], np.float64)
                if len(pool) < n_miss:
                    cur_pool, cur_i = ((shift_pool, shift_i) if shifted
                                       else (insert_pool, pool_i))
                    extra = cur_pool[cur_i: cur_i + (n_miss - len(pool))]
                    pool = np.concatenate([pool, extra])
                if len(pool):
                    q[rng.integers(0, B, len(pool))] = pool
            batches.append(OpBatch("lookup", keys=q))
        elif op == "upsert":
            n_new = int(round(B * spec.insert_frac))
            if shifted:
                n_new = min(n_new, len(shift_pool) - shift_i)
                new = shift_pool[shift_i: shift_i + n_new]
                shift_i += n_new
            else:
                n_new = min(n_new, len(insert_pool) - pool_i)
                new = insert_pool[pool_i: pool_i + n_new]
                pool_i += n_new
            upd = pick_keys(B - n_new)
            keys = np.concatenate([new, upd])
            vals = np.arange(val_seq, val_seq + len(keys), dtype=np.int64)
            val_seq += len(keys)
            batches.append(OpBatch("upsert", keys=keys, vals=vals))
            if n_new:
                live.insert(new)
        elif op == "delete":
            # never drain the live set below a floor: a workload that
            # deletes everything stops being a workload
            B_d = min(B, max(len(live) - 64, 0))
            if B_d == 0:
                batches.append(OpBatch("lookup", keys=pick_keys(B)))
                continue
            if spec.delete_policy == "oldest":     # TTL expiry order
                victims = np.unique(live.by_age[:B_d])
            else:
                victims = np.unique(pick_keys(B_d))
            batches.append(OpBatch("delete", keys=victims))
            live.delete(victims)
        else:                                    # range
            starts = pick_keys(B)
            spans = rng.integers(1, spec.scan_len + 1, B)
            pos = np.searchsorted(live.sorted, starts)
            end = np.minimum(pos + spans, len(live) - 1)
            # integer-valued keys: +1 makes the last rank inclusive under
            # the facade's half-open [lo, hi) contract
            batches.append(OpBatch("range", lo=starts,
                                   hi=live.sorted[end] + 1.0))
    return batches


def stream_op_counts(batches: list[OpBatch]) -> dict:
    out = {op: 0 for op in OPS}
    for b in batches:
        out[b.op] += b.n_ops
    return out
