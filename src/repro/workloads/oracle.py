"""Ground-truth oracle for differential workload checking.

`SortedOracle` is the simplest possible correct implementation of the
`LearnedIndex` read contract — a sorted key array plus a parallel value
array, mutated with numpy set operations — so any disagreement between it
and an engine is an engine bug (or a quantization-contract violation; see
the integer-key convention in `generator`).  Its `lookup` and `range`
return exactly the facade's shapes and padding conventions
(vals int64 / found bool; range keys +inf-padded, vals -1-padded, counts
int32 saturating at max_hits), so diffs are `np.testing.assert_array_equal`
— no tolerance knobs to hide bugs behind.
"""

from __future__ import annotations

import numpy as np

from ..core.flat import merge_sorted_runs


class SortedOracle:
    """Reference model: the exact logical content of the index."""

    def __init__(self, keys, vals=None):
        keys = np.atleast_1d(np.asarray(keys, np.float64))
        if vals is None:
            vals = np.arange(len(keys), dtype=np.int64)
        vals = np.atleast_1d(np.asarray(vals, np.int64))
        order = np.argsort(keys, kind="stable")
        keys, vals = keys[order], vals[order]
        keep = np.ones(len(keys), bool)
        keep[:-1] = keys[:-1] != keys[1:]       # last-write-wins, like build
        self.keys = keys[keep]
        self.vals = vals[keep]

    # -- writes --------------------------------------------------------------

    def upsert(self, keys, vals) -> None:
        keys = np.atleast_1d(np.asarray(keys, np.float64))
        vals = np.atleast_1d(np.asarray(vals, np.int64))
        mk, (mv,) = merge_sorted_runs(self.keys, (self.vals,),
                                      keys, (vals,))
        self.keys, self.vals = mk, mv

    def delete(self, keys) -> None:
        keys = np.atleast_1d(np.asarray(keys, np.float64))
        keep = ~np.isin(self.keys, keys)
        self.keys, self.vals = self.keys[keep], self.vals[keep]

    # -- reads (facade-shaped) ----------------------------------------------

    def lookup(self, queries) -> tuple[np.ndarray, np.ndarray]:
        q = np.atleast_1d(np.asarray(queries, np.float64))
        if len(self.keys) == 0:
            return np.full(len(q), -1, np.int64), np.zeros(len(q), bool)
        i = np.clip(np.searchsorted(self.keys, q), 0, len(self.keys) - 1)
        found = self.keys[i] == q
        vals = np.where(found, self.vals[i], -1)
        return vals.astype(np.int64), np.asarray(found, bool)

    def range(self, lo, hi, max_hits: int
              ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        lo = np.atleast_1d(np.asarray(lo, np.float64))
        hi = np.atleast_1d(np.asarray(hi, np.float64))
        q_n = len(lo)
        out_k = np.full((q_n, max_hits), np.inf)
        out_v = np.full((q_n, max_hits), -1, np.int64)
        out_c = np.zeros(q_n, np.int32)
        starts = np.searchsorted(self.keys, lo, side="left")
        ends = np.searchsorted(self.keys, hi, side="left")
        for i in range(q_n):
            c = min(int(ends[i] - starts[i]), max_hits)
            out_k[i, :c] = self.keys[starts[i]: starts[i] + c]
            out_v[i, :c] = self.vals[starts[i]: starts[i] + c]
            out_c[i] = c
        return out_k, out_v, out_c

    def items(self) -> tuple[np.ndarray, np.ndarray]:
        return self.keys.copy(), self.vals.copy()

    def __len__(self) -> int:
        return len(self.keys)
