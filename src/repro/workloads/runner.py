"""`WorkloadRunner`: drive any `repro.api.LearnedIndex` engine through an
op stream while diffing every batch against the `SortedOracle`.

The runner is the differential half of the workload subsystem: the
generator says *what* happens, the oracle says what the answers *must* be,
and the runner replays the stream engine-batch-wise, checking

  * lookup hits AND misses (found masks bit-equal, values equal on hits),
  * range windows (keys/vals/counts bit-equal including padding),
  * write visibility (every upsert batch is immediately readable with its
    new values, every delete batch immediately invisible — the overlay
    path, not just post-merge state),
  * final content (`items()` equals the oracle after the whole stream).

Timing covers only the engine calls (oracle bookkeeping and diffing run
off the clock), so the same replay that proves correctness also yields the
mixed-workload throughput numbers `benchmarks/run.py --workload` records.

A divergence raises `WorkloadDivergence` by default (CI-friendly: a broken
engine fails the job); pass strict=False to collect divergence messages
into the report instead, e.g. to assert that an injected fault IS caught.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

from ..obs import latency_summary
from .generator import OPS, OpBatch, WorkloadSpec, stream_op_counts
from .oracle import SortedOracle


class WorkloadDivergence(AssertionError):
    """An engine answered differently from the ground-truth oracle."""


@dataclass
class WorkloadReport:
    """Outcome of one stream replay: throughput + divergence record."""
    name: str
    engine: str
    n_ops: int = 0
    n_batches: int = 0
    op_counts: dict = field(default_factory=lambda: {o: 0 for o in OPS})
    op_seconds: dict = field(default_factory=lambda: {o: 0.0 for o in OPS})
    # per-batch engine-call durations (seconds), captured off the clock —
    # the tail-latency raw material behind `latency_ms` in the JSON report
    op_latencies: dict = field(default_factory=lambda: {o: [] for o in OPS})
    divergences: list = field(default_factory=list)
    final_stats: dict = field(default_factory=dict)

    def note_op(self, op: str, dur_s: float) -> None:
        self.op_seconds[op] += dur_s
        self.op_latencies[op].append(dur_s)

    @property
    def wall_s(self) -> float:
        return sum(self.op_seconds.values())

    @property
    def ops_per_s(self) -> float:
        return self.n_ops / max(self.wall_s, 1e-12)

    def latency_ms(self) -> dict:
        """{op: p50/p95/p99/p999/max/mean ms per engine batch call} via
        the shared percentile recipe (`repro.obs.latency_summary`)."""
        return {op: latency_summary(self.op_latencies[op]) for op in OPS}

    def to_json_dict(self) -> dict:
        return dict(name=self.name, engine=self.engine, n_ops=self.n_ops,
                    n_batches=self.n_batches, ops_per_s=self.ops_per_s,
                    us_per_op=1e6 * self.wall_s / max(self.n_ops, 1),
                    op_counts=dict(self.op_counts),
                    op_seconds={k: round(v, 6)
                                for k, v in self.op_seconds.items()},
                    latency_ms=self.latency_ms(),
                    n_divergences=len(self.divergences),
                    divergences=self.divergences[:8],
                    pending_writes=self.final_stats.get("pending_writes"),
                    epoch=self.final_stats.get("epoch"),
                    n_merges=self.final_stats.get("n_merges"))


def _diff(tag: str, got, want) -> list[str]:
    """Bit-exact comparison; returns human-pointable messages, not raises."""
    out = []
    for part, g, w in zip(("keys/vals", "vals", "found/counts"),
                          got, want):
        g, w = np.asarray(g), np.asarray(w)
        if np.array_equal(g, w):
            continue
        if g.shape != w.shape:
            out.append(f"{tag}: {part} shape diverge "
                       f"(got {g.shape}, want {w.shape})")
            continue
        bad = np.nonzero(~np.isclose(g.astype(np.float64),
                                     w.astype(np.float64), equal_nan=True))
        lane = bad[0][0] if len(bad[0]) else -1
        out.append(f"{tag}: {part} diverge at lane {lane} "
                   f"(got {g.reshape(-1)[:4]}..., "
                   f"want {w.reshape(-1)[:4]}...)")
    return out


class WorkloadRunner:
    """Replay `OpBatch` streams through one `LearnedIndex`, oracle-checked.

    check=False turns the runner into a pure throughput driver (no oracle,
    no diffs) for perf sweeps where the keys are not exactly representable
    in the engine's dtype (the pallas engine quantizes to f32; the
    differential contract requires the integer-key convention).

    `warmup_batches` marks the index's retrace watchdog warm after that
    many replayed batches (`telemetry.mark_warm()`): every executable the
    steady state needs should exist by then, so the report's post-warmup
    trace count is a retrace regression signal, not compile noise."""

    def __init__(self, index, check: bool = True, strict: bool = True,
                 verify_writes: bool = True, final_check: bool = True,
                 warmup_batches: int = 8):
        self.index = index
        self.check = check
        self.strict = strict
        self.verify_writes = verify_writes and check
        self.final_check = final_check and check
        self.warmup_batches = warmup_batches
        k, v = index.items()
        self.oracle = SortedOracle(k, v) if check else None

    # -- one batch -----------------------------------------------------------

    def _replay(self, i: int, b: OpBatch, report: WorkloadReport) -> None:
        ix, oc = self.index, self.oracle
        if b.op == "lookup":
            t0 = time.perf_counter()
            v, f = ix.lookup(b.keys)
            report.note_op("lookup", time.perf_counter() - t0)
            if self.check:
                wv, wf = oc.lookup(b.keys)
                msgs = _diff(f"batch {i} lookup", (f, v[f]),
                             (wf, wv[wf] if len(wv) else wv))
                report.divergences += msgs
        elif b.op == "upsert":
            t0 = time.perf_counter()
            ix.upsert(b.keys, b.vals)
            report.note_op("upsert", time.perf_counter() - t0)
            if self.check:
                oc.upsert(b.keys, b.vals)
                if self.verify_writes:
                    v, f = ix.lookup(b.keys)
                    wv, wf = oc.lookup(b.keys)
                    report.divergences += _diff(
                        f"batch {i} upsert-visibility", (f, v[f]), (wf, wv[wf]))
        elif b.op == "delete":
            t0 = time.perf_counter()
            ix.delete(b.keys)
            report.note_op("delete", time.perf_counter() - t0)
            if self.check:
                oc.delete(b.keys)
                if self.verify_writes:
                    _, f = ix.lookup(b.keys)
                    if f.any():
                        report.divergences.append(
                            f"batch {i} delete-visibility: "
                            f"{int(f.sum())}/{len(f)} deleted keys still "
                            f"found")
        else:                                    # range
            mh = getattr(self, "_max_hits", 64)
            t0 = time.perf_counter()
            ks, vs, cnt = ix.range(b.lo, b.hi, max_hits=mh)
            report.note_op("range", time.perf_counter() - t0)
            if self.check:
                want = oc.range(b.lo, b.hi, max_hits=mh)
                report.divergences += _diff(f"batch {i} range",
                                            (ks, vs, cnt), want)

    def _prewarm_buckets(self, batches: list[OpBatch]) -> None:
        """Mint every read-path executable the stream's batch lengths can
        reach before declaring warmup over: one probe lookup (and range,
        when the mix has ranges) per pow2 lane bucket the facade pads to.
        Without this the stream's shorter tail batch hits a smaller pad
        bucket AFTER mark_warm and the compile counts as a retrace."""
        ix = self.index
        pad = getattr(ix, "_pad_batch", None)
        if pad is None:
            return
        buckets, has_range = set(), False
        for b in batches:
            if b.op == "range":
                has_range = True
                buckets.add(pad(len(b.lo)) or len(b.lo))
            else:
                buckets.add(pad(len(b.keys)) or len(b.keys))
        k0 = float(ix.items()[0][0])
        mh = getattr(self, "_max_hits", 64)
        for n in sorted(buckets):
            ix.lookup(np.full(n, k0))
            if has_range:
                ix.range(np.full(n, k0), np.full(n, k0), max_hits=mh)

    # -- the stream ----------------------------------------------------------

    def run(self, batches: list[OpBatch],
            spec: WorkloadSpec | None = None,
            name: str = "") -> WorkloadReport:
        self._max_hits = spec.max_hits if spec is not None else 64
        report = WorkloadReport(
            name=name or (spec.name if spec is not None else "stream"),
            engine=self.index.engine)
        report.op_counts = stream_op_counts(batches)
        tel = getattr(self.index, "telemetry", None)
        for i, b in enumerate(batches):
            n_before = len(report.divergences)
            self._replay(i, b, report)
            report.n_batches += 1
            report.n_ops += b.n_ops
            if (tel is not None and not tel.warmed
                    and report.n_batches >= self.warmup_batches):
                self._prewarm_buckets(batches)
                tel.mark_warm()
            if self.strict and len(report.divergences) > n_before:
                raise WorkloadDivergence(
                    f"{report.name} on engine {report.engine!r}: "
                    + "; ".join(report.divergences[n_before:]))
        if self.final_check:
            k, v = self.index.items()
            wk, wv = self.oracle.items()
            msgs = _diff(f"{report.name} final items()", (k, v), (wk, wv))
            report.divergences += msgs
            if self.strict and msgs:
                raise WorkloadDivergence("; ".join(msgs))
        report.final_stats = self.index.stats()
        # off-thread merges must stay oracle-exact AND alive: a background
        # maintenance task that died is a silent correctness/liveness hole
        # the per-batch diffs may not have tripped over — fail loudly
        n_err = report.final_stats.get("maint_errors", 0)
        if n_err:
            logs = report.final_stats.get("maint_error_logs", [])
            msg = (f"{report.name} on engine {report.engine!r}: "
                   f"{n_err} background maintenance task(s) failed"
                   + ("\n" + "\n".join(logs) if logs else ""))
            report.divergences.append(msg)
            if self.strict:
                raise WorkloadDivergence(msg)
        return report


    # -- kill-and-recover replay (DESIGN.md section 14) ----------------------

    def run_kill_recover(self, batches: list[OpBatch], kill_at: int,
                         spec: WorkloadSpec | None = None,
                         name: str = "") -> dict:
        """Replay `batches[:kill_at]` oracle-checked, crash the index
        (`abandon()`: no final fsync — exactly a SIGKILL's disk state),
        `LearnedIndex.recover` it from its durability directory, diff the
        recovered content bit-exactly against the oracle at the kill
        point, then continue the remaining stream on the RECOVERED index
        (self.index is replaced; the caller closes it via the runner).

        Requires `config.durability`.  Returns a JSON-able dict with both
        leg reports, the recovery wall time, and the replayed-record
        count; strict mode raises `WorkloadDivergence` on any diff."""
        from ..api.index import LearnedIndex
        if not self.check:
            raise ValueError("kill-and-recover is a differential mode; "
                             "construct the runner with check=True")
        dur = self.index.config.durability
        if dur is None:
            raise ValueError("kill-and-recover requires config.durability "
                             "(there is no WAL to recover from)")
        name = name or (spec.name if spec is not None else "stream")
        pre = self.run(batches[:kill_at], spec=spec,
                       name=f"{name}[pre-kill]")
        self.index.abandon()
        t0 = time.perf_counter()
        self.index = LearnedIndex.recover(dur.dir)
        recovery_s = time.perf_counter() - t0
        k, v = self.index.items()
        wk, wv = self.oracle.items()
        msgs = _diff(f"{name} post-recovery items()", (k, v), (wk, wv))
        if self.strict and msgs:
            raise WorkloadDivergence("; ".join(msgs))
        counters = self.index.metrics()["counters"]
        post = self.run(batches[kill_at:], spec=spec,
                        name=f"{name}[post-recovery]")
        return dict(
            name=name, kill_at_batch=kill_at, recovery_s=recovery_s,
            replayed_records=int(counters["recovery.replayed_records"]),
            post_recovery_divergences=msgs,
            n_divergences=(len(pre.divergences) + len(msgs)
                           + len(post.divergences)),
            pre=pre.to_json_dict(), post=post.to_json_dict())


def run_preset(index, preset_or_spec, loaded_keys=None, **scale
               ) -> WorkloadReport:
    """One-call convenience: resolve a preset name (or take a spec),
    generate its stream over the index's current content, and replay it."""
    from .generator import PRESETS, generate_stream
    spec = (PRESETS[preset_or_spec].scaled(**scale)
            if isinstance(preset_or_spec, str) else preset_or_spec)
    if loaded_keys is None:
        loaded_keys = index.items()[0]
    batches = generate_stream(spec, loaded_keys)
    return WorkloadRunner(index).run(batches, spec=spec)
