"""Shared fixtures.  x64 is enabled for the whole test session: the index
(key) paths need f64 and the model paths use explicit dtypes throughout."""
import os
os.environ.setdefault("JAX_ENABLE_X64", "1")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)


def make_keys(dist: str, n: int, rng) -> np.ndarray:
    if dist == "logn":
        return np.unique(rng.lognormal(0, 1, n))
    if dist == "uniform":
        return np.unique(rng.uniform(0, 1e9, n))
    if dist == "fb":        # long-tail pareto (FB-id-like)
        return np.unique((rng.pareto(1.1, n) + 1) * 1e5)
    if dist == "wikits":    # near-sequential timestamps
        return np.unique(np.cumsum(rng.integers(1, 5, n)).astype(np.float64))
    raise ValueError(dist)
