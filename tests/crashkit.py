"""Crash-injection kit for the durability subsystem (DESIGN.md section 14).

A CHILD process builds a durable index and replays a fixed, deterministic
op stream; `DILI_CRASH_POINT="<point>:<n>"` (see `repro.durability.hooks`)
makes it SIGKILL itself at the n-th crossing of an injection point:

    wal.append        after the n-th facade write's WAL append (the record
                      is durable, the engine may never have applied it)
    wal.mid_record    halfway through writing the n-th WAL record (torn
                      record on disk)
    ckpt.pre_publish  checkpoint staged but not yet published (tmp dir)
    ckpt.mid_publish  checkpoint published, `latest`/rotation/GC not done

The PARENT (`run_point`) reaps the SIGKILL, runs `LearnedIndex.recover`,
and diffs the recovered content bit-exactly against a `SortedOracle` fed
exactly the acknowledged-durable prefix of the op stream — computed from
the kill point alone, using the same per-shard append schedule the
durability manager uses.

Both a pytest suite (tests/test_durability.py) and CI drive this via
`run_matrix`; `python tests/crashkit.py matrix --engine local` runs it
standalone (exit 0 = every point recovered exactly).
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys

os.environ.setdefault("JAX_ENABLE_X64", "1")    # before any jax import

import numpy as np

SELF = os.path.abspath(__file__)
SRC = os.path.join(os.path.dirname(os.path.dirname(SELF)), "src")
if SRC not in sys.path:                      # standalone invocation
    sys.path.insert(0, SRC)

# -- the deterministic workload ----------------------------------------------

BASE_SEED, OPS_SEED = 77, 123
N_BASE = 1200
N_BATCHES = 6          # batch = one upsert op + one delete op
FLUSH_AFTER_OPS = 6    # ops before the explicit flush (=> checkpoint hit 2)


def base_data() -> tuple[np.ndarray, np.ndarray]:
    """Integer-valued keys < 2^21 (f32-exact for the pallas engine) and
    int32-range vals (the pallas payload width)."""
    rng = np.random.default_rng(BASE_SEED)
    keys = np.unique(rng.integers(0, 1 << 21, N_BASE)).astype(np.float64)
    vals = rng.integers(0, 1 << 30, len(keys)).astype(np.int64)
    return keys, vals


def gen_ops() -> list[tuple[str, np.ndarray, np.ndarray | None]]:
    """The fixed op stream: [("upsert", keys, vals) | ("delete", keys,
    None), ...].  One op = one facade call = one WAL group commit."""
    base, _ = base_data()
    rng = np.random.default_rng(OPS_SEED)
    ops = []
    for _ in range(N_BATCHES):
        pick = rng.choice(len(base), 40, replace=False)
        up_k = np.unique(np.concatenate([
            base[pick[:20]],                 # updates of existing keys
            base[pick[20:]] + 0.5]))         # fresh keys (0.5: f32-exact)
        up_v = rng.integers(0, 1 << 30, len(up_k)).astype(np.int64)
        ops.append(("upsert", up_k, up_v))
        ops.append(("delete", base[rng.choice(len(base), 8, replace=False)],
                    None))
    return ops


def make_config(engine: str, dur_dir: str):
    from repro.api import IndexConfig, manual_merge_policy
    from repro.durability import DurabilityConfig
    # manual merges + explicit flush: the checkpoint-hit schedule is then
    # deterministic (hit 1 = build base, hit 2 = first flush's publish)
    return IndexConfig(engine=engine, merge=manual_merge_policy(),
                       overlay_cap=256,
                       durability=DurabilityConfig(dir=dur_dir,
                                                   fsync="always"))


def _schedule_indices(engine: str) -> list[tuple[int, list[int]]]:
    """[(op_idx, key indices within that op)] in WAL-append order —
    mirrors `DurabilityManager.log`'s per-shard routing (ascending shard
    id within an op) against a throwaway build of the same base data.
    Must run under the SAME device topology as the child (shard
    boundaries depend on the device count)."""
    from repro.api import IndexConfig, LearnedIndex, manual_merge_policy
    keys, vals = base_data()
    ix = LearnedIndex.build(keys, vals, config=IndexConfig(
        engine=engine, merge=manual_merge_policy(), overlay_cap=256))
    try:
        eng = ix._engine
        sched = []
        for i, (op, k, _) in enumerate(gen_ops()):
            sids = eng.shard_ids(k)
            for s in np.unique(sids):
                sched.append((i, np.flatnonzero(sids == s).tolist()))
        return sched
    finally:
        ix.close()


def append_schedule(engine: str, n_devices: int = 1):
    """[(op_idx, op, keys_subset, vals_subset)] in WAL-append order, so
    the parent can predict exactly which record the n-th append wrote.
    With n_devices > 1 the routing is computed in a subprocess under the
    forced device topology (the parent must keep seeing 1 device)."""
    if n_devices == 1:
        entries = _schedule_indices(engine)
    else:
        import json
        proc = subprocess.run(
            [sys.executable, SELF, "schedule", "--engine", engine],
            env=_child_env(n_devices), capture_output=True, text=True,
            timeout=600)
        assert proc.returncode == 0, proc.stderr[-4000:]
        entries = json.loads(proc.stdout.splitlines()[-1])
    ops = gen_ops()
    sched = []
    for i, idx in entries:
        op, k, v = ops[i]
        idx = np.asarray(idx, int)
        sched.append((i, op, k[idx], None if v is None else v[idx]))
    return sched


# -- expected recovered state -------------------------------------------------


def oracle_after_ops(ops_prefix):
    """SortedOracle fed the base data + a prefix of the op stream."""
    from repro.workloads.oracle import SortedOracle
    keys, vals = base_data()
    oracle = SortedOracle(keys, vals)
    for op, k, v in ops_prefix:
        if op == "upsert":
            oracle.upsert(k, v)
        else:
            oracle.delete(k)
    return oracle


def expected_oracle(engine: str, point: str, hits: int,
                    n_devices: int = 1):
    """The acknowledged-durable prefix for a kill at `point:hits`."""
    from repro.workloads.oracle import SortedOracle
    ops = gen_ops()
    if point == "wal.append":
        # the n-th facade write's append completed (the hook fires after
        # the manager releases its lock), nothing after it ran
        return oracle_after_ops(ops[:hits])
    if point == "wal.mid_record":
        # appends 1..n-1 are durable; the n-th record is torn (its first
        # half is on disk — recovery must truncate it away)
        keys, vals = base_data()
        oracle = SortedOracle(keys, vals)
        for _, op, k, v in append_schedule(engine, n_devices)[: hits - 1]:
            if op == "upsert":
                oracle.upsert(k, v)
            else:
                oracle.delete(k)
        return oracle
    if point in ("ckpt.pre_publish", "ckpt.mid_publish"):
        # hit 2 = the post-first-flush checkpoint: every op before the
        # flush was WAL-appended; the checkpoint itself must not matter
        assert hits == 2, "checkpoint points target the first flush"
        return oracle_after_ops(ops[:FLUSH_AFTER_OPS])
    raise ValueError(f"unknown crash point {point!r}")


# -- child --------------------------------------------------------------------


def child_main(engine: str, dur_dir: str) -> int:
    from repro.api import LearnedIndex
    keys, vals = base_data()
    ix = LearnedIndex.build(keys, vals, config=make_config(engine, dur_dir))
    for i, (op, k, v) in enumerate(gen_ops()):
        if op == "upsert":
            ix.upsert(k, v)
        else:
            ix.delete(k)
        if i + 1 == FLUSH_AFTER_OPS:
            ix.flush()                       # merge publish -> checkpoint
    ix.flush()
    ix.close()
    return 3          # reachable only if the armed crash point never fired


def _child_env(n_devices: int) -> dict:
    env = dict(os.environ,
               JAX_ENABLE_X64="1",
               PYTHONPATH=os.pathsep.join(
                   [SRC] + [p for p in (os.environ.get("PYTHONPATH"),)
                            if p]))
    if n_devices > 1:
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={n_devices} "
            + env.get("XLA_FLAGS", ""))
    return env


def spawn_child(engine: str, dur_dir: str, point: str, hits: int,
                n_devices: int = 1) -> subprocess.CompletedProcess:
    env = dict(_child_env(n_devices),
               DILI_CRASH_POINT=f"{point}:{hits}")
    return subprocess.run(
        [sys.executable, SELF, "child", "--engine", engine,
         "--dir", dur_dir],
        env=env, capture_output=True, text=True, timeout=600)


# -- parent: run one point / the whole matrix ---------------------------------


def run_point(engine: str, dur_dir: str, point: str, hits: int,
              n_devices: int = 1) -> dict:
    """Spawn, kill, recover, diff.  Returns a result dict; raises
    AssertionError on any divergence from the oracle.  The recovery runs
    in THIS process (1 device): a multi-device child's per-shard WALs are
    re-sharded elastically onto the parent's topology."""
    from repro.api import LearnedIndex
    proc = spawn_child(engine, dur_dir, point, hits, n_devices)
    assert proc.returncode == -9, (
        f"{engine}/{point}:{hits}: child exited {proc.returncode} instead "
        f"of dying at the crash point\n{proc.stdout}\n{proc.stderr}")
    oracle = expected_oracle(engine, point, hits, n_devices)
    ix = LearnedIndex.recover(dur_dir)
    try:
        k, v = ix.items()
        ok, ov = oracle.items()
        np.testing.assert_array_equal(
            k, ok, err_msg=f"{engine}/{point}:{hits} recovered keys")
        np.testing.assert_array_equal(
            v, ov, err_msg=f"{engine}/{point}:{hits} recovered vals")
        replayed = int(ix.metrics()["counters"]
                       ["recovery.replayed_records"])
    finally:
        ix.close()
    return dict(engine=engine, point=point, hits=hits,
                n_items=len(k), replayed_records=replayed)


def matrix_points(engine: str, n_devices: int = 1) -> list[tuple[str, int]]:
    """The kill-point matrix: every injection point, both before and
    after the first checkpoint where the point allows it."""
    n_before = len([e for e in append_schedule(engine, n_devices)
                    if e[0] < FLUSH_AFTER_OPS])
    return [
        ("wal.append", 2),                   # pre-checkpoint tail
        ("wal.append", FLUSH_AFTER_OPS + 3),  # post-checkpoint tail
        ("wal.mid_record", 3),               # torn record, pre-checkpoint
        ("wal.mid_record", n_before + 1),    # torn first record post-ckpt
        ("ckpt.pre_publish", 2),
        ("ckpt.mid_publish", 2),
    ]


def run_matrix(engine: str, tmp_root: str, n_devices: int = 1
               ) -> list[dict]:
    results = []
    for point, hits in matrix_points(engine, n_devices):
        d = os.path.join(tmp_root,
                         f"{engine}_{point.replace('.', '_')}_{hits}")
        results.append(run_point(engine, d, point, hits, n_devices))
        print(f"[crashkit] ok {results[-1]}", flush=True)
    return results


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="mode", required=True)
    for mode in ("child", "schedule", "matrix"):
        p = sub.add_parser(mode)
        p.add_argument("--engine", default="local")
        p.add_argument("--dir", default=None)
        p.add_argument("--devices", type=int, default=1)
    args = ap.parse_args(argv)
    if args.mode == "child":
        return child_main(args.engine, args.dir)
    if args.mode == "schedule":
        import json
        print(json.dumps(_schedule_indices(args.engine)))
        return 0
    import tempfile
    root = args.dir or tempfile.mkdtemp(prefix="crashkit_")
    run_matrix(args.engine, root, args.devices)
    print(f"[crashkit] matrix passed for engine={args.engine} "
          f"(devices={args.devices})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
