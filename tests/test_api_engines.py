"""Engine equivalence (acceptance): the same key set served through
`LocalEngine`, `PallasEngine`, and `ShardedEngine` must answer lookups,
range queries, and delete-visibility identically at every lifecycle point
(fresh build / overlay-pending / post-flush).

f32 tolerance rule: the Pallas engine quantizes keys to f32 at the
boundary, so the shared key set is integer-valued below 2^24 (exactly
f32-representable) and payloads stay below 2^31 (the kernel path's int32
payload width) — under those conditions the engines must agree bit-exactly,
not approximately.
"""
import numpy as np
import pytest

from repro.api import (IndexConfig, LearnedIndex, MaintenanceConfig,
                       manual_merge_policy)

ENGINES = ("local", "pallas", "sharded")


def _keyset(rng):
    # integer-valued f64 keys < 2^24: exact under the pallas engine's f32
    # quantization; payloads < 2^31: exact under the kernel's int32 vals
    keys = np.unique(rng.integers(0, 1 << 22, 4000)).astype(np.float64)
    vals = rng.integers(0, 1 << 30, len(keys)).astype(np.int64)
    return keys, vals


@pytest.fixture(scope="module")
def fleet():
    rng = np.random.default_rng(99)
    keys, vals = _keyset(rng)
    cfg = IndexConfig(merge=manual_merge_policy(), overlay_cap=256)
    ixs = {e: LearnedIndex.build(keys, vals, config=cfg.with_engine(e))
           for e in ENGINES}
    return keys, vals, ixs, rng


def _assert_lookup_equivalent(ixs, queries):
    ref_v, ref_f = ixs["local"].lookup(queries)
    for e in ENGINES[1:]:
        v, f = ixs[e].lookup(queries)
        np.testing.assert_array_equal(f, ref_f, err_msg=e)
        np.testing.assert_array_equal(v[f], ref_v[ref_f], err_msg=e)
    return ref_v, ref_f


def _assert_range_equivalent(ixs, lo, hi, max_hits=64):
    ref = ixs["local"].range(lo, hi, max_hits=max_hits)
    for e in ENGINES[1:]:
        ks, vs, cnt = ixs[e].range(lo, hi, max_hits=max_hits)
        np.testing.assert_array_equal(cnt, ref[2], err_msg=e)
        np.testing.assert_array_equal(ks, ref[0], err_msg=e)
        np.testing.assert_array_equal(vs, ref[1], err_msg=e)
    return ref


def test_lookup_equivalence_fresh(fleet):
    keys, vals, ixs, rng = fleet
    qi = rng.integers(0, len(keys), 2048)
    # the +2^23 shift pushes queries past every key: guaranteed misses
    q = np.concatenate([keys[qi], keys[qi[:16]] + (1 << 23)])
    v, f = _assert_lookup_equivalent(ixs, q)
    assert f[: len(qi)].all()
    np.testing.assert_array_equal(v[: len(qi)], vals[qi])


def test_range_equivalence_fresh(fleet):
    keys, vals, ixs, rng = fleet
    starts = rng.integers(0, len(keys) - 100, 128)
    lo, hi = keys[starts], keys[starts + rng.integers(1, 90, 128)]
    ks, vs, cnt = _assert_range_equivalent(ixs, lo, hi)
    # oracle: brute force over the host key set
    for i in range(0, 128, 17):
        want = keys[(keys >= lo[i]) & (keys < hi[i])][:64]
        assert cnt[i] == len(want)
        np.testing.assert_array_equal(ks[i][: cnt[i]], want)


def test_write_and_delete_visibility_equivalence(fleet):
    keys, vals, ixs, rng = fleet
    new = np.setdiff1d(np.arange(1, 200, dtype=np.float64) * 7 + (1 << 22),
                       keys)[:96]
    new_v = np.arange(len(new), dtype=np.int64) + 5_000_000
    dead = keys[rng.integers(0, len(keys), 64)]
    for ix in ixs.values():
        ix.upsert(new, new_v)
        ix.delete(dead)

    probe = np.concatenate([new, dead, keys[:256]])
    # pending state: upserts visible, tombstones hide snapshot hits
    v, f = _assert_lookup_equivalent(ixs, probe)
    assert f[: len(new)].all()
    assert not f[len(new): len(new) + len(dead)].any()

    # ranges spanning the written region agree too (overlay-exact)
    lo = np.array([new[0] - 3, keys[0], dead.min() - 1])
    hi = np.array([new[-1] + 3, keys[300], dead.min() + 1])
    _assert_range_equivalent(ixs, lo, hi)

    # post-flush: folded through Alg. 7/8 on every engine
    for ix in ixs.values():
        ix.flush()
        assert ix.stats()["pending_writes"] == 0
    v, f = _assert_lookup_equivalent(ixs, probe)
    assert f[: len(new)].all()
    assert not f[len(new): len(new) + len(dead)].any()
    _assert_range_equivalent(ixs, lo, hi)

    # logical content identical across engines
    k0, v0 = ixs["local"].items()
    for e in ENGINES[1:]:
        k, v = ixs[e].items()
        np.testing.assert_array_equal(k, k0, err_msg=e)
        np.testing.assert_array_equal(v, v0, err_msg=e)


STATS_CONTRACT = frozenset((
    "engine", "epoch", "max_depth", "snapshot_keys", "pending_writes",
    "overlay_live", "overlay_tombstones", "overlay_cap", "overlay_fill",
    "n_flattens", "n_merges", "device_bytes",
    # maintenance counters (PR 5): every engine reports them, with or
    # without a MaintenanceConfig
    "n_full_flattens", "n_incremental_flattens", "n_retrains",
    "dirty_row_fraction", "maint_queue_depth", "maint_errors",
    # retry exhaustion flag (PR 7): background merges degraded to sync
    "maint_degraded"))


def test_stats_contract_equivalence():
    """Every engine reports the same stats keys with the same meanings:
    epoch counts device publishes (1 after build, +1 per effective flush —
    the sharded engine used to count merges from 0), and the overlay
    breakdown (pending/live/tombstones/cap/fill) is identical for the same
    write history on all three engines."""
    rng = np.random.default_rng(42)
    keys = np.unique(rng.integers(0, 1 << 21, 1200)).astype(np.float64)
    cfg = IndexConfig(merge=manual_merge_policy(), overlay_cap=128)
    ixs = {e: LearnedIndex.build(keys, config=cfg.with_engine(e))
           for e in ENGINES}
    for e, ix in ixs.items():
        s = ix.stats()
        assert STATS_CONTRACT <= set(s), e
        assert s["epoch"] == 1 and ix.epoch == 1, e
        assert (s["pending_writes"], s["overlay_live"],
                s["overlay_tombstones"], s["overlay_fill"]) == (0, 0, 0, 0.0)

    new = np.setdiff1d(keys[:50] + 1.0, keys)      # 50 fresh integer keys
    dead = np.unique(keys[rng.integers(100, 900, 64)])
    for ix in ixs.values():
        ix.upsert(new, np.arange(len(new), dtype=np.int64))
        ix.delete(dead)
    ref = ixs["local"].stats()
    assert ref["pending_writes"] == len(new) + len(dead)
    assert ref["overlay_live"] == len(new)
    assert ref["overlay_tombstones"] == len(dead)
    for e in ENGINES[1:]:
        s = ixs[e].stats()
        for k in ("pending_writes", "overlay_live", "overlay_tombstones"):
            assert s[k] == ref[k], (e, k)
        assert s["overlay_cap"] >= s["pending_writes"], e
        assert 0.0 < s["overlay_fill"] <= 1.0, e
    # sharded: per-shard breakdown sums to the total (the old stats path
    # had no per-shard visibility at all)
    sh = ixs["sharded"].stats()
    assert sum(sh["per_shard_pending"]) == sh["pending_writes"]

    for e, ix in ixs.items():
        ix.flush()
        s = ix.stats()
        assert s["epoch"] == 2 and ix.epoch == 2, e
        assert (s["pending_writes"], s["overlay_fill"]) == (0, 0.0), e
        assert s["n_merges"] == 1, e
        # an empty flush must NOT bump the publish epoch on any engine
        ix.flush()
        assert ix.stats()["epoch"] == 2, e
        # without a MaintenanceConfig every flatten is a full one and the
        # maintenance counters sit at their legacy values
        s = ix.stats()
        assert s["n_incremental_flattens"] == 0, e
        assert s["n_full_flattens"] == s["n_flattens"], e
        assert (s["n_retrains"], s["maint_queue_depth"],
                s["maint_errors"]) == (0, 0, 0), e
        assert s["dirty_row_fraction"] == 1.0, e


def test_stats_maintenance_counters_equivalence():
    """With a (synchronous) MaintenanceConfig, every engine reports the
    same maintenance-counter semantics: a post-build merge flattens
    incrementally (splice), full-flatten count stays at the build count,
    and the dirty-row fraction reflects a partial re-materialization."""
    rng = np.random.default_rng(7)
    # irregular gaps => a multi-segment tree (uniform keys collapse to one
    # perfect leaf, where splice == full by construction); even integers
    # keep the pallas f32 convention
    keys = np.unique(rng.integers(0, 1 << 21, 6000)).astype(np.float64) * 2
    cfg = IndexConfig(merge=manual_merge_policy(), overlay_cap=256,
                      maintenance=MaintenanceConfig(retrain=False))
    for e in ENGINES:
        ix = LearnedIndex.build(keys, config=cfg.with_engine(e))
        builds = ix.stats()["n_full_flattens"]
        assert builds >= 1 and ix.stats()["n_incremental_flattens"] == 0, e
        # hot-spot writes: only a narrow key region gets dirty
        hot = (rng.integers(0, 60, 64) * 2 + 1).astype(np.float64)
        ix.upsert(hot, np.arange(len(hot), dtype=np.int64))
        ix.flush()
        # the first maintained merge seeds the segment cache: full on a
        # cold flattener, incremental from then on
        hot2 = hot[:32]
        ix.upsert(hot2, np.arange(len(hot2), dtype=np.int64))
        ix.flush()
        s = ix.stats()
        assert s["n_incremental_flattens"] >= 1, (e, s)
        assert s["n_retrains"] == 0 and s["maint_errors"] == 0, e
        assert 0.0 < s["dirty_row_fraction"] <= 1.0, e
        assert s["dirty_row_fraction"] < 1.0, (e, s)   # hot-spot => partial
        assert len(ix.maint_timings()) >= 1, e
        ix.close()


def test_pallas_engine_large_magnitude_keys_exact():
    """Regression: at 1.6e9 key magnitude f32 ulp is 128, the section-7
    nudge is unattainable, and compiled XLA single-rounds `a + b*q` past
    the barrier — boundary queries used to mis-route by one child and
    miss.  The pair-table recheck must make every present key findable."""
    rng = np.random.default_rng(1)
    steps = rng.integers(1, 4, 20000).astype(np.float64)
    keys = np.unique(1.6e9 + np.cumsum(steps))
    ix = LearnedIndex.build(keys, config=IndexConfig(
        engine="pallas", sample_stride=4, merge=manual_merge_policy()))
    k32 = np.unique(keys.astype(np.float32)).astype(np.float64)
    v, f = ix.lookup(k32)
    assert f.all(), f"{int((~f).sum())} f32 ULP misses"
    assert ix.get(float(k32[len(k32) // 2])) is not None
    # absent keys must still miss (recheck adds no false positives);
    # offsets far beyond the f32 spacing (128 at this magnitude)
    _, f2 = ix.lookup([keys[0] - 5e5, keys[-1] + 5e5])
    assert not f2.any()


def test_pallas_engine_rejects_integer_writes_beyond_f32_domain():
    """Satellite regression: at |key| >= 2**24 the f32 spacing exceeds 1,
    so adjacent int64 keys alias to one f32 value — a write there would
    silently land on a DIFFERENT logical key.  The engine must refuse it
    (naming the precision domain), not quantize it; in-domain integers and
    fractional keys (the documented quantize tolerance) still pass."""
    U = np.arange(0, 4000, 2, dtype=np.float64)
    ix = LearnedIndex.build(U, config=IndexConfig(
        engine="pallas", merge=manual_merge_policy()))
    bad = np.array([2.0 ** 25 + 1])            # f32 spacing here is 4
    with pytest.raises(ValueError, match="16777216"):
        ix.upsert(bad, np.array([7]))
    with pytest.raises(ValueError, match="f32"):
        ix.delete(bad)
    ix.upsert(np.array([3.0, 2.0 ** 24 - 2.0]), np.array([1, 2]))
    ix.upsert(np.array([5.25]), np.array([3]))     # fractional: tolerated
    assert ix.get(2.0 ** 24 - 2.0) == 2
    assert ix.get(5.25) == 3
    ix.close()


def test_pallas_engine_warns_on_build_key_collisions():
    """Satellite regression: building the pallas engine over keys that
    collide after f32 quantization is tolerated (last-write-wins) but
    must WARN, stating the f32 integer-precision domain (2**24)."""
    keys = 2.0 ** 25 + np.arange(64, dtype=np.float64)   # collapse 4:1
    with pytest.warns(UserWarning, match="16777216"):
        ix = LearnedIndex.build(keys, config=IndexConfig(
            engine="pallas", merge=manual_merge_policy()))
    v, f = ix.lookup(np.array([2.0 ** 25]))
    assert f.all()
    ix.close()


@pytest.mark.slow
def test_sharded_engine_multi_device_equivalence():
    """The facade on an 8-shard mesh answers exactly like the local engine
    (subprocess: the main test process must keep seeing 1 device)."""
    from tests.test_distributed import run_sub
    out = run_sub("""
        import numpy as np
        from repro.api import (IndexConfig, LearnedIndex, MaintenanceConfig,
                       manual_merge_policy)
        rng = np.random.default_rng(5)
        keys = np.unique(rng.integers(0, 1 << 22, 20000)).astype(np.float64)
        cfg = IndexConfig(merge=manual_merge_policy())
        a = LearnedIndex.build(keys, config=cfg)
        b = LearnedIndex.build(keys, config=cfg.with_engine("sharded"))
        assert b.stats()["n_shards"] == 8
        q = np.concatenate([keys[rng.integers(0, len(keys), 4000)],
                            keys[:100] + 0.5])
        for ix in (a, b):
            ix.upsert(keys[:50] + 0.25, np.arange(50))
            ix.delete(keys[100:150])
        # stats contract on a REAL multi-shard mesh: totals match the
        # local engine, per-shard pending sums to the total, publish-epoch
        # semantics agree
        sa, sb = a.stats(), b.stats()
        for k in ("pending_writes", "overlay_live", "overlay_tombstones",
                  "epoch"):
            assert sa[k] == sb[k], (k, sa[k], sb[k])
        assert sb["pending_writes"] == 100
        assert sum(sb["per_shard_pending"]) == 100
        assert len(sb["per_shard_pending"]) == 8
        va, fa = a.lookup(q); vb, fb = b.lookup(q)
        assert np.array_equal(fa, fb) and np.array_equal(va[fa], vb[fb])
        lo = keys[rng.integers(0, len(keys) - 200, 256)]
        ra = a.range(lo, lo + 5000, max_hits=32)
        rb = b.range(lo, lo + 5000, max_hits=32)
        for x, y in zip(ra, rb):
            assert np.array_equal(x, y)
        a.flush(); b.flush()
        assert a.stats()["epoch"] == b.stats()["epoch"] == 2
        assert b.stats()["pending_writes"] == 0
        va, fa = a.lookup(q); vb, fb = b.lookup(q)
        assert np.array_equal(fa, fb) and np.array_equal(va[fa], vb[fb])
        # a2a with a skewed batch: bucket overflow must fall back to the
        # exact gather path, not silently report misses
        c = LearnedIndex.build(keys, config=cfg.with_engine("sharded"),
                               lookup_strategy="a2a")
        lo_shard = keys[keys < np.quantile(keys, 1.0 / 8)]
        skew = lo_shard[rng.integers(0, len(lo_shard), 1024)]
        vs_, fs_ = c.lookup(skew)
        assert fs_.all(), f"a2a overflow dropped {int((~fs_).sum())} lanes"
        print("API-SHARDED-OK")
    """)
    assert "API-SHARDED-OK" in out
