"""save/load round-trips on every engine (satellite of the workload PR:
only construction paths were covered before).

`save()` persists the *logical* content — live keys/vals including pending
overlay writes — plus the config; `load()` rebuilds the tree.  So the
contract under test is: (1) content survives the round-trip bit-exactly,
including un-flushed upserts and tombstones; (2) a loaded index is fully
live — it accepts new writes, folds them on flush, and keeps answering
exactly; (3) the engine is part of the saved config but can be overridden
at load (build local, serve pallas/sharded)."""
import numpy as np
import pytest

from repro.api import IndexConfig, LearnedIndex, manual_merge_policy

ENGINES = ("local", "pallas", "sharded")


def _keyset():
    rng = np.random.default_rng(77)
    keys = np.unique(rng.integers(0, 1 << 22, 1500)).astype(np.float64)
    vals = rng.integers(0, 1 << 30, len(keys)).astype(np.int64)
    return keys, vals


@pytest.mark.parametrize("engine", ENGINES)
def test_save_load_round_trip_with_pending_writes(tmp_path, engine):
    keys, vals = _keyset()
    cfg = IndexConfig(engine=engine, merge=manual_merge_policy(),
                      overlay_cap=128)
    ix = LearnedIndex.build(keys, vals, config=cfg)
    new = np.setdiff1d(keys[:64] + 1.0, keys)      # odd offsets: fresh keys
    ix.upsert(new, np.arange(len(new), dtype=np.int64) + 9_000_000)
    dead = keys[200:240]
    ix.delete(dead)
    assert ix.stats()["pending_writes"] > 0        # round-trips UNFLUSHED

    path = str(tmp_path / f"{engine}.npz")
    ix.save(path)
    ix2 = LearnedIndex.load(path)
    assert ix2.engine == engine
    assert ix2.config.overlay_cap == 128
    # a rebuild folds everything: the loaded index starts clean
    assert ix2.stats()["pending_writes"] == 0
    assert ix2.epoch == 1

    k1, v1 = ix.items()
    k2, v2 = ix2.items()
    np.testing.assert_array_equal(k2, k1)
    np.testing.assert_array_equal(v2, v1)
    # pending state semantics survived: upserts found, tombstones gone
    _, f_new = ix2.lookup(new)
    _, f_dead = ix2.lookup(dead)
    assert f_new.all() and not f_dead.any()


@pytest.mark.parametrize("engine", ENGINES)
def test_load_then_upsert_then_flush(tmp_path, engine):
    """The loaded index must be a live writer, not a read-only replica."""
    keys, vals = _keyset()
    path = str(tmp_path / "ix")
    LearnedIndex.build(keys, vals, config=IndexConfig(
        engine=engine, merge=manual_merge_policy())).save(path)

    ix = LearnedIndex.load(path)
    more = np.setdiff1d(keys[300:380] + 1.0, keys)
    ix.upsert(more, np.arange(len(more), dtype=np.int64) + 7_000_000)
    ix.delete(keys[:32])
    st = ix.flush()
    assert st["pending_writes"] == 0
    assert st["epoch"] == 2                        # one republish post-load

    v, f = ix.lookup(more)
    assert f.all()
    np.testing.assert_array_equal(
        v, np.arange(len(more), dtype=np.int64) + 7_000_000)
    _, f2 = ix.lookup(keys[:32])
    assert not f2.any()
    # and the folded content round-trips AGAIN (save after mutate)
    ix.save(str(tmp_path / "ix2"))
    k3, v3 = LearnedIndex.load(str(tmp_path / "ix2")).items()
    k1, v1 = ix.items()
    np.testing.assert_array_equal(k3, k1)
    np.testing.assert_array_equal(v3, v1)


def test_load_with_engine_override(tmp_path):
    """Cross-engine migration: build local, load onto pallas and sharded;
    content and answers are identical (integer keys: f32-exact)."""
    keys, vals = _keyset()
    cfg = IndexConfig(merge=manual_merge_policy())
    path = str(tmp_path / "local.npz")
    src = LearnedIndex.build(keys, vals, config=cfg)
    src.save(path)
    q = np.concatenate([keys[::7], keys[:64] + 3.0])
    v0, f0 = src.lookup(q)
    for engine in ENGINES[1:]:
        dst = LearnedIndex.load(path, config=cfg.with_engine(engine))
        assert dst.engine == engine
        v, f = dst.lookup(q)
        np.testing.assert_array_equal(f, f0, err_msg=engine)
        np.testing.assert_array_equal(v[f], v0[f0], err_msg=engine)
        k1, v1 = dst.items()
        np.testing.assert_array_equal(k1, keys, err_msg=engine)
        np.testing.assert_array_equal(v1, vals, err_msg=engine)
