"""Hypothesis stateful test: arbitrary interleavings of upsert / delete /
flush / lookup / range on a `LearnedIndex` vs a plain dict model.

The fixed workload scenarios (tests/test_workloads.py) replay *seeded*
streams; this machine lets hypothesis DRIVE the interleaving, which is
what catches overlay/merge sequencing bugs the fixed grids miss
(upsert-delete-upsert of one key across a flush boundary, deletes of
never-inserted keys, merges triggered mid-sequence by the auto policy,
range queries straddling freshly tombstoned runs, ...).

Gated on hypothesis via the repo's importorskip pattern
(tests/test_dili_property.py): absent the dependency, the module skips."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import settings, strategies as st
from hypothesis.stateful import RuleBasedStateMachine, invariant, rule

from repro.api import IndexConfig, LearnedIndex

# a small integer key domain maximizes collisions between rules — the
# interesting interleavings are repeated writes to the SAME key
KEYS = st.integers(min_value=0, max_value=400)


class IndexVsModel(RuleBasedStateMachine):
    def __init__(self):
        super().__init__()
        base = np.arange(0, 128, dtype=np.float64) * 3
        # tiny overlay + default auto-merge policy: hypothesis sequences
        # cross merge boundaries without an explicit flush rule firing
        self.ix = LearnedIndex.build(
            base, config=IndexConfig(engine="local", overlay_cap=16))
        self.model = dict(zip(base.tolist(), range(len(base))))
        self.seq = 10_000

    @rule(ks=st.lists(KEYS, min_size=1, max_size=8))
    def upsert(self, ks):
        vals = np.arange(self.seq, self.seq + len(ks), dtype=np.int64)
        self.seq += len(ks)
        self.ix.upsert(np.asarray(ks, np.float64), vals)
        # last-write-wins within the batch, like the engine
        self.model.update(zip((float(k) for k in ks), vals.tolist()))

    @rule(ks=st.lists(KEYS, min_size=1, max_size=8))
    def delete(self, ks):
        self.ix.delete(np.asarray(ks, np.float64))
        for k in ks:
            self.model.pop(float(k), None)

    @rule()
    def flush(self):
        st_ = self.ix.flush()
        assert st_["pending_writes"] == 0

    @rule(ks=st.lists(KEYS, min_size=1, max_size=16))
    def lookup(self, ks):
        v, f = self.ix.lookup(np.asarray(ks, np.float64))
        for k, vi, fi in zip(ks, v.tolist(), f.tolist()):
            assert fi == (float(k) in self.model), (k, "visibility")
            if fi:
                assert vi == self.model[float(k)], (k, "payload")

    @rule(lo=KEYS, span=st.integers(min_value=1, max_value=60))
    def range_query(self, lo, span):
        ks, vs, cnt = self.ix.range([float(lo)], [float(lo + span)],
                                    max_hits=32)
        want = sorted(k for k in self.model if lo <= k < lo + span)[:32]
        assert cnt[0] == len(want)
        np.testing.assert_array_equal(ks[0][: cnt[0]], want)
        np.testing.assert_array_equal(
            vs[0][: cnt[0]], [self.model[k] for k in want])

    @invariant()
    def content_matches(self):
        # O(n) but n is tiny; run at every step so a divergence is pinned
        # to the exact rule that introduced it
        k, v = self.ix.items()
        want = sorted(self.model)
        np.testing.assert_array_equal(k, want)
        np.testing.assert_array_equal(v, [self.model[x] for x in want])


TestIndexVsModel = IndexVsModel.TestCase
TestIndexVsModel.settings = settings(
    max_examples=12, stateful_step_count=25, deadline=None)
