"""Public-API surface contract (CI acceptance): `repro.api.__all__` imports
cleanly, the facade round-trips build -> upsert -> flush -> lookup on CPU,
config validation fails fast, and save/load rebuilds the logical content."""
import numpy as np
import pytest

import repro.api as api
from repro.api import DeviceSnapshot, IndexConfig, LearnedIndex, MergePolicy


def test_public_surface_imports_cleanly():
    assert api.__all__, "repro.api must declare a public surface"
    for name in api.__all__:
        assert getattr(api, name) is not None, name
    # the facade and config are the documented entry points
    assert "LearnedIndex" in api.__all__
    assert "IndexConfig" in api.__all__
    assert "DeviceSnapshot" in api.__all__


def test_config_validates_engine_and_strategy():
    with pytest.raises(ValueError, match="unknown engine"):
        IndexConfig(engine="gpu")
    with pytest.raises(ValueError, match="lookup_strategy"):
        IndexConfig(lookup_strategy="broadcast")
    assert IndexConfig(engine="pallas").resolved_dtype != \
        IndexConfig(engine="local").resolved_dtype


def test_config_json_roundtrip():
    cfg = IndexConfig(engine="sharded", overlay_cap=128,
                      merge=MergePolicy(max_fill=0.25, max_writes=77),
                      lookup_strategy="a2a", max_hits=32)
    back = IndexConfig.from_json_dict(cfg.to_json_dict())
    assert back == cfg


def test_facade_roundtrip_cpu(rng):
    keys = np.unique(rng.uniform(0, 1e6, 2000))
    ix = LearnedIndex.build(keys)
    assert ix.engine == "local"
    v, f = ix.lookup(keys[:100])
    assert f.all() and np.array_equal(v, np.arange(100))
    ix.upsert(keys[:3] + 0.5, [7, 8, 9])
    ix.delete(keys[10])
    v, f = ix.lookup(np.concatenate([keys[:3] + 0.5, keys[10:11]]))
    assert f[:3].all() and list(v[:3]) == [7, 8, 9]
    assert not f[3]                     # tombstone visible pre-flush
    st = ix.flush()
    assert st["pending_writes"] == 0
    v, f = ix.lookup(np.concatenate([keys[:3] + 0.5, keys[10:11]]))
    assert f[:3].all() and not f[3]     # and post-flush
    ks, vs, cnt = ix.range(keys[0], keys[20])
    ik, _ = ix.items()
    want = ik[(ik >= keys[0]) & (ik < keys[20])]
    assert cnt[0] == len(want)          # upserts in, deleted key out
    np.testing.assert_array_equal(ks[0][: cnt[0]], want)


def test_facade_rejects_nonfinite_and_oversized(rng):
    keys = np.unique(rng.uniform(0, 1e5, 300))
    ix = LearnedIndex.build(keys)
    for bad in ([np.inf], [np.nan], [1.0, -np.inf]):
        with pytest.raises(ValueError, match="finite"):
            ix.lookup(bad)
        with pytest.raises(ValueError, match="finite"):
            ix.upsert(bad, [1] * len(bad))
        with pytest.raises(ValueError, match="finite"):
            ix.delete(bad)
    with pytest.raises(ValueError, match="finite"):
        ix.range([keys[0]], [np.inf])
    # pallas engine: int32 payload width enforced instead of silent wrap
    with pytest.raises(ValueError, match="int32"):
        LearnedIndex.build(keys, np.full(len(keys), 2**31 + 5),
                           engine="pallas")
    px = LearnedIndex.build(keys, engine="pallas")
    with pytest.raises(ValueError, match="int32"):
        px.upsert(keys[0], 2**31 + 5)
    # ...while the int64 engines accept wide payloads (existing contract)
    wide = LearnedIndex.build(keys, np.full(len(keys), 2**41 + 5))
    v, f = wide.lookup(keys[:4])
    assert f.all() and (v == 2**41 + 5).all()


def test_facade_save_load_roundtrip(rng, tmp_path):
    keys = np.unique(rng.uniform(0, 1e5, 500))
    ix = LearnedIndex.build(keys, config=IndexConfig(overlay_cap=32))
    ix.upsert(keys[0], 999)
    ix.delete(keys[1])
    p = str(tmp_path / "ix.npz")
    ix.save(p)                          # pending writes included
    ix2 = LearnedIndex.load(p)
    assert ix2.config.overlay_cap == 32
    k1, v1 = ix.items()
    k2, v2 = ix2.items()
    np.testing.assert_array_equal(k1, k2)
    np.testing.assert_array_equal(v1, v2)
    v, f = ix2.lookup([keys[0], keys[1]])
    assert f[0] and v[0] == 999 and not f[1]


def test_save_load_without_npz_suffix(rng, tmp_path):
    """np.savez appends .npz to bare paths; save(p) -> load(p) must still
    round-trip."""
    keys = np.unique(rng.uniform(0, 1e5, 200))
    ix = LearnedIndex.build(keys)
    p = str(tmp_path / "bare_name")
    ix.save(p)
    ix2 = LearnedIndex.load(p)
    np.testing.assert_array_equal(ix2.items()[0], keys)


@pytest.mark.slow
def test_sharded_build_clamps_shards_to_key_budget():
    """A tiny index must not crash on a many-shard request: shard count
    clamps to len(keys)//2 and to the device count (in-process: 1)."""
    ix = LearnedIndex.build([1.0, 2.0, 3.0, 4.0, 5.0], engine="sharded",
                            n_shards=4)
    assert ix.stats()["n_shards"] == 1
    v, f = ix.lookup([1.0, 3.0, 5.0, 9.0])
    assert list(f) == [True, True, True, False]
    # the multi-device clamp (keys//2 < devices) runs in a subprocess
    from tests.test_distributed import run_sub
    out = run_sub("""
        from repro.api import LearnedIndex
        ix = LearnedIndex.build([1.0, 2.0, 3.0, 4.0, 5.0], engine="sharded")
        assert ix.stats()["n_shards"] == 2
        v, f = ix.lookup([1.0, 5.0, 9.0])
        assert list(f) == [True, True, False]
        print("CLAMP-OK")
    """)
    assert "CLAMP-OK" in out


def test_pallas_engine_honors_pressure_trigger():
    """pressure_lambda must merge a hot leaf on the pallas engine too, not
    only through OnlineIndex."""
    from repro.api import MergePolicy
    rng = np.random.default_rng(7)
    keys = np.unique(rng.lognormal(0, 1, 2000).astype(np.float32)
                     ).astype(np.float64)
    ix = LearnedIndex.build(keys, engine="pallas", overlay_cap=1 << 16,
                            merge=MergePolicy(max_fill=1.1,
                                              max_writes=10**9,
                                              pressure_lambda=2.0,
                                              pressure_check_every=64))
    # hammer one tiny key interval: all pending writes land in one leaf
    hot = np.unique(np.linspace(keys[1000], keys[1001], 300)[1:-1]
                    .astype(np.float32)).astype(np.float64)
    ix.upsert(hot, np.arange(len(hot)))
    assert ix.n_merges >= 1
    v, f = ix.lookup(hot)
    assert f.all()


def test_snapshot_pytree_preserves_statics(rng):
    import jax
    from repro.core.dili import bulk_load
    from repro.core.flat import flatten
    keys = np.unique(rng.uniform(0, 1e6, 1500))
    snap = DeviceSnapshot.from_flat(flatten(bulk_load(keys)))
    leaves, tree = jax.tree_util.tree_flatten(snap)
    back = jax.tree_util.tree_unflatten(tree, leaves)
    assert back.max_depth == snap.max_depth
    assert back.has_dense == snap.has_dense
    assert set(back.arrays) == set(snap.arrays)
    # search entry points accept it with no depth threading
    from repro.core import search as S
    import jax.numpy as jnp
    v, f = S.search_batch(snap, jnp.asarray(keys[:64]))
    assert bool(np.asarray(f).all())
    assert S.resolve_max_depth(snap) == snap.max_depth
