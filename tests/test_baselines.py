"""All competitor indexes: correctness + no false positives on 2 dists."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import ALL_BASELINES
from tests.conftest import make_keys


@pytest.mark.parametrize("B", ALL_BASELINES, ids=lambda b: b.name)
@pytest.mark.parametrize("dist", ["logn", "uniform"])
def test_baseline_correct(B, dist):
    rng = np.random.default_rng(31)
    keys = make_keys(dist, 20000, rng)
    vals = np.arange(len(keys), dtype=np.int64)
    st = B.build(keys, vals)
    dev = B.device(st)
    qi = rng.integers(0, len(keys), 4096)
    v, f, pr = [np.asarray(x) for x in B.lookup(dev, jnp.asarray(keys[qi]))]
    assert f.all(), B.name
    assert np.array_equal(v, qi), B.name
    assert (pr > 0).all()
    # absent keys
    qi2 = rng.integers(0, len(keys) - 1, 2048)
    mids = (keys[qi2] + keys[qi2 + 1]) / 2
    ok = (mids != keys[qi2]) & (mids != keys[qi2 + 1])
    _, fm, _ = B.lookup(dev, jnp.asarray(mids))
    assert not np.asarray(fm)[ok].any(), B.name


def test_probe_ordering_learned_beats_binary():
    """Sanity: learned indexes touch fewer entries than binary search
    (the paper's core claim, Table 5)."""
    rng = np.random.default_rng(32)
    keys = make_keys("logn", 30000, rng)
    vals = np.arange(len(keys), dtype=np.int64)
    qi = rng.integers(0, len(keys), 4096)
    q = jnp.asarray(keys[qi])
    probes = {}
    for B in ALL_BASELINES:
        st = B.build(keys, vals)
        _, _, pr = B.lookup(B.device(st), q)
        probes[B.name] = float(np.asarray(pr).mean())
    assert probes["RMI"] < probes["BinS"]
    assert probes["LIPP"] < probes["BinS"]
    assert probes["RS"] < probes["BinS"]
