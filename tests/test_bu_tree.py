"""Unit tests for the BU-Tree construction (paper Algorithms 2 & 3)."""
import numpy as np
import pytest

from repro.core.bu_tree import (SegStats, build_bu_tree, bu_search,
                                greedy_merging, least_squares)
from tests.conftest import make_keys


def test_least_squares_exact_line():
    x = np.array([1.0, 2.0, 3.0, 4.0])
    a, b = least_squares(x, 2 * x + 3)
    assert abs(a - 3) < 1e-9 and abs(b - 2) < 1e-9


def test_least_squares_tight_cluster_nonzero_slope():
    # catastrophic-cancellation regression: keys 7.3e-9 apart must separate
    x = np.array([3.584090078469237, 3.584090085784596])
    a, b = least_squares(x, np.array([0.0, 1.0]))
    assert b > 0
    assert abs((a + b * x[0]) - 0.0) < 1e-6
    assert abs((a + b * x[1]) - 1.0) < 1e-6


def test_segstats_merge_equals_full():
    rng = np.random.default_rng(0)
    x = np.sort(rng.uniform(0, 1, 100))
    y = np.arange(100.0)
    s1 = SegStats.of(x[:60], y[:60])
    s2 = SegStats.of(x[60:], y[60:])
    m = s1.merge(s2)
    full = SegStats.of(x, y)
    assert abs(m.sse() - full.sse()) < 1e-6 * max(full.sse(), 1.0)


@pytest.mark.parametrize("dist", ["logn", "uniform", "fb", "wikits"])
def test_greedy_merging_partitions(dist, rng):
    keys = make_keys(dist, 20000, rng)
    n_h, bps, pieces = greedy_merging(keys, None, len(keys))
    assert n_h == len(pieces) == len(bps)
    # pieces tile [0, n) exactly
    assert pieces[0][0] == 0 and pieces[-1][1] == len(keys)
    for (a, b, *_), (c, d, *_) in zip(pieces, pieces[1:]):
        assert b == c
    # piece size cap (2 * omega)
    assert max(p[1] - p[0] for p in pieces) <= 2 * 4096


def test_bu_tree_structure(rng):
    keys = make_keys("logn", 30000, rng)
    bu = build_bu_tree(keys)
    assert bu.height >= 2
    assert len(bu.levels[-1]) == 1           # single root
    # levels shrink monotonically
    sizes = [len(l) for l in bu.levels]
    assert all(a >= b for a, b in zip(sizes, sizes[1:]))
    # leaves tile the key range
    leaves = bu.levels[0]
    assert leaves[0].lo == 0 and leaves[-1].hi == len(keys)


def test_bu_search_finds_keys(rng):
    keys = make_keys("uniform", 20000, rng)
    bu = build_bu_tree(keys)
    for i in rng.integers(0, len(keys), 100):
        pos, nodes, probes = bu_search(bu, keys, float(keys[i]))
        assert pos == i
    pos, _, _ = bu_search(bu, keys, float(keys[0]) - 1.0)
    assert pos == -1


def test_sampling_similar_layout(rng):
    keys = make_keys("logn", 20000, rng)
    full = build_bu_tree(keys, sample_stride=1)
    samp = build_bu_tree(keys, sample_stride=4)
    # appendix A.7: sampling barely changes the layout
    assert abs(len(full.levels[0]) - len(samp.levels[0])) \
        < 0.25 * len(full.levels[0]) + 10
