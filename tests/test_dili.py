"""Host-side DILI: bulk load (Alg. 4), local opt (Alg. 5), search (Alg. 6),
updates (Alg. 7/8) — including hypothesis property tests against a dict."""
import numpy as np
import pytest

from repro.core.dili import (DILI, Leaf, bulk_load, collect_pairs, local_opt,
                             phi)
from tests.conftest import make_keys


@pytest.fixture(scope="module", params=["logn", "uniform", "fb", "wikits"])
def built(request):
    rng = np.random.default_rng(7)
    keys = make_keys(request.param, 30000, rng)
    vals = np.arange(len(keys), dtype=np.int64)
    return keys, vals, bulk_load(keys, vals)


def test_all_keys_found(built):
    keys, vals, d = built
    rng = np.random.default_rng(8)
    for i in rng.integers(0, len(keys), 500):
        assert d.search(float(keys[i])) == vals[i]


def test_absent_keys_not_found(built):
    keys, _, d = built
    rng = np.random.default_rng(9)
    for i in rng.integers(0, len(keys) - 1, 200):
        mid = (keys[i] + keys[i + 1]) / 2
        if mid != keys[i] and mid != keys[i + 1]:
            assert d.search(float(mid)) is None
    assert d.search(float(keys[0]) - 1.0) is None
    assert d.search(float(keys[-1]) + 1.0) is None


def test_pair_conservation(built):
    keys, _, d = built
    st_ = d.stats()
    assert st_["n_pairs"] == len(keys)


def test_height_bounded(built):
    # paper Table 6: max height 4-9 at 200M; small sets stay shallow
    _, _, d = built
    st_ = d.stats()
    assert st_["max_height"] <= 12
    assert st_["avg_height"] <= 6


def test_range_query(built):
    keys, vals, d = built
    lo, hi = float(keys[100]), float(keys[160])
    got = d.range_query(lo, hi)
    expect = [(float(k), int(v)) for k, v in zip(keys, vals)
              if lo <= k < hi]
    assert got == sorted(expect)


def test_insert_search_delete_roundtrip(built):
    keys, _, d = built
    rng = np.random.default_rng(10)
    new = np.setdiff1d(np.unique(rng.uniform(keys[0], keys[-1], 2000)), keys)
    for j, k in enumerate(new):
        assert d.insert(float(k), 5_000_000 + j)
    for j, k in enumerate(new):
        assert d.search(float(k)) == 5_000_000 + j
    # duplicate insert is a no-op
    assert not d.insert(float(new[0]), 1)
    for k in new[: len(new) // 2]:
        assert d.delete(float(k))
    for k in new[: len(new) // 2]:
        assert d.search(float(k)) is None
    for j, k in enumerate(new[len(new) // 2:], start=len(new) // 2):
        assert d.search(float(k)) == 5_000_000 + j
    assert not d.delete(float(keys[0]) - 1.0)


def test_adjustment_triggers_and_preserves(rng):
    keys = make_keys("logn", 5000, rng)
    d = bulk_load(keys)
    # hammer one region to force conflicts + adjustment (Alg. 7 lines 20-26)
    lo, hi = float(keys[100]), float(keys[101])
    extra = np.linspace(lo, hi, 600)[1:-1]
    for j, k in enumerate(extra):
        d.insert(float(k), 9_000_000 + j)
    assert d.n_adjustments >= 1
    for j, k in enumerate(extra):
        assert d.search(float(k)) == 9_000_000 + j


def test_phi_monotone_capped():
    vals = [phi(a) for a in range(0, 40)]
    assert all(b >= a for a, b in zip(vals, vals[1:]))
    assert max(vals) <= 4.0


def test_upsert_replaces_payload(built):
    keys, vals, d = built
    k = float(keys[42])
    assert not d.upsert(k, 123_456)          # existed: payload replaced
    assert d.search(k) == 123_456
    new = (float(keys[42]) + float(keys[43])) / 2
    if new not in (float(keys[42]), float(keys[43])):
        assert d.upsert(new, 1)              # absent: behaves like insert
        assert d.search(new) == 1
    d.upsert(k, int(vals[42]))               # restore for later tests


def test_upsert_dense_leaf_replaces_payload(rng):
    """Regression: the dense-leaf insert path used to report duplicates as
    newly inserted, so upsert silently kept the stale payload."""
    keys = np.arange(100, dtype=np.float64)
    d = bulk_load(keys, local_optimized=False)   # DILI-LO: dense leaves
    assert not d.upsert(5.0, 999)
    assert d.search(5.0) == 999
    assert not d.insert(5.0, 7)                  # plain insert is still a no-op
    assert d.search(5.0) == 999
    assert d.insert(100.5, 7) is True            # new dense insert reports so
    assert d.upsert(200.5, 8) is True
    assert d.search(100.5) == 7 and d.search(200.5) == 8


def test_dili_lo_variant(rng):
    keys = make_keys("uniform", 8000, rng)
    d = bulk_load(keys, local_optimized=False)
    for i in rng.integers(0, len(keys), 300):
        assert d.search(float(keys[i])) == i
    st_ = d.stats()
    # DILI-LO packs tightly: slots == pairs
    assert st_["n_slots"] >= st_["n_pairs"]


# The hypothesis property test (random op sequences vs a python dict) lives in
# tests/test_dili_property.py behind pytest.importorskip("hypothesis") so this
# module collects and runs even when the optional extra is absent.
