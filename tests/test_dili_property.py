"""Property-based host-DILI tests: random op sequences vs a python dict.

hypothesis is an optional extra (see requirements.txt); the importorskip
guard keeps `pytest -x -q` collecting when it is absent while keeping the
property tests runnable wherever it is installed.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.dili import bulk_load  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(st.lists(
    st.tuples(st.sampled_from(["insert", "delete", "search"]),
              st.integers(0, 400)),
    min_size=1, max_size=120),
    st.integers(0, 2**31 - 1))
def test_random_ops_match_dict(ops, seed):
    rng = np.random.default_rng(seed)
    base = np.unique(rng.uniform(0, 1000, 300))
    d = bulk_load(base)
    oracle = {float(k): i for i, k in enumerate(base)}
    universe = np.unique(np.concatenate([base, rng.uniform(0, 1000, 200)]))
    nxt = len(base)
    for op, ki in ops:
        k = float(universe[ki % len(universe)])
        if op == "insert":
            r = d.insert(k, nxt)
            assert r == (k not in oracle)
            if r:
                oracle[k] = nxt
            nxt += 1
        elif op == "delete":
            r = d.delete(k)
            assert r == (k in oracle)
            oracle.pop(k, None)
        else:
            assert d.search(k) == oracle.get(k)
    # final full validation
    for k, v in oracle.items():
        assert d.search(k) == v
