"""Distributed tests run in subprocesses with 8 forced host devices (the main
test process must keep seeing 1 device — dry-run contract)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_ENABLE_X64="1",
               PYTHONPATH=os.path.join(ROOT, "src"))
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


@pytest.mark.slow
def test_sharded_index_gather_and_a2a():
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.distributed import build_sharded, to_mesh, sharded_lookup
        rng = np.random.default_rng(1)
        keys = np.unique(rng.lognormal(0, 1, 40000))
        sd = build_sharded(keys, None, n_shards=8, sample_stride=4)
        mesh = jax.make_mesh((8,), ("data",))
        arrs = to_mesh(sd, mesh)
        qi = rng.integers(0, len(keys), 4096)
        q = jnp.asarray(keys[qi])
        v, f = sharded_lookup(mesh, arrs, q, sd.max_depth, strategy="gather")
        assert bool(np.asarray(f).all())
        assert np.array_equal(np.asarray(v), qi)
        v2, f2, ovf = sharded_lookup(mesh, arrs, q, sd.max_depth, strategy="a2a")
        ok = np.asarray(f2)
        assert np.array_equal(np.asarray(v2)[ok], qi[ok])
        assert ok.mean() > 0.99
        print("DIST-OK", int(np.asarray(ovf).sum()))
    """)
    assert "DIST-OK" in out


@pytest.mark.slow
def test_sharded_online_updates():
    """Per-shard overlays absorb upserts/deletes without a global rebuild;
    merge republishes only the touched shards' rows."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.distributed import (build_sharded, to_mesh,
            sharded_lookup_with_overlay, sharded_upsert, sharded_delete,
            sharded_merge)
        rng = np.random.default_rng(2)
        keys = np.unique(rng.lognormal(0, 1, 20000))
        sd = build_sharded(keys, None, n_shards=8, sample_stride=4)
        mesh = jax.make_mesh((8,), ("data",))
        arrs = to_mesh(sd, mesh)
        qi = rng.integers(0, len(keys), 4096)
        q = jnp.asarray(keys[qi])
        new = np.setdiff1d(np.unique(rng.lognormal(0, 1, 3000)), keys)[:2048]
        sharded_upsert(sd, new, 5_000_000 + np.arange(len(new)))
        dels = np.unique(keys[qi[:512]])
        sharded_delete(sd, dels)
        # exact between merges: overlay keys found, tombstoned keys hidden
        v, f = sharded_lookup_with_overlay(mesh, arrs, sd, q, sd.max_depth)
        f = np.asarray(f); deleted = np.isin(keys[qi], dels)
        assert not f[deleted].any() and f[~deleted].all()
        qn = jnp.asarray(new[:1024])
        vn, fn = sharded_lookup_with_overlay(mesh, arrs, sd, qn, sd.max_depth)
        assert np.asarray(fn).all()
        assert np.array_equal(np.asarray(vn), 5_000_000 + np.arange(1024))
        # merge: fold per-shard overlays through Alg. 7/8, republish rows
        merged = sharded_merge(sd)
        assert merged and sd.epoch == 1
        assert all(ov.count == 0 for ov in sd.overlays)
        arrs = to_mesh(sd, mesh)
        v3, f3 = sharded_lookup_with_overlay(mesh, arrs, sd, qn, sd.max_depth)
        assert np.asarray(f3).all()
        assert np.array_equal(np.asarray(v3), 5_000_000 + np.arange(1024))
        v4, f4 = sharded_lookup_with_overlay(mesh, arrs, sd, q, sd.max_depth)
        f4 = np.asarray(f4)
        assert not f4[deleted].any() and f4[~deleted].all()
        print("DIST-ONLINE-OK", sd.epoch)
    """)
    assert "DIST-ONLINE-OK" in out


@pytest.mark.slow
def test_sharded_range_query():
    """Per-shard sorted-pair bisection + prefix-offset psum assembly matches
    a brute-force numpy oracle, including windows spanning shard boundaries
    and max_hits truncation."""
    out = run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.distributed import (build_sharded, to_mesh,
            sharded_range_query)
        rng = np.random.default_rng(7)
        keys = np.unique(rng.lognormal(0, 1, 30000))
        sd = build_sharded(keys, None, n_shards=8, sample_stride=4)
        mesh = jax.make_mesh((8,), ("data",))
        arrs = to_mesh(sd, mesh)
        # windows: random; some straddle shard boundaries, some overflow
        starts = rng.integers(0, len(keys) - 200, 512)
        widths = rng.integers(0, 180, 512)
        b_idx = np.searchsorted(keys, sd.boundaries[1:-1])
        starts[:64] = np.clip(b_idx[rng.integers(0, len(b_idx), 64)] - 20,
                              0, len(keys) - 200)       # straddle boundaries
        lo = keys[starts]
        hi = keys[np.minimum(starts + widths, len(keys) - 1)]
        ks, vs, cnt = sharded_range_query(mesh, arrs, jnp.asarray(lo),
                                          jnp.asarray(hi), max_hits=128)
        ks, vs, cnt = np.asarray(ks), np.asarray(vs), np.asarray(cnt)
        for i in range(512):
            m = (keys >= lo[i]) & (keys < hi[i])
            ek = keys[m][:128]; ev = np.nonzero(m)[0][:128]
            assert cnt[i] == len(ek), (i, cnt[i], len(ek))
            assert np.array_equal(ks[i][:cnt[i]], ek), i
            assert np.array_equal(vs[i][:cnt[i]], ev), i
            assert np.all(ks[i][cnt[i]:] == np.inf), i
        print("DIST-RANGE-OK")
    """)
    assert "DIST-RANGE-OK" in out


@pytest.mark.slow
def test_small_mesh_train_step_shardings():
    out = run_sub("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.models import model as MDL
        from repro.parallel import sharding as SH
        from repro.train import step as STEP
        from repro.train.optim import adamw
        cfg = dataclasses.replace(get_config("granite_8b").reduced(),
                                  d_model=128, n_heads=4, n_kv_heads=2,
                                  d_ff=256, vocab=512)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        opt = adamw(lr=1e-3)
        state_shape = jax.eval_shape(
            lambda: STEP.init_state(jax.random.PRNGKey(0), cfg, opt))
        p_sh = SH.param_shardings(cfg, mesh, state_shape["params"])
        # init on mesh
        with mesh:
            state = STEP.init_state(jax.random.PRNGKey(0), cfg, opt)
            step = jax.jit(STEP.make_train_step(cfg, opt))
            toks = jnp.zeros((8, 16), jnp.int32)
            batch = dict(tokens=toks, labels=toks)
            state2, m = step(state, batch)
            assert np.isfinite(float(m["loss"]))
        print("MESH-TRAIN-OK", float(m["loss"]))
    """)
    assert "MESH-TRAIN-OK" in out


@pytest.mark.slow
def test_elastic_restore_across_meshes(tmp_path):
    out = run_sub(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.ft import checkpoint as CKPT
        from repro.train import step as STEP
        from repro.train.optim import adamw
        cfg = get_config("granite_8b").reduced()
        opt = adamw()
        state = STEP.init_state(jax.random.PRNGKey(0), cfg, opt)
        CKPT.save(r"{tmp_path}", 5, state)
        # restore onto an 8-device mesh with FSDP shardings
        from repro.parallel import sharding as SH
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        tmpl = jax.eval_shape(lambda: STEP.init_state(
            jax.random.PRNGKey(0), cfg, opt))
        p_sh = SH.param_shardings(cfg, mesh, tmpl["params"])
        got, man = CKPT.restore(r"{tmp_path}", tmpl["params"], p_sh,
                                prefix="params")
        assert man["step"] == 5
        leaf = got["layers"]["attn"]["wq"]
        assert len(leaf.sharding.device_set) >= 1
        np.testing.assert_allclose(
            np.asarray(leaf), np.asarray(state["params"]["layers"]["attn"]["wq"]),
            rtol=1e-6)
        print("ELASTIC-OK")
    """)
    assert "ELASTIC-OK" in out


@pytest.mark.slow
def test_psum_int8_compression_collective():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.parallel.compression import psum_int8
        mesh = jax.make_mesh((8,), ("data",))
        def f(x):
            return psum_int8(x, "data")
        g = shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P("data"))
        x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (64, 32)),
                        jnp.float32)
        y = g(x)
        # every shard receives the same sum; compare against exact psum
        exact = shard_map(lambda x: jax.lax.psum(x, "data"), mesh=mesh,
                          in_specs=P("data"), out_specs=P("data"))(x)
        err = float(jnp.abs(y - exact).max())
        scale = float(jnp.abs(exact).max())
        assert err < 0.05 * scale + 0.1, (err, scale)
        print("COMPRESS-OK", err)
    """)
    assert "COMPRESS-OK" in out
