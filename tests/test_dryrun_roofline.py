"""Integration: the dry-run launcher on a real cell (512 fake devices,
subprocess) + the trip-count-aware HLO walker's core invariant."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_dryrun_single_cell(tmp_path):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(ROOT, "src"),
               JAX_COMPILATION_CACHE_DIR="/tmp/jaxcache")
    env.pop("XLA_FLAGS", None)       # dryrun.py sets its own (512 devices)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "whisper-base", "--shape", "train_4k",
         "--out", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=840, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    row = json.load(open(tmp_path / "whisper-base_train_4k_single.json"))
    assert row["status"] == "OK"
    assert row["flops"] > 0
    assert row.get("mem_peak_memory_in_bytes", 0) < 16 * 2**30
    assert (tmp_path / "whisper-base_train_4k_single.hlo.gz").exists()


def test_hlo_walker_multiplies_trip_counts():
    """cost_analysis counts scan bodies once; the walker must multiply."""
    sys.path.insert(0, os.path.join(ROOT, "benchmarks"))
    import jax
    import jax.numpy as jnp
    from hlo_analysis import analyze

    def f_scan(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)
        return y

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    flops = {}
    for L in (2, 8):
        ws = jax.ShapeDtypeStruct((L, 256, 256), jnp.float32)
        hlo = jax.jit(f_scan).lower(x, ws).compile().as_text()
        r = analyze(hlo)
        flops[L] = r["flops"]
        # dot flops ~= L * 2*128*256*256
        expect = L * 2 * 128 * 256 * 256
        assert abs(r["flops"] - expect) / expect < 0.25, (L, r["flops"])
    assert 3.0 < flops[8] / flops[2] < 5.0   # linear in trip count


def test_roofline_model_flops_sane():
    sys.path.insert(0, os.path.join(ROOT, "benchmarks"))
    from roofline import model_flops, param_counts
    total, active, cfg = param_counts("granite_8b")
    assert 7e9 < total < 9e9           # granite-8b really has ~8B params
    total_g, active_g, _ = param_counts("grok_1_314b")
    assert 3.0e11 < total_g < 3.4e11   # grok ~314B
    assert active_g < 0.45 * total_g   # top-2 of 8 experts
    mf = model_flops("granite_8b", "train_4k")
    # 6*N*D/chips = 6*8e9*1M/256 ~ 2e14
    assert 1e14 < mf < 4e14