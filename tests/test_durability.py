"""Durability subsystem (DESIGN.md section 14): WAL codec + torn-tail
truncation, checkpoint corruption fallback, in-process crash/recover
round trips, the subprocess crash-injection matrix (tests/crashkit.py),
and the hardened-maintenance satellites (bounded merge retries with
degrade-to-sync, merge.failed/maint.errors observability)."""
import os

import numpy as np
import pytest

import crashkit
from repro.api import (DurabilityConfig, IndexConfig, LearnedIndex,
                       MaintenanceConfig, manual_merge_policy)
from repro.durability import wal
from repro.durability import checkpoint as dckpt
from repro.workloads.generator import PRESETS, generate_stream
from repro.workloads.oracle import SortedOracle
from repro.workloads.runner import WorkloadRunner

ENGINES = ("local", "pallas", "sharded")


def _dur_cfg(tmp_path, engine="local", fsync="always", **kw):
    return IndexConfig(engine=engine, merge=manual_merge_policy(),
                       overlay_cap=128,
                       durability=DurabilityConfig(
                           dir=str(tmp_path / "dur"), fsync=fsync, **kw))


# ---------------------------------------------------------------------------
# WAL unit tests
# ---------------------------------------------------------------------------


def test_wal_record_round_trip(tmp_path):
    d = str(tmp_path / "w")
    w = wal.WalWriter(d, fsync="always")
    k1 = np.array([1.5, 2.5, 99.0])
    v1 = np.array([10, 20, 30], np.int64)
    assert w.append(wal.OP_UPSERT, k1, v1, epoch=3) == 0
    assert w.append(wal.OP_DELETE, np.array([2.5]), None, epoch=3) == 1
    w.close()
    recs = wal.read_records(d)
    assert [r["lsn"] for r in recs] == [0, 1]
    assert recs[0]["op"] == wal.OP_UPSERT and recs[0]["epoch"] == 3
    np.testing.assert_array_equal(recs[0]["keys"], k1)
    np.testing.assert_array_equal(recs[0]["vals"], v1)
    assert recs[1]["op"] == wal.OP_DELETE and recs[1]["vals"] is None
    np.testing.assert_array_equal(recs[1]["keys"], [2.5])


def test_wal_torn_tail_truncates_at_first_bad_crc(tmp_path):
    d = str(tmp_path / "w")
    w = wal.WalWriter(d, fsync="always")
    for i in range(4):
        w.append(wal.OP_UPSERT, np.array([float(i)]),
                 np.array([i], np.int64), epoch=1)
    w.close()
    (_, path), = wal.list_segments(d)
    full = os.path.getsize(path)
    # a half-written trailing record: everything before it must survive
    with open(path, "ab") as f:
        f.write(wal.encode_record(4, 1, wal.OP_UPSERT, np.array([9.0]),
                                  np.array([9], np.int64))[:11])
    assert [r["lsn"] for r in wal.read_records(d)] == [0, 1, 2, 3]
    # flip one payload byte mid-file: records BEFORE it survive, the
    # corrupt one and everything after are dropped (CRC catches it)
    with open(path, "r+b") as f:
        f.truncate(full)
        f.seek(full // 2)
        b = f.read(1)
        f.seek(full // 2)
        f.write(bytes([b[0] ^ 0xFF]))
    lsns = [r["lsn"] for r in wal.read_records(d)]
    assert lsns == list(range(len(lsns))) and len(lsns) < 4


def test_wal_rotate_purge_and_resume(tmp_path):
    d = str(tmp_path / "w")
    w = wal.WalWriter(d, fsync="always")
    for i in range(3):
        w.append(wal.OP_DELETE, np.array([float(i)]), None, epoch=1)
    w.rotate()                               # seg[0..3) closed, seg[3..) live
    w.append(wal.OP_DELETE, np.array([7.0]), None, epoch=1)
    assert len(wal.list_segments(d)) == 2
    assert w.purge_upto(2) == 0              # watermark inside the closed seg
    assert w.purge_upto(3) == 1              # whole closed range checkpointed
    assert [r["lsn"] for r in wal.read_records(d, from_lsn=3)] == [3]
    w.close()
    # a resumed writer continues the lsn sequence in the same directory
    w2 = wal.WalWriter(d, fsync="always", start_lsn=wal.end_lsn(d))
    assert w2.append(wal.OP_DELETE, np.array([8.0]), None, epoch=2) == 4
    w2.close()
    assert [r["lsn"] for r in wal.read_records(d, from_lsn=3)] == [3, 4]


# ---------------------------------------------------------------------------
# durability checkpoint fallback
# ---------------------------------------------------------------------------


def _write_ckpt(d, step, n):
    keys = np.arange(n, dtype=np.float64)
    return dckpt.write_checkpoint(
        str(d), step, keys, (keys * 2).astype(np.int64),
        epoch=step, wal_lsns={0: step * 10}, keep=3)


def test_checkpoint_corrupt_newest_falls_back(tmp_path):
    d = tmp_path / "ckpt"
    _write_ckpt(d, 1, 50)
    p2 = _write_ckpt(d, 2, 60)
    # corrupt the newest checkpoint's array payload
    npz = os.path.join(p2, "state.npz")
    blob = bytearray(open(npz, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    open(npz, "wb").write(bytes(blob))
    name, manifest, keys, _ = next(dckpt.iter_checkpoints(str(d)))
    assert manifest["step"] == 1 and len(keys) == 50
    # with the newest manifest gone instead, same fallback
    _write_ckpt(d, 3, 70)
    os.remove(os.path.join(str(d), dckpt.ftck.step_name(3),
                           "manifest.json"))
    name, manifest, keys, _ = next(dckpt.iter_checkpoints(str(d)))
    assert manifest["step"] == 1 and len(keys) == 50


# ---------------------------------------------------------------------------
# in-process build -> crash -> recover round trips
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
def test_abandon_recover_round_trip(tmp_path, engine):
    """The acknowledged write stream survives an un-fsynced abandon on
    every engine: checkpointed prefix + WAL tail == oracle."""
    rng = np.random.default_rng(5)
    keys = np.unique(rng.integers(0, 1 << 20, 900)).astype(np.float64)
    vals = rng.integers(0, 1 << 30, len(keys)).astype(np.int64)
    oracle = SortedOracle(keys, vals)
    ix = LearnedIndex.build(keys, vals, config=_dur_cfg(tmp_path, engine))
    up_k, up_v = keys[:40] + 0.5, np.arange(40, dtype=np.int64)
    ix.upsert(up_k, up_v)
    oracle.upsert(up_k, up_v)
    ix.flush()                               # checkpointed prefix
    ix.delete(keys[100:120])
    oracle.delete(keys[100:120])             # un-flushed WAL tail
    ix.abandon()

    rx = LearnedIndex.recover(str(tmp_path / "dur"))
    try:
        k, v = rx.items()
        wk, wv = oracle.items()
        np.testing.assert_array_equal(k, wk)
        np.testing.assert_array_equal(v, wv)
        assert rx.engine == engine
        m = rx.metrics()
        assert m["counters"]["recovery.count"] == 1
        assert m["counters"]["recovery.replayed_records"] == 1
        # recovery spans are recorded even with telemetry disabled
        for s in ("recovery.load", "recovery.replay", "recovery.publish"):
            assert m["spans"][s]["count"] == 1, s
        # the recovered index is a live durable writer
        rx.upsert([3.25], [777])
        rx.flush()
    finally:
        rx.close()
    rz = LearnedIndex.recover(str(tmp_path / "dur"))
    try:
        assert rz.get(3.25) == 777
    finally:
        rz.close()


def test_recover_without_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        LearnedIndex.recover(str(tmp_path / "nothing"))


def test_clean_close_then_recover(tmp_path):
    ix = LearnedIndex.build(np.arange(64, dtype=np.float64),
                            config=_dur_cfg(tmp_path, fsync="interval"))
    ix.upsert([7.5], [70])
    ix.close()                               # final fsync, clean shutdown
    rx = LearnedIndex.recover(str(tmp_path / "dur"))
    try:
        assert rx.get(7.5) == 70
    finally:
        rx.close()


def test_wal_truncation_after_checkpoints(tmp_path):
    """Checkpoints advance the watermark and old segments are purged —
    but only past the OLDEST retained checkpoint, so the fallback path
    always has enough tail."""
    cfg = _dur_cfg(tmp_path, keep_checkpoints=2)
    ix = LearnedIndex.build(np.arange(256, dtype=np.float64), config=cfg)
    dur = ix._dur
    for i in range(5):
        ix.upsert(np.arange(8, dtype=np.float64) + 1000 + 16 * i,
                  np.arange(8, dtype=np.int64))
        ix.flush()                           # merge publish -> checkpoint
    manifests = dckpt.retained_manifests(os.path.join(cfg.durability.dir,
                                                      "ckpt"))
    assert len(manifests) == 2               # keep_checkpoints enforced
    oldest = min(int(m["wal_lsns"]["0"]) for m in manifests)
    segs = wal.list_segments(os.path.join(cfg.durability.dir, "wal",
                                          "shard_00000"))
    # every surviving segment still covers the oldest retained watermark
    assert all(start >= oldest or i + 1 == len(segs)
               or segs[i + 1][0] > oldest for i, (start, _) in
               enumerate(segs))
    assert dur is ix._dur
    ix.close()


def test_config_round_trips_durability(tmp_path):
    cfg = _dur_cfg(tmp_path, fsync="interval")
    back = IndexConfig.from_json_dict(cfg.to_json_dict())
    assert back.durability == cfg.durability
    assert IndexConfig.from_json_dict(
        IndexConfig().to_json_dict()).durability is None
    with pytest.raises(ValueError):
        DurabilityConfig(dir=str(tmp_path), fsync="sometimes")
    with pytest.raises(ValueError):
        DurabilityConfig(dir="")


# ---------------------------------------------------------------------------
# crash-injection matrix (subprocess SIGKILL at armed points)
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("engine", ENGINES)
def test_crash_matrix(tmp_path, engine):
    """Every kill point recovers to exactly the acknowledged prefix —
    bit-identical to the oracle — on every engine.  The sharded engine
    runs its child under 4 forced devices (per-shard WALs), recovered
    elastically onto this process's single device."""
    n_dev = 4 if engine == "sharded" else 1
    results = crashkit.run_matrix(engine, str(tmp_path), n_devices=n_dev)
    assert len(results) == len(crashkit.matrix_points(engine, n_dev))
    # the post-checkpoint tail points actually replayed records
    by_point = {(r["point"], r["hits"]): r for r in results}
    assert by_point[("wal.append", 2)]["replayed_records"] >= 2


@pytest.mark.slow
def test_kill_recover_workload_replay(tmp_path):
    """ycsb_a kill-and-recover: replay half the stream, SIGKILL-equivalent
    abandon, recover, finish the stream on the recovered index — zero
    divergence from the oracle end to end."""
    rng = np.random.default_rng(11)
    keys = np.unique(rng.integers(0, 1 << 22, 3000)).astype(np.float64)
    ix = LearnedIndex.build(keys, config=IndexConfig(
        durability=DurabilityConfig(dir=str(tmp_path / "dur"),
                                    fsync="always")))
    spec = PRESETS["ycsb_a"].scaled(n_ops=3000, batch_size=128)
    batches = generate_stream(spec, keys)
    runner = WorkloadRunner(ix)
    out = runner.run_kill_recover(batches, kill_at=len(batches) // 2,
                                  spec=spec)
    runner.index.close()
    assert out["n_divergences"] == 0
    assert out["post_recovery_divergences"] == []
    assert out["recovery_s"] > 0


# ---------------------------------------------------------------------------
# hardened maintenance: bounded retries, degrade-to-sync, observability
# ---------------------------------------------------------------------------


def _flaky_merge_steps(oi, fail_times: int):
    """Wrap OnlineIndex._merge_steps to fail the first `fail_times` calls."""
    real = oi._merge_steps
    state = dict(left=fail_times, calls=0)

    def wrapped(*a, **kw):
        state["calls"] += 1
        if state["left"] > 0:
            state["left"] -= 1
            raise RuntimeError("injected merge fault")
        return real(*a, **kw)

    oi._merge_steps = wrapped
    return state


def test_background_merge_retries_then_succeeds():
    keys = np.arange(2048, dtype=np.float64)
    cfg = IndexConfig(maintenance=MaintenanceConfig(
        background=True, max_merge_retries=2, retry_backoff_s=0.001),
        telemetry=True)
    ix = LearnedIndex.build(keys, config=cfg)
    oi = ix._engine.oi
    state = _flaky_merge_steps(oi, fail_times=1)
    ix.upsert(keys[:600] + 0.5, np.arange(600, dtype=np.int64))
    st = ix.flush()                          # drains the worker
    assert state["calls"] >= 2               # failed once, retried, won
    assert st["pending_writes"] == 0
    assert not st["maint_degraded"]
    assert st["maint_errors"] == 0           # the retry succeeded: no
    #                                          scheduler-level failure
    m = ix.metrics()
    assert m["counters"]["maint.errors"] == 1
    assert m["spans"]["merge.failed"]["count"] == 1
    _, f = ix.lookup(keys[:600] + 0.5)
    assert f.all()
    ix.close()


def test_background_merge_exhaustion_degrades_to_sync():
    keys = np.arange(2048, dtype=np.float64)
    cfg = IndexConfig(maintenance=MaintenanceConfig(
        background=True, max_merge_retries=1, retry_backoff_s=0.001),
        telemetry=True)
    ix = LearnedIndex.build(keys, config=cfg)
    oi = ix._engine.oi
    state = _flaky_merge_steps(oi, fail_times=2)   # 1 + 1 retry both die
    ix.upsert(keys[:600] + 0.5, np.arange(600, dtype=np.int64))
    oi.merge("test")                         # submit to the worker
    oi.scheduler.drain()
    assert state["calls"] == 2
    st = ix.stats()
    assert st["maint_degraded"]
    assert st["maint_errors"] == 1           # one task failed after retries
    assert ix.metrics()["counters"]["maint.errors"] == 2
    # degraded => merges now run synchronously on the writer thread, and
    # the frozen overlay from the dead merge is reclaimed: still exact
    _, f = ix.lookup(keys[:600] + 0.5)
    assert f.all()
    st = ix.flush()
    assert st["pending_writes"] == 0 and st["maint_degraded"]
    _, f = ix.lookup(keys[:600] + 0.5)
    assert f.all()
    ix.close()


def test_sync_merge_failure_still_counts_errors():
    """The merge.failed span / maint.errors counter also fire on the
    synchronous path (no retries there: the caller sees the raise)."""
    keys = np.arange(1024, dtype=np.float64)
    ix = LearnedIndex.build(keys, config=IndexConfig(
        merge=manual_merge_policy(), telemetry=True,
        maintenance=MaintenanceConfig(max_merge_retries=3)))
    oi = ix._engine.oi
    state = _flaky_merge_steps(oi, fail_times=1)
    ix.upsert([0.5], [1])
    with pytest.raises(RuntimeError, match="injected merge fault"):
        ix.flush()
    assert state["calls"] == 1               # retry=False: no retry loop
    m = ix.metrics()
    assert m["counters"]["maint.errors"] == 1
    assert m["spans"]["merge.failed"]["count"] == 1
    assert not ix.stats()["maint_degraded"]
    assert ix.get(0.5) == 1                  # overlay still exact
    ix.flush()                               # next merge succeeds
    assert ix.stats()["pending_writes"] == 0
    ix.close()


# ---------------------------------------------------------------------------
# atomic save
# ---------------------------------------------------------------------------


def test_save_is_atomic_over_existing_file(tmp_path, monkeypatch):
    keys = np.arange(128, dtype=np.float64)
    ix = LearnedIndex.build(keys)
    path = str(tmp_path / "ix.npz")
    ix.save(path)
    before = open(path, "rb").read()

    def boom(*a, **kw):
        raise IOError("disk full")

    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(IOError):
        ix.save(path)
    # the old file is untouched and no tmp litter remains
    assert open(path, "rb").read() == before
    assert os.listdir(str(tmp_path)) == ["ix.npz"]
    rx = LearnedIndex.load(path)
    np.testing.assert_array_equal(rx.items()[0], keys)


def test_load_truncated_file_raises_not_garbage(tmp_path):
    keys = np.arange(128, dtype=np.float64)
    ix = LearnedIndex.build(keys)
    path = str(tmp_path / "ix.npz")
    ix.save(path)
    blob = open(path, "rb").read()
    open(path, "wb").write(blob[: len(blob) // 2])
    with pytest.raises(Exception):
        LearnedIndex.load(path)
