"""`repro.ft.checkpoint` corruption fallback: restore must walk past
flipped-byte shard payloads, mangled manifests, and stale `latest`
pointers to the newest checkpoint that still validates — and report
(None, None) only when nothing does.  (The atomic-publish helpers under
test here are shared with `repro.durability.checkpoint`.)"""
import json
import os

import numpy as np
import pytest

from repro.ft import checkpoint as ftck


def _state(step: int) -> dict:
    return dict(w=np.full((4, 3), float(step)),
                b=np.arange(3, dtype=np.float64) + step)


def _template() -> dict:
    return dict(w=np.zeros((4, 3)), b=np.zeros(3))


def _flip_byte(path: str, frac: float = 0.5) -> None:
    blob = bytearray(open(path, "rb").read())
    blob[int(len(blob) * frac)] ^= 0xFF
    open(path, "wb").write(bytes(blob))


def test_restore_skips_corrupt_shard_npz(tmp_path):
    d = str(tmp_path)
    ftck.save(d, 1, _state(1))
    ftck.save(d, 2, _state(2))
    _flip_byte(os.path.join(d, ftck.step_name(2), "shard_00000.npz"))
    state, manifest = ftck.restore(d, _template())
    assert manifest["step"] == 1
    np.testing.assert_array_equal(np.asarray(state["w"]),
                                  _state(1)["w"])


def test_restore_skips_mangled_manifest(tmp_path):
    d = str(tmp_path)
    ftck.save(d, 1, _state(1))
    ftck.save(d, 2, _state(2))
    with open(os.path.join(d, ftck.step_name(2), "manifest.json"), "w") as f:
        f.write("{not json")
    state, manifest = ftck.restore(d, _template())
    assert manifest["step"] == 1
    np.testing.assert_array_equal(np.asarray(state["b"]),
                                  _state(1)["b"])


def test_restore_checksum_catches_inplace_bitflip(tmp_path):
    """A flip INSIDE an array payload that still unzips must fail the
    per-leaf CRC, not silently restore wrong weights."""
    d = str(tmp_path)
    ftck.save(d, 1, _state(1))
    ftck.save(d, 2, _state(2))
    npz = os.path.join(d, ftck.step_name(2), "shard_00000.npz")
    # rewrite the npz uncompressed with one poisoned leaf: valid zip,
    # wrong bytes — only the manifest checksum can catch it
    data = dict(np.load(npz))
    data["leaf_00000"] = data["leaf_00000"].copy()
    data["leaf_00000"].flat[0] += 1.0
    np.savez(npz, **data)
    state, manifest = ftck.restore(d, _template())
    assert manifest["step"] == 1


def test_restore_ignores_stale_latest_pointer(tmp_path):
    d = str(tmp_path)
    ftck.save(d, 1, _state(1))
    ftck.save(d, 2, _state(2))
    ftck.write_latest(d, ftck.step_name(7))       # names a missing step
    state, manifest = ftck.restore(d, _template())
    assert manifest["step"] == 2


def test_restore_nothing_valid_returns_none(tmp_path):
    d = str(tmp_path)
    assert ftck.restore(d, _template()) == (None, None)   # no dir at all
    ftck.save(d, 1, _state(1))
    _flip_byte(os.path.join(d, ftck.step_name(1), "shard_00000.npz"))
    assert ftck.restore(d, _template()) == (None, None)


def test_tmp_dirs_are_never_candidates(tmp_path):
    """A crashed writer's `.tmp` staging dir must not shadow the newest
    published step (the pre-publish crash state)."""
    d = str(tmp_path)
    ftck.save(d, 1, _state(1))
    tmp = ftck.make_tmp_dir(d, ftck.step_name(2))
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(dict(step=2), f)
    assert ftck.step_candidates(d) == [ftck.step_name(1)]
    _, manifest = ftck.restore(d, _template())
    assert manifest["step"] == 1
