"""Observability tentpole tests (DESIGN.md section 13 extensions): the
`dili.inspect/1` index-health document (identical key tree on all three
engines, sane values), end-to-end causal tracing (serve request ->
queue_wait -> exec -> facade op -> WAL append with linked merge spans,
exported as Chrome-trace-event JSON), and the perf-regression sentinel
(benchmarks/sentinel.py band logic + artifact self-test)."""

import importlib.util
import json
import os
import sys

import numpy as np
import pytest

from repro.api import (DurabilityConfig, IndexConfig, LearnedIndex,
                       MaintenanceConfig)
from repro.obs import (INSPECT_SCHEMA_VERSION, TRACE_SCHEMA_VERSION,
                       TraceBuffer, current_trace_ids, mint_trace_id,
                       trace_context)

ENGINES = ("local", "pallas", "sharded")
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _universe(n=4096, seed=0):
    rng = np.random.default_rng(seed)
    keys = np.unique(rng.integers(1, 10 * n, n)).astype(np.float64)
    return keys, np.arange(len(keys), dtype=np.int64)


def _churn(ix, keys, seed=2, rounds=4):
    """Write/merge churn so inspect has segments/heat/overlay to report."""
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        ks = rng.integers(1, 10 * len(keys), 512).astype(np.float64)
        ix.upsert(ks, np.arange(512))
        ix.delete(ks[:32])
    ix.flush()
    ix.lookup(keys[:128])


# -- inspect ------------------------------------------------------------------


def _shape(d, prefix=""):
    """Dotted key paths; lists are leaves (depth_hist length may differ
    across engines — the CONTRACT is the key tree, not list lengths)."""
    out = []
    for k in sorted(d):
        out.append(prefix + k)
        if isinstance(d[k], dict):
            out += _shape(d[k], prefix + k + ".")
    return out


def test_inspect_key_tree_identical_across_engines():
    """Pinned acceptance criterion: `LearnedIndex.inspect()` returns the
    same `dili.inspect/1` key tree on local, pallas, and sharded."""
    keys, vals = _universe()
    shapes, docs = {}, {}
    for engine in ENGINES:
        ix = LearnedIndex.build(keys, vals, config=IndexConfig(
            engine=engine, telemetry=True, overlay_cap=1024))
        _churn(ix, keys)
        doc = ix.inspect()
        json.dumps(doc)                       # JSON-able end to end
        assert doc["schema"] == INSPECT_SCHEMA_VERSION
        assert doc["engine"] == engine
        shapes[engine] = _shape(doc)
        docs[engine] = doc
        ix.close()
    assert shapes["local"] == shapes["pallas"] == shapes["sharded"]
    # one flat per shard (a single-device host runs the sharded engine
    # with one shard — the key-tree contract is what's pinned here)
    assert docs["sharded"]["n_shards"] >= 1
    assert docs["local"]["n_shards"] == 1


def test_inspect_values_sane():
    keys, vals = _universe()
    ix = LearnedIndex.build(keys, vals, config=IndexConfig(
        engine="local", telemetry=True, overlay_cap=1024,
        maintenance=MaintenanceConfig(retrain=False, recluster=True)))
    _churn(ix, keys)
    doc = ix.inspect()
    t, lv = doc["tree"], doc["leaves"]
    # every node has exactly one depth; the histogram partitions them
    assert sum(t["depth_hist"]) == t["n_nodes"]
    # max_depth is the snapshot's traversal bound; the realized node
    # depths can sit strictly under it
    assert 1 <= len(t["depth_hist"]) <= t["max_depth"] + 1
    assert t["n_pairs"] >= len(keys)
    assert lv["n_leaves"] + lv["n_internal"] == t["n_nodes"]
    assert 0.0 <= lv["fill"]["p50"] <= lv["fill"]["max"] <= 1.0
    me = doc["model_error"]
    assert 0 < me["sampled"] <= t["n_pairs"]
    # leaf models predict within the leaf by construction
    assert me["overall"]["max"] <= t["n_slots"]
    seg = doc["segments"]
    assert seg["n_segments"] > 0
    assert seg["dirty_rows"] <= seg["total_rows"]
    assert 0.0 <= seg["dirty_fraction"] <= 1.0
    # churn wrote through the accounting: heat must be populated
    assert doc["heat"]["n_tracked"] > 0
    assert doc["heat"]["writes"]["max"] >= 1
    ov = doc["overlay"]
    assert ov["cap"] == 1024 and ov["pending"] == 0    # post-flush
    assert not doc["wal"]["armed"]                      # durability off
    # the cheap publish-time sample landed in the metrics gauges too
    g = ix.metrics()["gauges"]
    assert g["inspect.total_rows"] > 0
    assert 0.0 <= g["inspect.dirty_fraction"] <= 1.0
    ix.close()


def test_inspect_wal_block_when_armed(tmp_path):
    keys, vals = _universe(n=1024, seed=4)
    ix = LearnedIndex.build(keys, vals, config=IndexConfig(
        engine="local", overlay_cap=256,
        durability=DurabilityConfig(dir=str(tmp_path / "dur"),
                                    fsync="always")))
    ix.upsert(keys[:64] + 0.0, np.arange(64))
    doc = ix.inspect()
    w = doc["wal"]
    assert w["armed"] and w["n_shards"] == 1
    assert w["wal_bytes"] > 0 and w["n_wal_files"] >= 1
    ix.close()


# -- causal tracing -----------------------------------------------------------


def test_trace_context_propagation():
    assert current_trace_ids() == ()
    a, b = mint_trace_id(), mint_trace_id()
    assert a != b
    with trace_context((a, b)):
        assert current_trace_ids() == (a, b)
        with trace_context((b,)):
            assert current_trace_ids() == (b,)
        assert current_trace_ids() == (a, b)
    assert current_trace_ids() == ()


def test_trace_buffer_export_shape(tmp_path):
    buf = TraceBuffer()
    buf.add("quiet", t0=0.0, dur_s=1e-3, track="t")     # disarmed: dropped
    buf.arm()
    tid = mint_trace_id()
    buf.add("serve.request", t0=1.0, dur_s=5e-3, track="client:a",
            trace_ids=(tid,), anchor=True, op="lookup")
    buf.add("op.lookup", t0=1.002, dur_s=1e-3, track="facade",
            trace_ids=(tid,), n_ops=64)
    path = str(tmp_path / "t.json")
    buf.dump(path)
    doc = json.load(open(path))
    assert doc["otherData"]["schema"] == TRACE_SCHEMA_VERSION
    assert doc["otherData"]["n_exported"] == 2
    ev = doc["traceEvents"]
    slices = [e for e in ev if e["ph"] == "X"]
    assert {e["name"] for e in slices} == {"serve.request", "op.lookup"}
    for e in slices:
        assert e["pid"] == 1 and e["dur"] > 0
        assert tid in e["args"]["trace_ids"]
    # one flow anchor on the request slice, one step on the facade slice
    assert sum(e["ph"] == "s" for e in ev) == 1
    assert sum(e["ph"] == "t" for e in ev) == 1
    # distinct tracks -> distinct tids with thread_name metadata
    meta = {e["args"]["name"] for e in ev if e["ph"] == "M"
            and e["name"] == "thread_name"}
    assert {"client:a", "facade"} <= meta


def test_traced_serve_request_end_to_end(tmp_path):
    """The ISSUE's acceptance trace: a ycsb_a serve leg with durability
    armed exports serve.request -> serve.queue_wait -> serve.exec ->
    facade op -> wal.append, with merge spans from the writes it
    triggered in the same timeline, all flow-linked by trace id."""
    from repro.serve import ServeFrontend, open_loop
    from repro.workloads import PRESETS, generate_stream
    keys, vals = _universe()
    ix = LearnedIndex.build(keys, vals, config=IndexConfig(
        engine="local", telemetry=True, overlay_cap=256,
        maintenance=MaintenanceConfig(background=False),
        durability=DurabilityConfig(dir=str(tmp_path / "dur"),
                                    fsync="interval")))
    spec = PRESETS["ycsb_a"].scaled(n_ops=2000, batch_size=64)
    batches = list(generate_stream(spec, keys))
    path = str(tmp_path / "serve_trace.json")
    with ServeFrontend(ix, journal=False) as fe:
        rep = open_loop(fe, batches, 50_000.0, n_clients=2,
                        trace_path=path)
    assert rep.failed_ops == 0
    assert ix.stats()["n_merges"] >= 1       # writes crossed the cap
    ix.close()

    doc = json.load(open(path))
    assert doc["otherData"]["schema"] == TRACE_SCHEMA_VERSION
    ev = doc["traceEvents"]
    slices = [e for e in ev if e["ph"] == "X"]
    names = {e["name"] for e in slices}
    for want in ("serve.request", "serve.queue_wait", "serve.exec",
                 "op.lookup", "op.upsert", "wal.append", "merge.fold",
                 "merge.publish"):
        assert want in names, (want, sorted(names))
    # causal linkage: some trace id minted at submit appears on a
    # serve.exec slice AND on the wal.append the dispatch performed,
    # and the sync merge ran inside the dispatch's trace context
    def ids(name):
        out = set()
        for e in slices:
            if e["name"] == name:
                out.update(e["args"].get("trace_ids", ()))
        return out
    assert ids("serve.exec") & ids("wal.append")
    assert ids("serve.exec") & ids("merge.publish")
    # flow events stitch the chain (anchors on the request slices)
    assert any(e["ph"] == "s" for e in ev)
    assert any(e["ph"] == "t" for e in ev)
    # timestamps are normalized microseconds on slices
    assert all(e["ts"] >= 0 for e in slices)


def test_dump_trace_facade_only(tmp_path):
    """`LearnedIndex.start_trace/dump_trace` works without a serve
    front-end: direct facade calls land as op.* slices."""
    keys, vals = _universe(n=1024, seed=5)
    ix = LearnedIndex.build(keys, vals, config=IndexConfig(
        engine="pallas", telemetry=True))
    ix.start_trace()
    ix.lookup(keys[:64])
    ix.upsert(keys[:16] + 0.0, np.arange(16))
    ix.stop_trace()
    path = str(tmp_path / "f.json")
    meta = ix.dump_trace(path)
    doc = json.load(open(path))
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert {"op.lookup", "op.upsert"} <= names
    assert meta["n_exported"] >= 2
    # disarmed again: further ops don't grow the buffer
    n = ix.telemetry.trace.n_events
    ix.lookup(keys[:64])
    assert ix.telemetry.trace.n_events == n
    ix.close()


# -- perf-regression sentinel -------------------------------------------------


def _sentinel():
    spec = importlib.util.spec_from_file_location(
        "sentinel", os.path.join(REPO, "benchmarks", "sentinel.py"))
    mod = importlib.util.module_from_spec(spec)
    # dataclass field-annotation resolution needs the module registered
    sys.modules["sentinel"] = mod
    spec.loader.exec_module(mod)
    return mod


def _doc(**vals):
    sec = dict(ns_per_query=100.0, us_per_op=10.0,
               latency_ms=dict(lookup=dict(count=50, ms_p50=1.0,
                                           ms_p99=5.0)),
               ops_per_s=30_000.0, n_merges=7, n_keys=300_000)
    sec.update(vals)
    return dict(n_keys=300_000, sections={"workload,x": sec})


def test_sentinel_band_logic():
    s = _sentinel()
    base = _doc()
    # identical -> clean
    deltas, _ = s.compare(base, _doc())
    assert deltas and all(d.ok for d in deltas)
    # 2x median -> flagged; counts never compared
    deltas, _ = s.compare(base, _doc(ns_per_query=200.0, n_merges=700))
    bad = [d for d in deltas if not d.ok]
    assert [d.path for d in bad] == ["workload,x.ns_per_query"]
    assert not any("n_merges" in d.path for d in deltas)
    # tails get the loose band: 2x p99 ok, 4x flagged
    nested = dict(lookup=dict(count=50, ms_p50=1.0, ms_p99=10.0))
    assert all(d.ok for d in s.compare(base, _doc(latency_ms=nested))[0])
    nested = dict(lookup=dict(count=50, ms_p50=1.0, ms_p99=20.0))
    bad = [d for d in s.compare(base, _doc(latency_ms=nested))[0]
           if not d.ok]
    assert [d.path for d in bad] == \
        ["workload,x.latency_ms.lookup.ms_p99"]
    # throughput judged inverted: halving ops_per_s is a regression
    bad = [d for d in s.compare(base, _doc(ops_per_s=15_000.0))[0]
           if not d.ok]
    assert [d.path for d in bad] == ["workload,x.ops_per_s"]
    assert bad[0].kind == "thrpt"
    # scale mismatch skips the section wholesale
    fresh = _doc()
    fresh["sections"]["workload,x"]["n_keys"] = 10_000_000
    deltas, notes = s.compare(base, fresh)
    assert not deltas and any("scale mismatch" in n for n in notes)


def test_sentinel_self_test_on_checked_in_artifact(capsys):
    """The CI tripwire end to end: the repo's own BENCH_PR2.json must
    pass against itself and catch an injected 2x median regression."""
    s = _sentinel()
    with open(os.path.join(REPO, "BENCH_PR2.json")) as fh:
        baseline = json.load(fh)
    rc = s.self_test(baseline, median_band=1.6, tail_band=3.0)
    out = capsys.readouterr().out
    assert rc == 0, out
    assert "self-test PASS" in out


def test_sentinel_cli_exit_codes(tmp_path, capsys):
    s = _sentinel()
    bp = tmp_path / "base.json"
    fp = tmp_path / "fresh.json"
    bp.write_text(json.dumps(_doc()))
    fp.write_text(json.dumps(_doc(ns_per_query=500.0)))
    assert s.main(["--baseline", str(bp), "--fresh", str(bp)]) == 0
    assert s.main(["--baseline", str(bp), "--fresh", str(fp)]) == 1
    out = capsys.readouterr().out
    assert "REGRESSION" in out and "ns_per_query" in out
    # widened band clears it
    assert s.main(["--baseline", str(bp), "--fresh", str(fp),
                   "--median-band", "6.0"]) == 0
