"""Pallas kernel validation: interpret-mode vs ref.py oracle vs host truth,
swept over shapes/dtypes/distributions (per the kernel-testing contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.flat import flatten
from repro.kernels import ops
from repro.kernels.dili_search import dili_search_pallas
from repro.kernels.ref import dili_search_ref
from tests.conftest import make_keys


def build(dist, n, seed=21):
    rng = np.random.default_rng(seed)
    keys = make_keys(dist, n, rng)
    d, keys32 = ops.build_f32_index(keys)
    f = flatten(d)
    return keys32, f, ops.kernel_arrays(f)


@pytest.mark.parametrize("dist", ["logn", "uniform", "fb", "wikits"])
@pytest.mark.parametrize("n", [2000, 30000])
def test_kernel_matches_truth(dist, n):
    keys32, f, arrs = build(dist, n)
    rng = np.random.default_rng(22)
    qi = rng.integers(0, len(keys32), 4096)
    q = jnp.asarray(keys32[qi], jnp.float32)
    v, fnd = ops.dili_search(arrs, q)
    v, fnd = np.asarray(v), np.asarray(fnd)
    assert fnd.all()
    assert np.array_equal(v, qi)


@pytest.mark.parametrize("block_q", [512, 2048])
def test_kernel_matches_ref_oracle(block_q):
    keys32, f, arrs = build("logn", 20000)
    rng = np.random.default_rng(23)
    qi = rng.integers(0, len(keys32), 4096)
    q = jnp.asarray(keys32[qi], jnp.float32)
    vk, fk, fbk = dili_search_pallas(
        arrs["a"], arrs["b"], arrs["base"], arrs["fo"], arrs["dense"],
        arrs["tag"], arrs["key"], arrs["val"], arrs["root"], q,
        max_depth=f.max_depth, interpret=True, block_q=block_q)
    vr, fr, fbr = dili_search_ref(
        arrs["a"], arrs["b"], arrs["base"], arrs["fo"], arrs["dense"],
        arrs["tag"], arrs["key"], arrs["val"], arrs["root"][0], q,
        f.max_depth)
    np.testing.assert_array_equal(np.asarray(vk), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(fk), np.asarray(fr))
    np.testing.assert_array_equal(np.asarray(fbk), np.asarray(fbr))


def test_kernel_misses_no_false_positives():
    keys32, f, arrs = build("uniform", 20000)
    rng = np.random.default_rng(24)
    qi = rng.integers(0, len(keys32) - 1, 2048)
    mids = ((keys32[qi].astype(np.float64)
             + keys32[qi + 1].astype(np.float64)) / 2).astype(np.float32)
    ok = (mids != keys32[qi]) & (mids != keys32[qi + 1])
    v, fnd = ops.dili_search(arrs, jnp.asarray(mids))
    assert not np.asarray(fnd)[ok].any()


def test_kernel_pads_ragged_batch():
    keys32, f, arrs = build("logn", 5000)
    q = jnp.asarray(keys32[:777], jnp.float32)      # not a block multiple
    v, fnd = ops.dili_search(arrs, q)
    assert np.asarray(fnd).all()
    assert np.array_equal(np.asarray(v), np.arange(777))


def test_vmem_budget_fallback_path():
    """Oversized tables must route to the XLA path and stay correct."""
    keys32, f, arrs = build("uniform", 30000)
    import repro.kernels.ops as O
    old = O.VMEM_BUDGET_BYTES
    try:
        O.VMEM_BUDGET_BYTES = 1   # force fallback
        q = jnp.asarray(keys32[:1024], jnp.float32)
        v, fnd = O.dili_search(arrs, q)
        assert np.asarray(fnd).all()
        assert np.array_equal(np.asarray(v), np.arange(1024))
    finally:
        O.VMEM_BUDGET_BYTES = old
