"""The adaptive maintenance subsystem (DESIGN.md section 12).

Three layers again, cheapest first: the incremental flattener's exactness
contract (splice == full `flatten()`, bit for bit, across random
upsert/delete folds — deterministic grid plus a hypothesis property test),
then the drift/tombstone accounting and local retrains in isolation, then
the concurrency acceptance: reader threads hammer lookups while background
merges fold/retrain/publish, and every answer is diffed against the
ground truth.
"""
import threading

import numpy as np
import pytest

from repro.api import IndexConfig, LearnedIndex, MaintenanceConfig
from repro.core.dili import (Internal, bulk_load, collect_pairs,
                             rebuild_subtree, split_leaf)
from repro.core.flat import flatten
from repro.maintain import (IncrementalFlattener, LeafAccounting,
                            MaintenanceScheduler, ks_uniform, leaf_drift)
from repro.online import MergePolicy, OnlineIndex
from repro.workloads import (PRESETS, SortedOracle, WorkloadRunner,
                             generate_stream)

FLAT_FIELDS = ("a", "b", "base", "fo", "dense", "tag", "key", "val",
               "pair_key", "pair_val", "pair_slot")


def assert_flat_identical(got, want, msg=""):
    for f in FLAT_FIELDS:
        g, w = getattr(got, f), getattr(want, f)
        assert g.dtype == w.dtype, (msg, f, g.dtype, w.dtype)
        np.testing.assert_array_equal(g, w, err_msg=f"{msg}: {f}")
    assert (got.root, got.max_depth) == (want.root, want.max_depth), msg
    assert (got.key_lo, got.key_hi) == (want.key_lo, want.key_hi), msg


def _irregular_keys(rng, n=8000):
    # irregular gaps => a genuinely multi-segment tree (uniform integer
    # keys collapse into one perfect leaf and prove nothing)
    return np.unique(rng.integers(0, 1 << 22, n)).astype(np.float64)


# ---------------------------------------------------------------------------
# incremental flattener: bit-identity
# ---------------------------------------------------------------------------


def test_splice_flatten_bit_identical_across_folds():
    """Cold build, random upsert/delete/update rounds, and retrains: after
    every round the splice output must equal a from-scratch flatten()."""
    rng = np.random.default_rng(0)
    keys = _irregular_keys(rng)
    d = bulk_load(keys, sample_stride=2)
    fl = IncrementalFlattener()
    assert_flat_identical(fl.flatten(d, d.take_dirty()), flatten(d), "cold")
    assert not fl.last_incremental

    for step in range(4):
        ins = np.setdiff1d(rng.integers(0, 1 << 22, 250).astype(np.float64),
                           keys)
        for j, k in enumerate(ins):
            d.upsert(float(k), 10_000 + j)
        for k in keys[rng.integers(0, len(keys), 80)]:
            d.delete(float(k))
        for j, k in enumerate(keys[rng.integers(0, len(keys), 150)]):
            d.upsert(float(k), 20_000 + j)
        assert_flat_identical(fl.flatten(d, d.take_dirty()), flatten(d),
                              f"fold{step}")
        assert fl.last_incremental
        assert fl.n_fallback_full == 0
        assert fl.last_dirty_segments < fl.last_total_segments

    # retrains swap whole subtrees (possibly Internal-rooted): the cache
    # must miss on identity and the splice must stay exact
    tops = (d.root.children if isinstance(d.root, Internal) else [d.root])
    rebuilt = 0
    for c in list(tops):
        if not isinstance(c, Internal) and c.omega >= 2:
            assert rebuild_subtree(d, c) is not None
            rebuilt += 1
        if rebuilt == 4:
            break
    assert rebuilt
    assert_flat_identical(fl.flatten(d, d.take_dirty()), flatten(d),
                          "retrain")
    assert fl.n_fallback_full == 0


def test_splice_flatten_search_serves_identically():
    """The spliced snapshot is not just array-equal — it answers device
    lookups and ranges identically (belt to the braces above)."""
    from repro.api import DeviceSnapshot
    from repro.core import search as S
    import jax.numpy as jnp
    rng = np.random.default_rng(1)
    keys = _irregular_keys(rng, 4000)
    d = bulk_load(keys)
    fl = IncrementalFlattener()
    fl.flatten(d, d.take_dirty())
    for j, k in enumerate(keys[rng.integers(0, len(keys), 400)]):
        d.upsert(float(k), 90_000 + j)
    inc = fl.flatten(d, d.take_dirty())
    idx = DeviceSnapshot.from_flat(inc)
    q = jnp.asarray(keys[rng.integers(0, len(keys), 2048)])
    v, f = S.search_batch(idx, q, early_exit=True)
    assert bool(np.asarray(f).all())
    host = [d.search(float(x)) for x in np.asarray(q)[:64]]
    np.testing.assert_array_equal(np.asarray(v)[:64], host)


def test_splice_flatten_property():
    """Hypothesis sweep: arbitrary interleaved upsert/delete folds at
    arbitrary fold boundaries never break bit-identity."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    base = np.unique(np.random.default_rng(3)
                     .integers(0, 1 << 20, 1500)).astype(np.float64)

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.tuples(st.sampled_from(["upsert", "delete", "fold",
                                               "split"]),
                              st.integers(0, 1 << 20)),
                    min_size=1, max_size=60),
           st.integers(0, 2 ** 31 - 1))
    def run(ops, seed):
        d = bulk_load(base)
        fl = IncrementalFlattener()
        fl.flatten(d, d.take_dirty())
        for i, (op, k) in enumerate(ops):
            if op == "upsert":
                d.upsert(float(k), i)
            elif op == "delete":
                d.delete(float(k))
            elif op == "split":
                # the re-clustering mutation, at arbitrary points in the
                # op stream; alternate dirty-marking because both paths
                # must splice exactly (production marks via the fold, but
                # identity-miss alone has to carry it too)
                tops = (d.root.children if isinstance(d.root, Internal)
                        else [d.root])
                cands = [c for c in tops
                         if not isinstance(c, Internal) and c.omega >= 4]
                if cands:
                    leaf = cands[k % len(cands)]
                    if split_leaf(d, leaf, 2 + k % 7) is not None \
                            and k % 2:
                        d.dirty_ids.add(id(leaf))
            else:
                assert_flat_identical(fl.flatten(d, d.take_dirty()),
                                      flatten(d), f"fold@{i}")
        assert_flat_identical(fl.flatten(d, d.take_dirty()), flatten(d),
                              "final")

    run()


# ---------------------------------------------------------------------------
# accounting + retrain
# ---------------------------------------------------------------------------


def test_ks_uniform_statistic():
    assert ks_uniform(np.zeros(0)) == 0.0
    # uniform grid: tiny distance; point mass: distance -> 1
    assert ks_uniform(np.linspace(0.01, 0.99, 100)) < 0.05
    assert ks_uniform(np.full(100, 0.5)) > 0.45


def test_drift_triggers_retrain_and_restores_layout():
    """Clustered arrivals into one leaf's region cross the KS threshold,
    the planner flags exactly that region, and the rebuild re-runs the
    top-down individualization (new node object, search stays exact)."""
    rng = np.random.default_rng(4)
    keys = _irregular_keys(rng, 6000)
    cfg = MaintenanceConfig(retrain_min_writes=32, drift_threshold=0.35)
    oi = OnlineIndex(keys, policy=MergePolicy(max_writes=1 << 40,
                                              pressure_check_every=1 << 40),
                     overlay_cap=1 << 14, maintenance=cfg)
    # hammer one narrow band with fresh keys (heavy one-sided drift)
    lo = float(keys[len(keys) // 2])
    band = np.setdiff1d(np.arange(lo + 1, lo + 400, 3, dtype=np.float64),
                        keys)
    oi.upsert_batch(band, np.arange(len(band)))
    oi.flush()
    assert oi.n_retrains >= 1
    assert oi.n_incremental_flattens >= 1
    # exactness after the rebuild, via the published snapshot
    v, f = oi.lookup(band[:64])
    assert bool(np.asarray(f).all())
    v, f = oi.lookup(keys[:256])
    assert bool(np.asarray(f).all())


def test_tombstone_density_triggers_compaction():
    rng = np.random.default_rng(5)
    keys = _irregular_keys(rng, 6000)
    cfg = MaintenanceConfig(retrain_min_writes=16, tombstone_trigger=0.2,
                            drift_threshold=2.0)     # drift path disabled
    oi = OnlineIndex(keys, policy=MergePolicy(max_writes=1 << 40,
                                              pressure_check_every=1 << 40),
                     overlay_cap=1 << 14, maintenance=cfg)
    # delete every other key of a wide slice: the touched leaves end up
    # ~50% tombstones but keep enough live pairs to be worth rebuilding
    victims = keys[100: 1124: 2]
    oi.delete_batch(victims)
    oi.flush()
    assert oi.n_retrains >= 1
    _, f = oi.lookup(victims[:64])
    assert not np.asarray(f).any()


def test_leaf_drift_uniform_arrivals_low():
    d = bulk_load(np.unique(np.random.default_rng(6)
                            .integers(0, 1 << 20, 4000)).astype(np.float64))
    leaf, _ = d.locate_leaf(1000.0)
    from repro.core.dili import collect_pairs
    ks = [p[0] for p in collect_pairs(leaf)]
    assert leaf_drift(leaf, ks) < 0.3       # own keys: no drift


# ---------------------------------------------------------------------------
# locality re-clustering (the zipfian splice-locality pathology)
# ---------------------------------------------------------------------------


def _top_leaves(d):
    tops = (d.root.children if isinstance(d.root, Internal) else [d.root])
    return [c for c in tops if not isinstance(c, Internal)]


def test_split_leaf_bit_identity_and_refusals():
    """`split_leaf` is splice-compatible: one parent pointer swap, the
    splice stays bit-identical to a full flatten, the segment count grows
    by the fanout, and every key keeps resolving.  Degenerate inputs are
    refused (None) without touching the tree."""
    rng = np.random.default_rng(11)
    keys = _irregular_keys(rng, 8000)
    d = bulk_load(keys, sample_stride=2)
    fl = IncrementalFlattener()
    f0 = fl.flatten(d, d.take_dirty())
    cands = [c for c in _top_leaves(d) if c.omega >= 32]
    assert cands, "irregular build must leave a splittable top-level leaf"
    leaf = max(cands, key=lambda c: c.omega)
    before = {float(p[0]): p[1] for p in collect_pairs(leaf)}
    assert split_leaf(d, leaf, 1) is None          # fanout < 2
    node = split_leaf(d, leaf, 8)
    assert node is not None and len(node.children) == 8
    assert split_leaf(d, leaf, 8) is None          # already replaced
    d.dirty_ids.add(id(leaf))                      # what the fold would do
    f1 = fl.flatten(d, d.take_dirty())
    assert fl.n_fallback_full == 0 and fl.last_incremental
    assert_flat_identical(f1, flatten(d), "post-split")
    assert f1.n_segments >= f0.n_segments + 7      # one seg -> 8 children
    for k, v in before.items():
        assert d.search(k) == v


def test_recluster_pipeline_splits_hot_segment_and_cuts_dirty_rows():
    """End-to-end through `OnlineIndex`: the same few keys written across
    consecutive merges mark one big leaf persistently hot; the merge
    pipeline splits it (n_reclusters >= 1) and later merges re-flatten a
    small child instead of the whole segment, while the published
    snapshot stays bit-identical to a full flatten and reads stay exact."""
    rng = np.random.default_rng(12)
    keys = _irregular_keys(rng, 16000)
    cfg = MaintenanceConfig(retrain=False, recluster_hot_streak=2,
                            recluster_min_rows=64, recluster_target_pairs=8,
                            recluster_max_per_merge=64)
    oi = OnlineIndex(keys, sample_stride=2, overlay_cap=1 << 14,
                     policy=MergePolicy(max_writes=1 << 40,
                                        pressure_check_every=1 << 40),
                     maintenance=cfg)
    leaf = max(_top_leaves(oi.dili), key=lambda c: c.omega)
    # rows (slot count, >= fanout) drive the planner, not omega; the
    # biggest leaf here flattens to well over recluster_min_rows slots
    assert leaf.omega >= 32, "need one big segment to make the point"
    lk = np.array([p[0] for p in collect_pairs(leaf)], np.float64)
    hot = lk[:: max(1, len(lk) // 4)][:4]          # few keys, one segment
    rows = []
    for r in range(4):
        oi.upsert_batch(hot, np.full(len(hot), 1000 + r, np.int64))
        oi.flush()
        rows.append(oi.flattener.last_dirty_rows)
    # merge 1 seeds the cache (full flatten); merge 2 crosses the streak
    # threshold and splits; merges 3+ dirty only the hot children
    assert oi.n_reclusters >= 1
    assert rows[-1] < rows[1], rows
    assert oi.flattener.n_fallback_full == 0
    assert_flat_identical(oi.store.flat, flatten(oi.dili), "recluster")
    v, f = oi.lookup(hot)
    assert np.asarray(f).all()
    np.testing.assert_array_equal(np.asarray(v), np.full(len(hot), 1003))
    v, f = oi.lookup(lk[:128])
    assert np.asarray(f).all()


def test_recluster_respects_budget_and_min_rows():
    """Planner contract: segments below `recluster_min_rows` never
    qualify, and one merge never splits more than
    `recluster_max_per_merge` leaves."""
    rng = np.random.default_rng(13)
    keys = _irregular_keys(rng, 16000)
    cfg = MaintenanceConfig(retrain=False, recluster_hot_streak=1,
                            recluster_min_rows=1 << 30,
                            recluster_target_pairs=8)
    oi = OnlineIndex(keys, sample_stride=2, overlay_cap=1 << 14,
                     policy=MergePolicy(max_writes=1 << 40,
                                        pressure_check_every=1 << 40),
                     maintenance=cfg)
    for r in range(3):
        oi.upsert_batch(keys[::97], np.full(len(keys[::97]), r, np.int64))
        oi.flush()
    assert oi.n_reclusters == 0                    # nothing is big enough
    cfg2 = MaintenanceConfig(retrain=False, recluster_hot_streak=1,
                             recluster_min_rows=16,
                             recluster_target_pairs=4,
                             recluster_max_per_merge=2)
    oi2 = OnlineIndex(keys, sample_stride=2, overlay_cap=1 << 14,
                      policy=MergePolicy(max_writes=1 << 40,
                                         pressure_check_every=1 << 40),
                      maintenance=cfg2)
    seen = 0
    for r in range(2):      # the build publish already seeded row counts
        oi2.upsert_batch(keys[::97], np.full(len(keys[::97]), r, np.int64))
        oi2.flush()
        delta = oi2.n_reclusters - seen
        seen = oi2.n_reclusters
        assert delta <= 2, delta                   # per-merge budget
    assert seen >= 1
    assert_flat_identical(oi2.store.flat, flatten(oi2.dili), "budget")


def test_unmappable_dirty_id_counts_forced_full_flatten():
    """Satellite regression: an id the flattener cannot map to a segment
    (leaked plumbing) falls back to a FULL re-flatten, and that event is
    counted distinctly — `n_forced_full_flattens` in stats(), separate
    from intentional full flattens — so the O(dirty) guarantee silently
    degrading is observable."""
    U = np.arange(0, 8000, 2, dtype=np.float64)
    ix = LearnedIndex.build(U, config=IndexConfig(
        engine="local", overlay_cap=1 << 14,
        merge=MergePolicy(max_writes=1 << 40, pressure_check_every=1 << 40),
        maintenance=MaintenanceConfig()))
    oi = ix._engine.oi
    ix.upsert(np.arange(1, 101, 2, dtype=np.float64),
              np.arange(50, dtype=np.int64))
    ix.flush()                                     # seeds the segment cache
    assert ix.stats()["n_forced_full_flattens"] == 0
    ix.upsert(np.arange(101, 201, 2, dtype=np.float64),
              np.arange(50, dtype=np.int64))
    oi.dili.dirty_ids.add(12345)                   # stale / leaked id
    ix.flush()
    s = ix.stats()
    assert s["n_forced_full_flattens"] == 1
    assert oi.flattener.n_fallback_full == 1
    # the degraded merge still published exactly, and the next clean
    # merge goes back to splicing without growing the forced-full count
    v, f = ix.lookup(np.arange(101, 201, 2, dtype=np.float64))
    assert f.all()
    ix.upsert(np.arange(201, 301, 2, dtype=np.float64),
              np.arange(50, dtype=np.int64))
    ix.flush()
    s = ix.stats()
    assert s["n_forced_full_flattens"] == 1
    assert s["n_incremental_flattens"] >= 1
    ix.close()


@pytest.mark.slow
def test_zipfian_recluster_bounds_dirty_fraction_at_1m():
    """The PR's acceptance pathology in miniature: 1M int64-valued keys,
    scrambled-zipfian updates (YCSB draw: zipfian ranks through the Knuth
    hash scatter, theta=0.99) folded across 12 merges.  Hashed skew
    spreads the hot set over every segment, so without re-clustering
    nearly every row re-flattens per merge (dirty fraction ~1); with it
    the mean must stay <= 0.25 and splits must actually happen."""
    from repro.workloads.distributions import (DEFAULT_THETA, ZetaCache,
                                               scatter_ranks, zipfian_ranks)
    n = 1_000_000
    keys = np.arange(0, 2 * n, 2, dtype=np.float64)
    cfg = MaintenanceConfig(recluster_hot_streak=1, recluster_min_rows=512,
                            recluster_target_pairs=128,
                            recluster_max_per_merge=4096)
    oi = OnlineIndex(keys, sample_stride=4, overlay_cap=1 << 15,
                     policy=MergePolicy(max_writes=1 << 40,
                                        pressure_check_every=1 << 40),
                     maintenance=cfg)
    rng = np.random.default_rng(23)
    zeta = ZetaCache(DEFAULT_THETA)
    fracs = []
    for _ in range(12):
        idx = scatter_ranks(
            zipfian_ranks(rng, n, 2048, DEFAULT_THETA, zeta), n)
        oi.upsert_batch(keys[idx], idx.astype(np.int64))
        oi.flush()
        fl = oi.flattener
        fracs.append(fl.last_dirty_rows / max(fl.last_total_rows, 1))
    assert oi.n_reclusters > 0
    assert float(np.mean(fracs)) <= 0.25, fracs
    assert fl.n_fallback_full == 0
    probe = keys[rng.integers(0, n, 4096)]
    _, f = oi.lookup(probe)
    assert np.asarray(f).all()


# ---------------------------------------------------------------------------
# scheduler + background merges
# ---------------------------------------------------------------------------


def test_scheduler_runs_records_errors_and_closes():
    sched = MaintenanceScheduler(max_queue=2)
    done = []
    assert sched.submit(lambda: done.append(1))
    sched.drain()
    assert done == [1] and sched.depth == 0
    assert sched.submit(lambda: 1 / 0)
    sched.drain()
    assert len(sched.errors) == 1 and "ZeroDivisionError" in sched.errors[0]
    sched.close()
    assert not sched.submit(lambda: done.append(2))   # closed: refused
    sched.close()                                     # idempotent


def test_background_merge_never_blocks_correctness():
    """Reader threads hammer a stable probe set while the writer drives
    background merges (fold/retrain/splice/publish on the worker); every
    read must be exact at every instant, and the final state must equal
    the oracle."""
    rng = np.random.default_rng(7)
    keys = np.unique(rng.integers(0, 1 << 21, 6000)).astype(np.float64) * 2
    vals = np.arange(len(keys), dtype=np.int64)
    ix = LearnedIndex.build(keys, vals, config=IndexConfig(
        engine="local", overlay_cap=512,
        merge=MergePolicy(max_writes=256),
        maintenance=MaintenanceConfig(background=True,
                                      retrain_min_writes=64)))
    oracle = SortedOracle(keys, vals)

    # probe keys the writer never touches: their answers are constant
    probe = keys[:512]
    want_v = vals[:512]
    stop = threading.Event()
    failures: list[str] = []

    def reader():
        while not stop.is_set():
            v, f = ix.lookup(probe)
            if not (f.all() and np.array_equal(v, want_v)):
                failures.append("probe lookup diverged mid-publish")
                return

    threads = [threading.Thread(target=reader) for _ in range(2)]
    for t in threads:
        t.start()
    # writer: upserts/deletes restricted to keys[1000:] and fresh odd keys
    fresh = np.arange(keys.max() + 1, keys.max() + 4000, 2)
    try:
        for step in range(30):
            new = fresh[step * 64: (step + 1) * 64]
            nv = np.arange(len(new), dtype=np.int64) + step * 1000
            ix.upsert(new, nv)
            oracle.upsert(new, nv)
            dead = keys[1000 + step * 16: 1000 + (step + 1) * 16]
            ix.delete(dead)
            oracle.delete(dead)
            v, f = ix.lookup(new)
            wv, wf = oracle.lookup(new)
            np.testing.assert_array_equal(f, wf)
            np.testing.assert_array_equal(v[f], wv[wf])
    finally:
        stop.set()
        for t in threads:
            t.join()
    assert failures == []
    st = ix.flush()
    assert st["n_merges"] >= 1 and st["maint_errors"] == 0
    assert st["n_incremental_flattens"] >= 1
    k, v = ix.items()
    wk, wv = oracle.items()
    np.testing.assert_array_equal(k, wk)
    np.testing.assert_array_equal(v, wv)
    ix.close()


def test_background_workload_replay_oracle_exact():
    """The CI smoke in miniature: shift_fb_logn through the local engine
    with background maintenance, per-batch oracle diffing, zero
    divergence; the runner also fails on any background task error."""
    U = np.arange(0, 6000, 2, dtype=np.float64)
    ix = LearnedIndex.build(U, config=IndexConfig(
        engine="local", overlay_cap=512,
        maintenance=MaintenanceConfig(background=True)))
    spec = PRESETS["shift_fb_logn"].scaled(n_ops=1500, batch_size=64,
                                           seed=17)
    rep = WorkloadRunner(ix).run(generate_stream(spec, U), spec=spec)
    assert rep.divergences == []
    ix.flush()
    assert ix.stats()["maint_errors"] == 0
    ix.close()


def test_background_rejected_off_local():
    U = np.arange(0, 400, 2, dtype=np.float64)
    for eng in ("pallas", "sharded"):
        with pytest.raises(ValueError, match="background maintenance"):
            LearnedIndex.build(U, config=IndexConfig(
                engine=eng, maintenance=MaintenanceConfig(background=True)))


def test_failed_merge_restores_pending_writes(monkeypatch):
    """A merge that dies mid-fold must not lose writes: the frozen overlay
    folds back into the live one and reads stay exact."""
    import repro.online.merge as M
    keys = np.arange(0, 2000, 2, dtype=np.float64)
    oi = OnlineIndex(keys, policy=MergePolicy(max_writes=1 << 40,
                                              pressure_check_every=1 << 40),
                     overlay_cap=1 << 14)
    oi.upsert_batch(np.arange(1, 201, 2, dtype=np.float64),
                    np.arange(100, dtype=np.int64))
    monkeypatch.setattr(M, "fold_overlay",
                        lambda *a: (_ for _ in ()).throw(RuntimeError("x")))
    with pytest.raises(RuntimeError):
        oi.merge("explicit")
    # the frozen overlay stays installed (reads resolve it) until the
    # writer thread reclaims it on the next merge — nothing lost
    assert oi._merging is not None and oi._merge_failed
    k, _, _ = oi.pending_entries()
    assert len(k) == 100
    v, f = oi.lookup(np.arange(1, 201, 2, dtype=np.float64))
    assert np.asarray(f).all()
    monkeypatch.undo()
    st = oi.flush()                          # reclaim + retry succeeds
    assert oi._merging is None and not oi._merge_failed
    assert oi.overlay.count == 0 and st.n_keys == len(keys) + 100


def test_flush_is_a_synchronous_barrier():
    U = np.arange(0, 4000, 2, dtype=np.float64)
    ix = LearnedIndex.build(U, config=IndexConfig(
        engine="local", overlay_cap=1 << 14,
        merge=MergePolicy(max_writes=1 << 40,
                          pressure_check_every=1 << 40),
        maintenance=MaintenanceConfig(background=True)))
    new = np.arange(1, 2000, 2, dtype=np.float64)
    ix.upsert(new, np.arange(len(new), dtype=np.int64))
    st = ix.flush()
    assert st["pending_writes"] == 0
    assert st["epoch"] == 2 and st["n_merges"] == 1
    assert st["snapshot_keys"] == len(U) + len(new)
    ix.close()
