"""Per-arch smoke tests (reduced configs): forward/train step on CPU with
shape + finiteness assertions; decode==full-forward consistency; flash
attention equivalence; chunked-scan invariance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import model as MDL


RNG = jax.random.PRNGKey(0)


def _inputs(cfg, B=2, S=24):
    tokens = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    kw = {}
    if cfg.family == "vlm":
        kw["extra_embeds"] = jax.random.normal(
            RNG, (B, cfg.frontend_seq, cfg.d_model), jnp.float32)
    if cfg.is_encdec:
        kw["enc_frames"] = jax.random.normal(
            RNG, (B, cfg.frontend_seq, cfg.d_model), jnp.float32)
    return tokens, labels, kw


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_forward_and_grads(arch):
    cfg = get_config(arch).reduced()
    params = MDL.init_params(RNG, cfg)
    tokens, labels, kw = _inputs(cfg)
    logits, aux = MDL.forward_train(params, cfg, tokens, **kw)
    assert logits.shape == (2, 24, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    loss, g = jax.value_and_grad(
        lambda p: MDL.loss_fn(p, cfg, tokens, labels, **kw))(params)
    assert bool(jnp.isfinite(loss))
    gnorm = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                for x in jax.tree_util.tree_leaves(g))
    assert bool(jnp.isfinite(gnorm))


@pytest.mark.parametrize("arch", list_archs())
def test_arch_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = MDL.init_params(RNG, cfg)
    B, S = 2, 21
    tokens, _, kw = _inputs(cfg, B, S)
    full, _ = MDL.forward_train(params, cfg, tokens, **kw)
    maxlen = S + (cfg.frontend_seq if cfg.family == "vlm" else 0) + 4
    cache = MDL.make_cache(cfg, B, maxlen)
    _, cache = MDL.prefill(params, cfg, tokens[:, :S - 1], cache, **kw)
    lg, cache = MDL.decode_step(params, cfg, tokens[:, S - 1:S], cache)
    rel = float(jnp.abs(full[:, -1] - lg[:, 0]).max()) \
        / (float(jnp.abs(full[:, -1]).max()) + 1e-9)
    assert rel < 2e-2, rel


def test_flash_equals_dense_attention(monkeypatch):
    cfg = get_config("granite_8b").reduced()
    p = L.init_attention(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(RNG, (2, 96, cfg.d_model), jnp.float32) * 0.1
    pos = jnp.broadcast_to(jnp.arange(96), (2, 96))
    dense = L.attention(p, cfg, x, pos, causal=True)
    monkeypatch.setattr(L, "FLASH_THRESHOLD", 1)
    monkeypatch.setattr(L, "FLASH_Q_CHUNK", 32)
    monkeypatch.setattr(L, "FLASH_KV_CHUNK", 16)
    flash = L.attention(p, cfg, x, pos, causal=True)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(flash),
                               atol=2e-5)


def test_flash_windowed_and_softcap(monkeypatch):
    cfg = dataclasses.replace(get_config("gemma2_2b").reduced(),
                              attn_softcap=50.0)
    p = L.init_attention(jax.random.PRNGKey(4), cfg)
    x = jax.random.normal(RNG, (1, 80, cfg.d_model), jnp.float32) * 0.1
    pos = jnp.broadcast_to(jnp.arange(80), (1, 80))
    dense = L.attention(p, cfg, x, pos, causal=True, window=13)
    monkeypatch.setattr(L, "FLASH_THRESHOLD", 1)
    monkeypatch.setattr(L, "FLASH_Q_CHUNK", 16)
    monkeypatch.setattr(L, "FLASH_KV_CHUNK", 16)
    flash = L.attention(p, cfg, x, pos, causal=True, window=13)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(flash),
                               atol=2e-5)


def test_selective_scan_chunk_invariance():
    rng = jax.random.PRNGKey(5)
    u = jax.random.normal(rng, (2, 50, 16))
    dt_ = jax.nn.softplus(jax.random.normal(rng, (2, 50, 16)))
    A = -jnp.exp(jax.random.normal(rng, (16, 8)) * 0.1)
    Bm = jax.random.normal(rng, (2, 50, 8))
    Cm = jax.random.normal(rng, (2, 50, 8))
    y1, h1 = M._selective_scan(u, dt_, A, Bm, Cm, chunk=64)
    y2, h2 = M._selective_scan(u, dt_, A, Bm, Cm, chunk=7)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)


def test_ssd_chunk_invariance():
    rng = jax.random.PRNGKey(6)
    b, s, nh, hd, ds = 2, 40, 4, 8, 16
    u = jax.random.normal(rng, (b, s, nh, hd))
    dt_ = jax.nn.softplus(jax.random.normal(rng, (b, s, nh)))
    A = -jnp.exp(jax.random.normal(rng, (nh,)) * 0.1)
    Bm = jax.random.normal(rng, (b, s, ds))
    Cm = jax.random.normal(rng, (b, s, ds))
    y1, h1 = M._ssd_scan(u, dt_, A, Bm, Cm, None, chunk=64)
    y2, h2 = M._ssd_scan(u, dt_, A, Bm, Cm, None, chunk=5)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)


def test_moe_router_load_balance_loss_positive():
    from repro.models import moe as X
    cfg = get_config("granite_moe_1b_a400m").reduced()
    p = X.init_moe(jax.random.PRNGKey(7), cfg)
    x = jax.random.normal(RNG, (2, 16, cfg.d_model), jnp.float32)
    out, aux = X.moe_block(p, cfg, x)
    assert out.shape == x.shape
    assert float(aux) >= 0.99   # E * sum f*p >= 1 by Cauchy-Schwarz
