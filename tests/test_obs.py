"""Telemetry core tests (DESIGN.md section 13): histogram accuracy vs the
shared percentile recipe, snapshot schema equivalence across engines,
merge-pipeline span taxonomy, the retrace watchdog (zero post-warmup
traces on a mixed sharded workload — the PR-4 regression class), and the
enabled-telemetry overhead budget."""

import json
import time

import numpy as np
import pytest

from repro.api import IndexConfig, LearnedIndex, MaintenanceConfig
from repro.obs import (MERGE_SPANS, NULL_TELEMETRY, OPS, RECOVERY_SPANS,
                       LatencyHistogram, MetricsRegistry, Telemetry,
                       latency_summary, watchdog)

ENGINES = ("local", "pallas", "sharded")


def _universe(n=4096, seed=0):
    # integer keys: exactly representable in f32 so the pallas engine can
    # participate in cross-engine comparisons
    rng = np.random.default_rng(seed)
    keys = np.unique(rng.integers(1, 10 * n, n)).astype(np.float64)
    return keys, np.arange(len(keys), dtype=np.int64)


# -- metrics primitives -------------------------------------------------------


def test_histogram_matches_latency_summary():
    """The bucketed estimate must agree with the exact recipe to within
    the bucket layout's relative error (<= 1/32 per sample, upper edge)."""
    rng = np.random.default_rng(1)
    xs = rng.lognormal(mean=-7.0, sigma=1.5, size=20_000)   # ~1ms-ish
    h = LatencyHistogram()
    for x in xs:
        h.record(float(x))
    exact = latency_summary(xs)
    est = h.summary()
    assert est["count"] == exact["count"] == len(xs)
    for key in ("ms_p50", "ms_p95", "ms_p99", "ms_p999", "ms_max"):
        assert est[key] == pytest.approx(exact[key], rel=0.05), key
    assert est["ms_mean"] == pytest.approx(exact["ms_mean"], rel=1e-9)


def test_histogram_extremes_and_empty():
    h = LatencyHistogram()
    empty = h.summary("op")
    assert empty["op_count"] == 0 and empty["op_ms_p999"] == 0.0
    h.record(0.0)                      # below T_MIN: first bucket
    h.record(1e9)                      # beyond the table: overflow bucket
    s = h.summary()
    assert s["count"] == 2
    assert s["ms_max"] == pytest.approx(1e12)          # exact max kept
    assert h.quantile(1.0) == pytest.approx(1e9)


def test_latency_summary_stable_schema():
    """Empty and non-empty summaries expose the same key set — engines
    with quiet ops must still export an identical schema."""
    assert set(latency_summary([])) == set(latency_summary([1e-3, 2e-3]))


def test_registry_snapshot_jsonable():
    reg = MetricsRegistry()
    reg.count("merges")
    reg.count("merges", 2)
    reg.gauge("fill", 0.5)
    reg.declare_histogram("op.lookup")
    reg.observe("op.lookup", 1e-3)
    reg.observe("op.other", 2e-3)          # lazy creation
    snap = json.loads(json.dumps(reg.snapshot()))
    assert snap["counters"]["merges"] == 3
    assert snap["gauges"]["fill"] == 0.5
    assert snap["histograms"]["op.lookup"]["count"] == 1
    assert snap["histograms"]["op.other"]["count"] == 1


def test_null_telemetry_costs_nothing_visible():
    t = NULL_TELEMETRY
    before = t.ops_total
    t.count_ops(5)
    with t.span("merge.fold"):
        pass
    t.record_span("merge.publish", 1e-3)
    assert t.ops_total == before + 5
    assert t.spans.count("merge.fold") == 0        # disabled: not recorded
    assert t.spans.count("merge.publish") == 0
    t.ops_total = before                            # shared instance: restore


def test_telemetry_snapshot_fixed_taxonomy():
    t = Telemetry(enabled=True)
    snap = t.snapshot()
    assert snap["schema"] == "dili.metrics/1"
    assert set(snap["ops"]) == set(OPS)
    assert set(snap["spans"]) == set(MERGE_SPANS + RECOVERY_SPANS)
    # recovery.* spans are pre-declared: zero-filled summaries with the
    # full latency_summary key set BEFORE any recovery has ever run, so
    # a fresh index and a recovered one export the same schema
    for s in RECOVERY_SPANS:
        assert s.startswith("recovery."), s
        assert snap["spans"][s]["count"] == 0, s
        assert set(snap["spans"][s]) == set(latency_summary([])) | {"count"}
    assert snap["retrace"]["post_warmup_traces"] == 0
    json.dumps(snap)


def test_registry_warn_rate_limited():
    """Structured warnings: the Python warning fires once per registry
    (rate limit), while the `warn.<name>` counter keeps accumulating the
    full magnitude — and declaring the counter never emits anything."""
    reg = MetricsRegistry()
    with pytest.warns(UserWarning, match="7 keys collided"):
        reg.warn("collisions", "7 keys collided", count=7)
    # subsequent calls are silent but still counted
    import warnings as _warnings
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        reg.warn("collisions", "3 more", count=3)
        reg.warn("collisions", "5 more", count=5)
    assert reg.snapshot()["counters"]["warn.collisions"] == 15
    # rate-limit bookkeeping must NOT leak into the counter schema
    assert set(reg.snapshot()["counters"]) == {"warn.collisions"}


# -- watchdog -----------------------------------------------------------------


def test_watchdog_counts_fresh_traces():
    import jax
    import jax.numpy as jnp

    @jax.jit
    def probe(x):
        return x * 2 + 1

    watchdog.register_jit("test.probe", probe)
    mark = watchdog.TraceMark.now()
    probe(jnp.arange(7))                   # first call: traces
    assert watchdog.TraceMark.now().delta() == dict(traces=0, compiles=0)
    d = mark.delta()
    assert d["traces"] >= 1
    assert watchdog.jit_cache_sizes()["test.probe"] == 1
    mark2 = watchdog.TraceMark.now()
    probe(jnp.arange(7))                   # cached: no new trace
    assert mark2.delta()["traces"] == 0
    probe(jnp.arange(9))                   # new shape: re-trace
    assert mark2.delta()["traces"] >= 1
    assert watchdog.jit_cache_sizes()["test.probe"] == 2


# -- facade integration -------------------------------------------------------


def _exercise(ix, keys):
    q = keys[:128]
    v, f = ix.lookup(q)
    assert bool(f.all())
    ix.upsert(keys[:16] + 0.0, np.arange(16))
    ix.delete(keys[4:6])
    ix.range(keys[0], keys[64], max_hits=16)
    ix.flush()
    ix.lookup(q)


@pytest.mark.parametrize("engine", ENGINES)
def test_metrics_off_by_default_but_counting(engine):
    keys, vals = _universe()
    ix = LearnedIndex.build(keys, vals, config=IndexConfig(engine=engine))
    _exercise(ix, keys)
    m = ix.metrics()
    assert not m["enabled"]
    assert m["ops_total"] > 0                          # counting stays live
    assert all(m["ops"][op]["count"] == 0 for op in OPS)   # no capture
    assert all(m["spans"][s]["count"] == 0 for s in MERGE_SPANS)
    ix.close()


def test_metrics_schema_equivalent_across_engines():
    """Pinned acceptance criterion: metrics() returns the SAME key tree on
    every engine (jit_cache_entries excepted — its members are process-
    global registrations, identical here but not schema-guaranteed)."""
    keys, vals = _universe()

    def shape(d, prefix=""):
        out = []
        for k in sorted(d):
            out.append(prefix + k)
            if isinstance(d[k], dict):
                out += shape(d[k], prefix + k + ".")
        return [k for k in out
                if not k.startswith("retrace.jit_cache_entries.")]

    shapes = {}
    for engine in ENGINES:
        ix = LearnedIndex.build(keys, vals, config=IndexConfig(
            engine=engine, telemetry=True))
        _exercise(ix, keys)
        ix.telemetry.mark_warm()
        m = ix.metrics()
        json.dumps(m)
        assert m["enabled"] and m["engine"] == engine
        assert m["ops"]["lookup"]["count"] > 0
        # the declared-everywhere surfaces ride along on every engine:
        # recovery.* spans (zero-filled without a recovery) and the
        # structured-warning counter (zero unless the pallas quantizer
        # actually collided)
        assert set(RECOVERY_SPANS) <= set(m["spans"])
        assert "warn.pallas_f32_collision" in m["counters"]
        shapes[engine] = shape(m)
        ix.close()
    assert shapes["local"] == shapes["pallas"] == shapes["sharded"]


def test_stats_shared_across_engines():
    """The EngineTelemetryBase mixin keeps the stats() core uniform."""
    keys, vals = _universe()
    for engine in ENGINES:
        ix = LearnedIndex.build(keys, vals, config=IndexConfig(engine=engine))
        s = ix.stats()
        for key in ("engine", "epoch", "n_flattens", "n_merges",
                    "telemetry_enabled", "ops_total", "maint_errors"):
            assert key in s, (engine, key)
        assert s["engine"] == engine
        ix.close()


def test_merge_pipeline_spans_background():
    """The full span taxonomy must fire across a background merge —
    including queue_wait (submit->worker start) and frozen_dwell
    (freeze->drop), which only exist on the scheduler path."""
    keys, vals = _universe()
    ix = LearnedIndex.build(keys, vals, config=IndexConfig(
        engine="local", telemetry=True,
        maintenance=MaintenanceConfig(background=True)))
    rng = np.random.default_rng(2)
    for _ in range(6):
        ks = rng.integers(1, 10 * len(keys), 512).astype(np.float64)
        ix.upsert(ks, np.arange(512))
    ix.flush()
    m = ix.metrics()
    counts = {s: m["spans"][s]["count"] for s in MERGE_SPANS}
    for s in ("merge.fold", "merge.flatten", "merge.publish",
              "merge.queue_wait", "merge.frozen_dwell"):
        assert counts[s] > 0, (s, counts)
    # retrain spans require the retrain pipeline; default config has it on
    assert m["spans"]["merge.fold"]["ms_p50"] > 0.0
    assert m["counters"]["publish.retraced"] >= 0
    ix.close()


def test_workload_runner_latency_and_warmup():
    from repro.workloads import PRESETS, WorkloadRunner, generate_stream
    keys, vals = _universe()
    ix = LearnedIndex.build(keys, vals, config=IndexConfig(
        engine="local", telemetry=True))
    spec = PRESETS["ycsb_a"].scaled(n_ops=2000, batch_size=128)
    rep = WorkloadRunner(ix, warmup_batches=4).run(
        generate_stream(spec, keys), spec=spec)
    from repro.workloads.generator import OPS as WORKLOAD_OPS
    d = rep.to_json_dict()
    assert set(d["latency_ms"]) == set(WORKLOAD_OPS)
    assert d["latency_ms"]["lookup"]["count"] > 0
    assert d["latency_ms"]["lookup"]["ms_p999"] >= \
        d["latency_ms"]["lookup"]["ms_p50"] > 0
    json.dumps(d)
    assert ix.telemetry.warmed                     # runner marked warm
    ix.close()


# -- the regression the subsystem exists for ---------------------------------


def test_zero_post_warmup_retraces_sharded_mixed():
    """PR-4 bug class: the sharded collectives once re-traced EVERY batch
    (~50x per-batch cost) with results staying correct.  After the
    runner's warmup (which pre-mints every pow2 batch bucket the stream
    can reach), a steady mixed workload must mint NO new executables."""
    from repro.workloads import PRESETS, WorkloadRunner, generate_stream
    keys, vals = _universe()
    ix = LearnedIndex.build(keys, vals, config=IndexConfig(
        engine="sharded", telemetry=True))
    spec = PRESETS["ycsb_a"].scaled(n_ops=3000, batch_size=128)
    WorkloadRunner(ix, warmup_batches=4).run(
        generate_stream(spec, keys), spec=spec)
    r = ix.metrics()["retrace"]
    assert r["warmed"]
    assert r["post_warmup_ops"] > 0
    assert r["post_warmup_traces"] == 0, r
    assert r["retraces_per_1k_ops"] == 0.0
    ix.close()


@pytest.mark.parametrize("vmem_budget", [12 * 1024 * 1024, 1024])
def test_zero_post_warmup_retraces_pallas_mixed(vmem_budget):
    """Same contract on the pallas engine, on BOTH sides of the
    kernel-dispatch boundary: with the default VMEM budget the snapshot
    tables fit and lookups go through the Pallas kernel wrapper; with a
    tiny budget every batch dispatches to the XLA fallback.  Either way
    a steady mixed workload after warmup must mint no new executables —
    and crossing the boundary must be a BUILD-time decision, never a
    per-batch retrace."""
    from repro.workloads import PRESETS, WorkloadRunner, generate_stream
    keys, vals = _universe()
    ix = LearnedIndex.build(keys, vals, config=IndexConfig(
        engine="pallas", telemetry=True, vmem_budget_bytes=vmem_budget))
    spec = PRESETS["ycsb_a"].scaled(n_ops=3000, batch_size=128)
    WorkloadRunner(ix, warmup_batches=4).run(
        generate_stream(spec, keys), spec=spec)
    r = ix.metrics()["retrace"]
    assert r["warmed"]
    assert r["post_warmup_ops"] > 0
    assert r["post_warmup_traces"] == 0, (vmem_budget, r)
    assert r["retraces_per_1k_ops"] == 0.0
    ix.close()


@pytest.mark.slow
def test_enabled_telemetry_overhead_budget():
    """config.telemetry=True must cost <= 3% on the ycsb_c-style point-
    lookup loop (plus a small absolute slack for timer noise at this
    scale).  Interleaved median-of-batches keeps the comparison fair."""
    keys, vals = _universe(n=20_000, seed=3)
    q = keys[:1024]
    pair = [LearnedIndex.build(keys, vals, config=IndexConfig(
        engine="local", telemetry=t)) for t in (False, True)]
    for ix in pair:
        for _ in range(5):
            ix.lookup(q)                       # warm both executables
    times: list[list[float]] = [[], []]
    for _ in range(60):
        for which, ix in enumerate(pair):
            t0 = time.perf_counter()
            ix.lookup(q)
            times[which].append(time.perf_counter() - t0)
    off, on = (float(np.median(t)) for t in times)
    assert on <= off * 1.03 + 5e-5, (off, on)
    for ix in pair:
        ix.close()
