"""Online-update subsystem (repro.online): tombstone overlay semantics,
epoch store double-buffering, merge-policy triggers, end-to-end correctness
between merges, and the one-flatten-per-merge serving contract."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dili import bulk_load
from repro.core.flat import flatten
from repro.online import (LIVE, TOMBSTONE, MergePolicy, OnlineIndex,
                          SnapshotStore, TombstoneOverlay, adjust_pressure,
                          overlay_device_arrays, search_with_updates)
from repro.serve.sessions import SessionTable
from tests.conftest import make_keys


# ---------------------------------------------------------------------------
# overlay
# ---------------------------------------------------------------------------


def test_overlay_last_write_wins():
    ov = TombstoneOverlay.empty(16)
    ov = ov.upsert_batch([5.0], [1])
    ov = ov.upsert_batch([5.0], [2])          # newer upsert wins
    assert ov.get(5.0) == (LIVE, 2)
    ov = ov.delete_batch([5.0])               # delete after upsert -> tomb
    assert ov.get(5.0) == (TOMBSTONE, None)
    ov = ov.upsert_batch([5.0], [3])          # upsert after delete -> live
    assert ov.get(5.0) == (LIVE, 3)
    assert ov.count == 1                      # one entry per key after dedupe
    assert ov.get(6.0) == (-1, None)
    # within one batch the later duplicate wins
    ov = ov.upsert_batch([7.0, 7.0], [10, 11])
    assert ov.get(7.0) == (LIVE, 11)


def test_overlay_empty_batches_are_noops():
    ov = TombstoneOverlay.empty(8)
    assert ov.upsert_batch([], []).count == 0      # empty into empty
    assert ov.delete_batch([]).count == 0
    ov = ov.upsert_batch([1.0], [1])
    ov2 = ov.upsert_batch([], [])                  # empty into non-empty
    assert ov2.count == 1 and ov2.get(1.0) == (LIVE, 1)


def test_empty_flush_keeps_epoch(rng):
    keys, oi = _fresh(rng, n=500, overlay_cap=32)
    e0, fl0 = oi.epoch, oi.n_flattens
    st = oi.flush()                                # nothing pending
    assert oi.epoch == e0 and oi.n_flattens == fl0
    assert st.epoch == e0


def test_overlay_capacity_doubling():
    ov = TombstoneOverlay.empty(4)
    ov = ov.upsert_batch(np.arange(10, dtype=np.float64), np.arange(10))
    assert ov.count == 10
    assert ov.cap == 16                       # doubled 4 -> 8 -> 16
    assert 0 < ov.full_fraction <= 1
    k, v, t = ov.entries()
    assert np.array_equal(k, np.arange(10))
    assert not t.any()
    ov = ov.delete_batch([3.0, 4.0])
    assert ov.n_tombstones == 2
    assert ov.n_live == 8


def test_fused_lookup_precedence(rng):
    from repro.core import search as S
    keys = make_keys("uniform", 4000, rng)
    d = bulk_load(keys)
    store = SnapshotStore()
    store.publish(flatten(d))
    ov = TombstoneOverlay.empty(64)
    ov = ov.upsert_batch([keys[10], keys[0] - 5.0], [777, 888])
    ov = ov.delete_batch([keys[11]])
    ova = overlay_device_arrays(ov)
    q = jnp.asarray([keys[10], keys[0] - 5.0, keys[11], keys[12]])
    # trip count comes from the DeviceSnapshot — no manual max_depth
    v, f = S.search_with_overlay(store.idx, ova, q)
    v, f = np.asarray(v), np.asarray(f)
    assert f[0] and v[0] == 777        # overlay overrides snapshot value
    assert f[1] and v[1] == 888        # overlay-only key found
    assert not f[2]                    # tombstone hides snapshot hit
    assert f[3] and v[3] == 12         # untouched snapshot key


def test_search_with_updates_deprecated(rng):
    """The PR-2 alias still answers correctly but warns toward
    search_with_overlay / the api facade."""
    keys = make_keys("uniform", 1000, rng)
    store = SnapshotStore()
    store.publish(flatten(bulk_load(keys)))
    ova = overlay_device_arrays(TombstoneOverlay.empty(4))
    with pytest.warns(DeprecationWarning, match="search_with_overlay"):
        v, f = search_with_updates(store.idx, ova, jnp.asarray(keys[:8]))
    assert np.asarray(f).all()


# ---------------------------------------------------------------------------
# epoch store
# ---------------------------------------------------------------------------


def test_snapshot_store_double_buffer(rng):
    keys = make_keys("uniform", 3000, rng)
    d = bulk_load(keys)
    store = SnapshotStore()
    st1 = store.publish(flatten(d))
    assert store.epoch == 1 and st1.retraced     # first epoch always traces
    idx_n = store.idx                            # a reader captures epoch 1
    for k in keys[:5]:
        d.delete(float(k))
    st2 = store.publish(flatten(d), overlay_fill=0.25, merge_lag=5)
    assert store.epoch == 2
    assert st2.overlay_fill == 0.25 and st2.merge_lag == 5
    assert st2.bytes_uploaded > 0 and st2.publish_s >= 0
    # double buffering: epoch 1's arrays are a different, still-live object
    assert store.idx is not idx_n
    from repro.core import search as S
    v, f = S.search_batch(idx_n, jnp.asarray(keys[:5]),
                          max_depth=store.max_depth + 2)
    assert bool(np.asarray(f).all())             # old epoch still consistent
    v2, f2 = S.search_batch(store.idx, jnp.asarray(keys[:5]),
                            max_depth=store.max_depth + 2)
    assert not np.asarray(f2).any()              # new epoch sees the deletes


def test_snapshot_store_pow2_padding_stable(rng):
    """Small mutations must keep padded shapes (no re-trace on republish)."""
    keys = make_keys("uniform", 3000, rng)
    d = bulk_load(keys)
    store = SnapshotStore()
    store.publish(flatten(d))
    d.insert(float(keys[0]) + 0.5, 42)
    st = store.publish(flatten(d))
    assert not st.retraced


# ---------------------------------------------------------------------------
# merge policy
# ---------------------------------------------------------------------------


def _fresh(rng, n=3000, **kw):
    keys = make_keys("uniform", n, rng)
    return keys, OnlineIndex(keys, **kw)


def test_merge_trigger_fill(rng):
    keys, oi = _fresh(rng, overlay_cap=64,
                      policy=MergePolicy(max_fill=0.5, max_writes=10**9))
    new = keys[:-1] + np.diff(keys) / 2
    for j, k in enumerate(new[:31]):
        oi.upsert(float(k), j)
    assert oi.n_merges == 0                   # 31/64 < 0.5
    oi.upsert(float(new[31]), 31)
    assert oi.n_merges == 1                   # 32/64 hits the fill trigger
    assert oi.merge_reasons["fill"] == 1
    assert oi.overlay.count == 0              # overlay reset after merge


def test_merge_trigger_lag(rng):
    keys, oi = _fresh(rng, overlay_cap=4096,
                      policy=MergePolicy(max_fill=1.1, max_writes=50))
    new = keys[:-1] + np.diff(keys) / 2
    for j, k in enumerate(new[:120]):
        oi.upsert(float(k), j)
    assert oi.n_merges == 2                   # every 50 writes of lag
    assert oi.merge_reasons["lag"] == 2


def test_merge_trigger_pressure(rng):
    keys, oi = _fresh(rng, overlay_cap=1 << 16,
                      policy=MergePolicy(max_fill=1.1, max_writes=10**9,
                                         pressure_lambda=2.0,
                                         pressure_check_every=64))
    # hammer one tiny key interval: all pending writes land in one host leaf
    lo, hi = float(keys[100]), float(keys[101])
    hot = np.linspace(lo, hi, 200)[1:-1]
    for j, k in enumerate(hot):
        oi.upsert(float(k), j)
    assert oi.merge_reasons["pressure"] >= 1
    v, f = oi.lookup(hot)
    assert f.all()
    assert np.array_equal(v, np.arange(len(hot)))


def test_explicit_flush_and_pressure_metric(rng):
    keys, oi = _fresh(rng, overlay_cap=1024,
                      policy=MergePolicy(max_fill=1.1, max_writes=10**9,
                                         pressure_check_every=10**9))
    assert adjust_pressure(oi.dili, oi.overlay) == 0.0
    oi.upsert(float(keys[0]) + 0.25, 1)
    assert adjust_pressure(oi.dili, oi.overlay) > 0.0
    e0 = oi.epoch
    st = oi.flush()
    assert oi.epoch == e0 + 1 and st.epoch == oi.epoch
    assert oi.get(float(keys[0]) + 0.25) == 1


# ---------------------------------------------------------------------------
# end-to-end: exact at every point between merges
# ---------------------------------------------------------------------------


def test_online_index_matches_oracle_between_merges(rng):
    keys = make_keys("logn", 4000, rng)
    oi = OnlineIndex(keys, overlay_cap=128,
                     policy=MergePolicy(max_fill=0.5, max_writes=300))
    oracle = {float(k): i for i, k in enumerate(keys)}
    universe = np.unique(np.concatenate(
        [keys, rng.uniform(keys[0], keys[-1], 1500)]))
    ops = rng.integers(0, 3, 900)
    picks = rng.integers(0, len(universe), 900)
    nxt = len(keys)
    for step, (op, pi) in enumerate(zip(ops, picks)):
        k = float(universe[pi])
        if op == 0:
            oi.upsert(k, nxt)
            oracle[k] = nxt
            nxt += 1
        elif op == 1:
            oi.delete(k)
            oracle.pop(k, None)
        if step % 60 == 0:        # exactness probe at arbitrary mid-points
            qs = universe[rng.integers(0, len(universe), 256)]
            v, f = oi.lookup(qs)
            for i, q in enumerate(qs):
                want = oracle.get(float(q))
                assert f[i] == (want is not None), (step, q)
                if want is not None:
                    assert v[i] == want, (step, q)
    assert oi.n_merges >= 1       # the workload actually crossed merges
    qs = np.asarray(list(oracle))
    v, f = oi.lookup(qs)
    assert f.all()
    assert all(v[i] == oracle[float(q)] for i, q in enumerate(qs))


def test_merge_upserts_overwrite_in_dense_leaves(rng):
    """Regression: merging an overlay upsert of an existing key must replace
    the payload even when that key lives in a dense (DILI-LO) leaf."""
    keys = np.arange(200, dtype=np.float64)
    dili = bulk_load(keys, local_optimized=False)
    oi = OnlineIndex(dili=dili, overlay_cap=64,
                     policy=MergePolicy(max_fill=1.1, max_writes=10**9))
    oi.upsert(5.0, 999)
    oi.flush()
    v, f = oi.lookup([5.0])
    assert f[0] and v[0] == 999


def test_online_index_int64_payloads(rng):
    keys, oi = _fresh(rng, n=1000, overlay_cap=64)
    big = 2**41 + 5
    oi.upsert(float(keys[0]) + 0.5, big)
    v, f = oi.lookup([float(keys[0]) + 0.5])
    assert f[0] and int(v[0]) == big           # via overlay
    oi.flush()
    v, f = oi.lookup([float(keys[0]) + 0.5])
    assert f[0] and int(v[0]) == big           # via merged snapshot


# ---------------------------------------------------------------------------
# serving contract (acceptance): one flatten per merge epoch, not per write
# ---------------------------------------------------------------------------


def test_session_table_one_flatten_per_merge_epoch():
    t = SessionTable(512, policy=MergePolicy(max_fill=1.1, max_writes=40))
    live: dict[float, int] = {}
    n_ops = 0
    for i in range(160):                       # sustained admit/evict loop
        sid = 1000.0 + i
        live[sid] = t.admit(sid)
        n_ops += 1
        if i % 3 == 2:                         # evict every third session
            victim = sorted(live)[0]
            t.evict(victim)
            live.pop(victim)
            n_ops += 1
        if i % 20 == 0:                        # correct between merges too
            probe = list(live)[:16]
            v, f = t.lookup_batch(probe)
            assert f.all()
            assert all(v[j] == live[s] for j, s in enumerate(probe))
            gone = 1000.0 + i + 5000
            _, f2 = t.lookup_batch([gone])
            assert not f2[0]
    # at most one flatten per merge epoch (plus the initial publish) — the
    # seed behavior was one flatten per admit/evict (n_ops of them)
    assert t.publish_count == 1 + t.index.n_merges
    assert t.publish_count <= n_ops // 40 + 2
    assert n_ops > 4 * t.publish_count
    # evicted sessions stay invisible after the final state
    v, f = t.lookup_batch(sorted(live))
    assert f.all()


def test_session_table_admit_evict_semantics_via_overlay():
    """Duplicate admits / missing evicts must be caught while the state is
    still overlay-only (before any merge)."""
    t = SessionTable(16, policy=MergePolicy(max_fill=1.1, max_writes=10**9))
    s = t.admit(100.5)
    with pytest.raises(KeyError):
        t.admit(100.5)                 # live in overlay only
    t.evict(100.5)
    with pytest.raises(KeyError):
        t.evict(100.5)                 # tombstoned in overlay only
    s2 = t.admit(100.5)                # re-admit after evict
    assert s2 == s                     # slot recycled
    assert t.publish_count == 1        # no merge happened at all
