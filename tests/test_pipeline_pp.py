"""GPipe pipeline parallelism over the pod axis (subprocess, 8 devices)."""
import pytest

from tests.test_distributed import run_sub


@pytest.mark.slow
def test_pipeline_forward_matches_reference():
    out = run_sub("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import model as MDL
        from repro.parallel.pipeline import pipeline_forward
        cfg = dataclasses.replace(get_config("granite-8b").reduced(),
                                  n_layers=4, remat="none")
        params = MDL.init_params(jax.random.PRNGKey(0), cfg)
        mesh = jax.make_mesh((4, 2, 1), ("pod", "data", "model"))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                    cfg.vocab)
        with mesh:
            pp = pipeline_forward(cfg, mesh, params, tokens, n_micro=4)
        ref, _ = MDL.forward_train(params, cfg, tokens)
        err = float(jnp.abs(pp - ref).max()) / \\
            (float(jnp.abs(ref).max()) + 1e-9)
        assert err < 1e-3, err
        print("PP-OK", err)
    """)
    assert "PP-OK" in out


@pytest.mark.slow
def test_pipeline_gradients_flow():
    out = run_sub("""
        import dataclasses, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import model as MDL
        from repro.parallel.pipeline import pipeline_forward
        cfg = dataclasses.replace(get_config("granite-8b").reduced(),
                                  n_layers=4, remat="none")
        params = MDL.init_params(jax.random.PRNGKey(0), cfg)
        mesh = jax.make_mesh((4, 2, 1), ("pod", "data", "model"))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                    cfg.vocab)

        def loss(p):
            with mesh:
                lg = pipeline_forward(cfg, mesh, p, tokens, n_micro=4)
            return jnp.mean(jnp.square(lg))
        g = jax.grad(loss)(params)
        gn = sum(jnp.sum(jnp.square(x)) for x in
                 jax.tree_util.tree_leaves(g))
        assert bool(jnp.isfinite(gn)) and float(gn) > 0
        print("PP-GRAD-OK")
    """)
    assert "PP-GRAD-OK" in out
