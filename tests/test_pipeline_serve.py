"""Data pipeline (DILI record store) + serving session table integration."""
import numpy as np
import pytest

from repro.data.datasets import ALL_DATASETS, generate
from repro.data.pipeline import StorePipeline, SyntheticLM
from repro.data.record_store import RecordStore
from repro.serve.sessions import SessionTable


@pytest.mark.parametrize("name", ALL_DATASETS)
def test_datasets_shape_and_determinism(name):
    a = generate(name, 5000, seed=3)
    b = generate(name, 5000, seed=3)
    assert len(a) == 5000
    assert np.all(np.diff(a) > 0)
    np.testing.assert_array_equal(a, b)


def test_synthetic_lm_deterministic_and_learnable():
    p = SyntheticLM(vocab=64, seq_len=16, batch=4, seed=5)
    b1, b2 = p.batch_at(7), p.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels mostly follow the permutation
    match = (p.perm[b1["tokens"]] == b1["labels"]).mean()
    assert match > 0.7


def test_record_store_roundtrip():
    rng = np.random.default_rng(6)
    keys = np.unique(rng.uniform(0, 1e6, 500))
    docs = [rng.integers(0, 100, rng.integers(5, 40)).astype(np.int32)
            for _ in keys]
    store = RecordStore(keys, docs)
    order = np.argsort(keys)
    for i in rng.integers(0, len(keys), 50):
        got = store.fetch(float(keys[i]))
        np.testing.assert_array_equal(got, docs[i])
    # batched lookup agreement
    off, ln, f = store.lookup(keys[:64])
    assert f.all()
    # write path + publish
    store.add(2e6, np.arange(7, dtype=np.int32))
    store.publish()
    np.testing.assert_array_equal(store.fetch(2e6), np.arange(7))


def test_store_pipeline_batches():
    rng = np.random.default_rng(7)
    keys = np.unique(rng.uniform(0, 1e6, 200))
    docs = [rng.integers(1, 50, 33).astype(np.int32) for _ in keys]
    store = RecordStore(keys, docs)
    pipe = StorePipeline(store, keys, seq_len=16, batch=8, seed=1)
    b = pipe.batch_at(0)
    assert b["tokens"].shape == (8, 16)
    np.testing.assert_array_equal(pipe.batch_at(3)["tokens"],
                                  pipe.batch_at(3)["tokens"])


def test_session_table_admit_lookup_evict():
    t = SessionTable(16)
    s1 = t.admit(100.5)
    s2 = t.admit(200.5)
    assert s1 != s2
    v, f = t.lookup_batch([100.5, 200.5, 300.5])
    assert list(f) == [True, True, False]
    assert list(v[:2]) == [s1, s2]
    t.evict(100.5)
    v, f = t.lookup_batch([100.5])
    assert not f[0]
    # slot is recycled
    s3 = t.admit(300.5)
    assert s3 == s1
    with pytest.raises(KeyError):
        t.admit(300.5)
    with pytest.raises(KeyError):
        t.evict(999.0)


def test_session_table_exhaustion():
    t = SessionTable(3)         # 2 warm ids + 1 free
    t.admit(50.0)
    with pytest.raises(RuntimeError):
        t.admit(60.0)
