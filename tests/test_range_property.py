"""Property-based tests for the sorted-pair range path: the device
`range_query_batch` (two searchsorted bisections + bounded window gather)
must match a brute-force numpy oracle on random keys and random — possibly
empty or inverted — windows, including `max_hits` truncation.

hypothesis is an optional extra (see requirements.txt); the importorskip
guard keeps `pytest -x -q` collecting when it is absent.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import search as S                        # noqa: E402
from repro.core.dili import bulk_load                     # noqa: E402
from repro.core.flat import flatten                       # noqa: E402

_idx_cache: dict = {}


def _index_for(seed: int):
    """One index per seed (bulk_load is the expensive part, not the claim
    under test)."""
    if seed not in _idx_cache:
        rng = np.random.default_rng(seed)
        keys = np.unique(rng.uniform(0.0, 1000.0, 600))
        d = bulk_load(keys)
        _idx_cache[seed] = (keys, S.device_arrays(flatten(d)))
    return _idx_cache[seed]


def _oracle(keys: np.ndarray, lo: float, hi: float, max_hits: int):
    sel = keys[(keys >= lo) & (keys < hi)]
    vals = np.nonzero((keys >= lo) & (keys < hi))[0]   # bulk_load payload = rank
    return sel[:max_hits], vals[:max_hits], min(len(sel), max_hits)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 3),
       st.lists(st.tuples(st.floats(-50.0, 1050.0), st.floats(-50.0, 1050.0)),
                min_size=1, max_size=24),
       st.sampled_from([1, 7, 32, 128]))
def test_range_matches_numpy_oracle(seed, windows, max_hits):
    keys, idx = _index_for(seed)
    lo = np.array([w[0] for w in windows])
    hi = np.array([w[1] for w in windows])
    ks, vs, counts = S.range_query_batch(idx, jnp.asarray(lo),
                                         jnp.asarray(hi), max_hits=max_hits)
    ks, vs, counts = np.asarray(ks), np.asarray(vs), np.asarray(counts)
    for i in range(len(windows)):
        ek, ev, ec = _oracle(keys, lo[i], hi[i], max_hits)
        assert counts[i] == ec, (lo[i], hi[i])
        assert np.array_equal(ks[i][:ec], ek)
        assert np.array_equal(vs[i][:ec], ev)
        # past the count: inert fills, keys padded to +inf
        assert np.all(ks[i][ec:] == np.inf)
        assert np.all(vs[i][ec:] == -1)


@settings(max_examples=25, deadline=None)
@given(st.floats(-50.0, 1050.0), st.floats(0.0, 30.0))
def test_range_empty_and_inverted_windows(lo, width):
    """Empty ([x, x)) and inverted (hi < lo) windows return count 0."""
    keys, idx = _index_for(0)
    lo_b = jnp.asarray([lo, lo, lo + width])
    hi_b = jnp.asarray([lo, lo - width, lo])    # empty, inverted, inverted
    ks, vs, counts = S.range_query_batch(idx, lo_b, hi_b, max_hits=16)
    counts = np.asarray(counts)
    assert counts[0] == 0 and counts[2] == 0
    if width > 0:
        assert counts[1] == 0
    assert np.all(np.asarray(ks)[np.asarray(counts) == 0] == np.inf)


def test_range_exact_key_boundaries():
    """[k_i, k_j) is inclusive of k_i, exclusive of k_j — on exact keys."""
    keys, idx = _index_for(1)
    ks, vs, counts = S.range_query_batch(
        idx, jnp.asarray([keys[10]]), jnp.asarray([keys[20]]), max_hits=64)
    assert int(np.asarray(counts)[0]) == 10
    assert np.array_equal(np.asarray(ks)[0][:10], keys[10:20])


def test_range_truncation_is_ascending_prefix():
    """max_hits truncation keeps the FIRST hits ascending from lo (a stable
    prefix, not an arbitrary subset)."""
    keys, idx = _index_for(2)
    ks, vs, counts = S.range_query_batch(
        idx, jnp.asarray([keys[0]]), jnp.asarray([keys[-1]]), max_hits=8)
    assert int(np.asarray(counts)[0]) == 8
    assert np.array_equal(np.asarray(ks)[0], keys[:8])
