"""Batched device search (core/search.py): flat snapshot vs host truth,
FMA-consistency regression, overlay, range queries."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import search as S
from repro.core.dili import bulk_load
from repro.core.flat import DeltaOverlay, flatten
from tests.conftest import make_keys


@pytest.fixture(scope="module", params=["logn", "uniform", "fb", "wikits"])
def snap(request):
    rng = np.random.default_rng(11)
    keys = make_keys(request.param, 25000, rng)
    d = bulk_load(keys)
    f = flatten(d)
    return keys, d, f, S.device_arrays(f)


def test_search_batch_hits(snap):
    keys, d, f, idx = snap
    rng = np.random.default_rng(12)
    qi = rng.integers(0, len(keys), 8192)
    v, fnd = S.search_batch(idx, jnp.asarray(keys[qi]),
                            max_depth=f.max_depth + 2)
    assert bool(np.asarray(fnd).all())
    assert np.array_equal(np.asarray(v), qi)


def test_search_batch_misses(snap):
    keys, d, f, idx = snap
    rng = np.random.default_rng(13)
    qi = rng.integers(0, len(keys) - 1, 4096)
    mids = (keys[qi] + keys[qi + 1]) / 2
    ok = (mids != keys[qi]) & (mids != keys[qi + 1])
    v, fnd = S.search_batch(idx, jnp.asarray(mids),
                            max_depth=f.max_depth + 2)
    assert not np.asarray(fnd)[ok].any()


def test_fma_consistency(snap):
    """jit vs eager must agree — regression for the FMA-contraction bug
    (construction nudges every model off integer boundaries)."""
    keys, d, f, idx = snap
    rng = np.random.default_rng(14)
    q = jnp.asarray(keys[rng.integers(0, len(keys), 4096)])
    v1, f1 = S.search_batch(idx, q, max_depth=f.max_depth + 2)
    with jax.disable_jit():
        v2, f2 = S.search_batch(idx, q, max_depth=f.max_depth + 2)
    assert np.array_equal(np.asarray(v1), np.asarray(v2))
    assert np.array_equal(np.asarray(f1), np.asarray(f2))


def test_stats_probe_counts(snap):
    keys, d, f, idx = snap
    rng = np.random.default_rng(15)
    q = jnp.asarray(keys[rng.integers(0, len(keys), 1024)])
    v, fnd, nodes, probes = S.search_batch(idx, q, max_depth=f.max_depth + 2,
                                           with_stats=True)
    nodes = np.asarray(nodes)
    assert bool(np.asarray(fnd).all())
    assert nodes.min() >= 2 and nodes.max() <= f.max_depth + 1


def test_overlay_lookup(snap):
    keys, d, f, idx = snap
    ov = DeltaOverlay.empty(1024)
    newk = np.array([keys[0] - 5.0, keys[-1] + 5.0])
    ov = ov.insert_batch(newk, np.array([111, 222]))
    ova = S.overlay_arrays(ov)
    v, fnd = S.search_with_overlay(idx, ova, jnp.asarray(newk),
                                   max_depth=f.max_depth + 2)
    assert bool(np.asarray(fnd).all())
    assert list(np.asarray(v)) == [111, 222]
    # snapshot keys still resolve through the combined path
    v2, f2 = S.search_with_overlay(idx, ova, jnp.asarray(keys[:64]),
                                   max_depth=f.max_depth + 2)
    assert bool(np.asarray(f2).all())


def test_overlay_vals_int64_roundtrip(snap):
    """Overlay payloads above 2^31 must not wrap (overlay_arrays regression)."""
    keys, d, f, idx = snap
    big = 2**40 + 123
    ov = DeltaOverlay.empty(64).insert_batch(
        np.array([keys[-1] + 9.0]), np.array([big]))
    ova = S.overlay_arrays(ov)
    assert ova["vals"].dtype == jnp.int64
    v, fnd = S.search_with_overlay(idx, ova, jnp.asarray([keys[-1] + 9.0]),
                                   max_depth=f.max_depth + 2)
    assert bool(np.asarray(fnd)[0])
    assert int(np.asarray(v)[0]) == big


def test_search_with_overlay_precedence(snap):
    """Overlay wins over the snapshot; a tombstone hides a snapshot hit."""
    from repro.online.overlay import TombstoneOverlay, overlay_device_arrays
    keys, d, f, idx = snap
    ov = TombstoneOverlay.empty(64)
    ov = ov.upsert_batch([keys[5]], [999_000])   # overwrite a snapshot key
    ov = ov.delete_batch([keys[6]])              # tombstone a snapshot key
    ova = overlay_device_arrays(ov)
    q = jnp.asarray([keys[5], keys[6], keys[7]])
    v, fnd = S.search_with_overlay(idx, ova, q, max_depth=f.max_depth + 2)
    v, fnd = np.asarray(v), np.asarray(fnd)
    assert fnd[0] and v[0] == 999_000            # overlay beats snapshot val
    assert not fnd[1]                            # tombstone hides the hit
    assert fnd[2] and v[2] == 7                  # untouched key unaffected


def test_republish_after_updates(snap):
    keys, d, f, idx = snap
    rng = np.random.default_rng(16)
    new = np.setdiff1d(np.unique(rng.uniform(keys[10], keys[-10], 500)), keys)
    for j, k in enumerate(new):
        d.insert(float(k), 7_000_000 + j)
    for k in keys[:100]:
        d.delete(float(k))
    f2 = flatten(d)
    idx2 = S.device_arrays(f2)
    v, fnd = S.search_batch(idx2, jnp.asarray(new), max_depth=f2.max_depth + 2)
    assert bool(np.asarray(fnd).all())
    v3, f3 = S.search_batch(idx2, jnp.asarray(keys[:100]),
                            max_depth=f2.max_depth + 2)
    assert not np.asarray(f3).any()


def _scan_lengths(closed_jaxpr) -> list:
    """All lax.scan trip counts reachable from a jaxpr (recursing through
    pjit / scan / while / custom calls)."""
    out = []

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "scan":
                out.append(int(eqn.params["length"]))
            for v in eqn.params.values():
                if hasattr(v, "jaxpr"):          # ClosedJaxpr
                    walk(v.jaxpr)
                elif hasattr(v, "eqns"):         # raw Jaxpr
                    walk(v)
    walk(closed_jaxpr.jaxpr)
    return out


def test_traversal_depth_exact_not_24(snap):
    """Regression: the traversal scan length must be the snapshot's true
    max_depth (derived via resolve_max_depth), not a hard-coded 24-trip
    worst case — and exactly max_depth trips must already find every key."""
    keys, d, f, idx = snap
    assert S.resolve_max_depth(idx) == f.max_depth
    rng = np.random.default_rng(17)
    q = jnp.asarray(keys[rng.integers(0, len(keys), 2048)])
    v, fnd = S.search_batch(idx, q)          # depth derived from the snapshot
    assert bool(np.asarray(fnd).all())
    lengths = _scan_lengths(
        jax.make_jaxpr(lambda q: S.search_batch(idx, q))(q))
    assert f.max_depth in lengths            # traversal is depth-exact
    # nothing scans 24 trips (or anything beyond the dense-probe phases)
    assert all(ln <= max(16, f.max_depth) for ln in lengths), lengths


def test_early_exit_matches_scan(snap):
    """The batch-convergence while_loop variant is bit-identical to the
    fixed-trip scan, including stats."""
    keys, d, f, idx = snap
    rng = np.random.default_rng(18)
    mids = (keys[:-1] + keys[1:]) / 2        # mix hits and misses
    q = jnp.asarray(np.concatenate([keys[rng.integers(0, len(keys), 1024)],
                                    mids[rng.integers(0, len(mids), 1024)]]))
    v1, f1 = S.search_batch(idx, q, early_exit=False)
    v2, f2 = S.search_batch(idx, q, early_exit=True)
    assert np.array_equal(np.asarray(v1), np.asarray(v2))
    assert np.array_equal(np.asarray(f1), np.asarray(f2))
    s1 = S.search_batch(idx, q, with_stats=True, early_exit=False)
    s2 = S.search_batch(idx, q, with_stats=True, early_exit=True)
    for a, b in zip(s1, s2):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_resolve_max_depth_rejects_tracers(snap):
    keys, d, f, idx = snap
    with pytest.raises(TypeError):
        jax.jit(lambda i: S.resolve_max_depth(i))(idx)


def test_fused_overlay_single_dispatch(snap):
    """search_with_overlay is ONE jitted computation: its jaxpr top level is
    a single pjit call (traversal + overlay resolution fused)."""
    from repro.online.overlay import TombstoneOverlay, overlay_device_arrays
    keys, d, f, idx = snap
    ova = overlay_device_arrays(
        TombstoneOverlay.empty(16).upsert_batch([keys[3]], [42]))
    q = jnp.asarray(keys[:8])
    jaxpr = jax.make_jaxpr(
        lambda q: S.search_with_overlay(idx, ova, q, f.max_depth))(q)
    assert [e.primitive.name for e in jaxpr.jaxpr.eqns] == ["pjit"]
    v, fnd = S.search_with_overlay(idx, ova, q)
    assert bool(np.asarray(fnd).all())
    assert int(np.asarray(v)[3]) == 42


def test_range_query_batch(snap):
    keys, d, f, idx = snap
    lo = jnp.asarray([keys[50], keys[500]])
    hi = jnp.asarray([keys[80], keys[520]])
    ks, vs, counts = S.range_query_batch(idx, lo, hi, max_hits=64)
    counts = np.asarray(counts)
    assert counts[0] == 30 and counts[1] == 20
    got = np.asarray(ks[0])[:30]
    assert np.array_equal(got, keys[50:80])


def test_range_query_batch_matches_host(snap):
    """Exact agreement with host DILI.range_query on random windows.

    Re-flattens at test time: the module-scoped host `d` may have absorbed
    updates from earlier tests, which also exercises ranges post-update."""
    keys, d, _, _ = snap
    f = flatten(d)
    idx = S.device_arrays(f)
    rng = np.random.default_rng(21)
    starts = rng.integers(0, len(keys) - 120, 16)
    widths = rng.integers(1, 100, 16)
    lo = keys[starts]
    hi = keys[np.minimum(starts + widths, len(keys) - 1)]
    ks, vs, counts = S.range_query_batch(idx, jnp.asarray(lo),
                                         jnp.asarray(hi), max_hits=256)
    ks, vs, counts = np.asarray(ks), np.asarray(vs), np.asarray(counts)
    for i in range(len(lo)):
        expect = d.range_query(float(lo[i]), float(hi[i]))
        assert counts[i] == len(expect)
        got_k = ks[i][: counts[i]]
        got_v = vs[i][: counts[i]]
        assert np.array_equal(got_k, [p[0] for p in expect])
        assert np.array_equal(got_v, [p[1] for p in expect])


def test_range_query_batch_max_hits_truncation(snap):
    """Overflowing windows truncate: count saturates at max_hits and every
    returned (key, val) is a true member of the host result."""
    keys, d, _, _ = snap
    idx = S.device_arrays(flatten(d))
    lo, hi = float(keys[200]), float(keys[500])     # ~300 pairs > max_hits=32
    ks, vs, counts = S.range_query_batch(idx, jnp.asarray([lo]),
                                         jnp.asarray([hi]), max_hits=32)
    counts = np.asarray(counts)
    assert counts[0] == 32
    expect = dict(d.range_query(lo, hi))
    got_k = np.asarray(ks[0])
    got_v = np.asarray(vs[0])
    assert np.all(np.diff(got_k) >= 0)              # sorted ascending
    for k, v in zip(got_k, got_v):
        assert k in expect and expect[k] == v
