"""Serving front-end suite (DESIGN.md section 15).

Three layers:

  * batcher mechanics WITHOUT an engine (a stub index records calls):
    coalescing homogeneity + pow2 buckets, admission shedding, AIMD
    convergence, FIFO dispatch, error fan-out;
  * the tier-1 concurrency contract on every engine: >= 4 seeded client
    threads drive mixed ops through one frontend, each client asserts
    read-your-acknowledged-writes inline, and the committed journal
    replayed through `WorkloadRunner` on a fresh index must reproduce
    the concurrent run's final `items()` bit-exactly;
  * facade thread-safety: `stats()`/`metrics()`/frontend stats hammered
    from sampler threads while the batcher serves writes.

Client write keys are odd (the generator convention: the loaded universe
is even integers), disjoint per client, and < 2^24 so the pallas
engine's f32 quantization is exact.
"""

import json
import threading
from collections import deque

import numpy as np
import pytest

from repro.api import IndexConfig, LearnedIndex
from repro.obs.tracing import MERGE_SPANS, RECOVERY_SPANS, SERVE_SPANS
from repro.serve import (AdaptiveBatchSizer, RejectedError, Request,
                         RequestBatcher, ServeConfig, ServeFrontend,
                         SessionTable, coalesce, open_loop, pow2_bucket)
from repro.workloads.runner import WorkloadRunner

ENGINES = ("local", "pallas", "sharded")


# -- stub-index layer (no engine) ---------------------------------------------

class StubIndex:
    """Records facade calls; optionally blocks inside the first call so a
    test can fill the admission queue while the worker is busy."""

    telemetry = None

    def __init__(self, gate: threading.Event | None = None):
        self.calls: list[tuple] = []
        self.gate = gate
        self.entered = threading.Event()

    def _enter(self):
        self.entered.set()
        if self.gate is not None:
            assert self.gate.wait(30.0)
            self.gate = None            # only the first call blocks

    def lookup(self, q):
        self._enter()
        self.calls.append(("lookup", len(q)))
        return np.asarray(q, np.int64), np.ones(len(q), bool)

    def range(self, lo, hi, max_hits=64):
        self._enter()
        self.calls.append(("range", len(lo), max_hits))
        n = len(lo)
        return (np.full((n, max_hits), np.inf),
                np.full((n, max_hits), -1, np.int64),
                np.zeros(n, np.int64))

    def upsert(self, keys, vals):
        self._enter()
        self.calls.append(("upsert", len(keys)))

    def delete(self, keys):
        self._enter()
        self.calls.append(("delete", len(keys)))


def req(op, n=1, max_hits=64, **kw):
    if op == "range":
        return Request(op, lo=np.zeros(n), hi=np.ones(n),
                       max_hits=max_hits, **kw)
    return Request(op, keys=np.arange(n, dtype=np.float64),
                   vals=np.zeros(n, np.int64) if op == "upsert" else None,
                   **kw)


def test_pow2_bucket_matches_facade_padding():
    ix = LearnedIndex.build(np.arange(8.0))
    try:
        for n in (1, 3, 64, 65, 100, 128, 1000):
            assert pow2_bucket(n) == ix._pad_batch(n), n
    finally:
        ix.close()


def test_coalesce_op_homogeneity_and_cap():
    d = deque([req("lookup", 10), req("lookup", 20), req("upsert", 5),
               req("lookup", 3)])
    g = coalesce(d, cap_ops=64)
    assert [r.op for r in g] == ["lookup", "lookup"]   # stops at upsert
    assert coalesce(d, 64)[0].op == "upsert"
    # cap: the head is always taken, the next 20-op req would exceed 25
    d = deque([req("lookup", 10), req("lookup", 20)])
    assert len(coalesce(d, cap_ops=25)) == 1 and len(d) == 1
    # oversized head still dispatches alone
    d = deque([req("lookup", 100)])
    assert len(coalesce(d, cap_ops=64)) == 1
    # ranges only coalesce on matching max_hits
    d = deque([req("range", 4, max_hits=64), req("range", 4, max_hits=64),
               req("range", 4, max_hits=8)])
    assert len(coalesce(d, 64)) == 2 and d[0].max_hits == 8


def test_aimd_sizer_converges_and_pow2_caps():
    cfg = ServeConfig(min_batch_ops=64, max_batch_ops=2048,
                      latency_slo_s=0.010, aimd_add_ops=64)
    s = AdaptiveBatchSizer(cfg)
    # scripted arrivals: sustained queue pressure, fast service -> grow
    # additively to the ceiling
    for _ in range(100):
        s.observe(queue_depth_ops=4096, service_s=0.001)
    assert s.target == cfg.max_batch_ops
    # one slow batch halves; floor is respected under repeated violations
    s.observe(4096, 0.100)
    assert s.target == cfg.max_batch_ops // 2
    for _ in range(20):
        s.observe(0, 0.100)
    assert s.target == cfg.min_batch_ops
    # the dispatch cap is always a pow2 facade bucket within bounds
    for depth in (0, 100, 500, 5000):
        s.observe(depth, 0.001)
        cap = s.cap
        assert cap & (cap - 1) == 0
        assert cfg.min_batch_ops <= cap <= cfg.max_batch_ops


def test_admission_control_sheds_above_bound():
    gate = threading.Event()
    stub = StubIndex(gate=gate)
    b = RequestBatcher(stub, ServeConfig(queue_cap_ops=8, dwell_s=0.0))
    try:
        b.submit(req("lookup", 1))          # worker picks this up...
        assert stub.entered.wait(10.0)      # ...and blocks inside it
        for _ in range(8):                  # fill the queue to the bound
            b.submit(req("lookup", 1))
        with pytest.raises(RejectedError):
            b.submit(req("lookup", 1))
        assert b.n_shed == 1
        gate.set()
        b.drain(30.0)
        assert b.n_completed == 9 and b.n_failed == 0
        s = b.stats()
        assert s["shed_ops"] == 1 and 0 < s["shed_frac"] < 1
    finally:
        gate.set()
        b.close()


def test_batcher_fifo_coalescing_and_journal():
    gate = threading.Event()
    stub = StubIndex(gate=gate)
    b = RequestBatcher(stub, ServeConfig(dwell_s=0.0))
    try:
        b.submit(req("lookup", 1))          # occupy the worker
        assert stub.entered.wait(10.0)
        rs = [b.submit(r) for r in
              (req("lookup", 2), req("lookup", 3), req("upsert", 4),
               req("lookup", 5), req("delete", 6))]
        gate.set()
        b.drain(30.0)
        # deterministic grouping of the queued prefix: the two lookups
        # coalesce, the write ops break the runs
        assert stub.calls == [("lookup", 1), ("lookup", 5), ("upsert", 4),
                              ("lookup", 5), ("delete", 6)]
        assert [(j.op, j.n_ops) for j in b.journal] == \
            [("lookup", 1), ("lookup", 5), ("upsert", 4), ("lookup", 5),
             ("delete", 6)]
        v, f = rs[0].wait(1.0)
        assert len(v) == 2 and f.all()      # sliced back per request
        v, f = rs[1].wait(1.0)
        assert len(v) == 3
    finally:
        gate.set()
        b.close()


def test_batcher_error_fans_out_to_waiters():
    class Exploding(StubIndex):
        def upsert(self, keys, vals):
            raise RuntimeError("boom")

    b = RequestBatcher(Exploding(), ServeConfig(dwell_s=0.0))
    try:
        r = b.submit(req("upsert", 3))
        with pytest.raises(RuntimeError, match="boom"):
            r.wait(10.0)
        assert b.n_failed == 3
        v, f = b.submit(req("lookup", 2)).wait(10.0)   # worker survives
        assert f.all()
    finally:
        b.close()


def test_closed_batcher_rejects_submits():
    b = RequestBatcher(StubIndex(), ServeConfig(dwell_s=0.0))
    b.close()
    with pytest.raises(RuntimeError):
        b.submit(req("lookup", 1))


def test_serve_spans_declared_only_on_attach():
    ix = LearnedIndex.build(np.arange(32.0), config=IndexConfig(
        engine="local", telemetry=True))
    try:
        base_snap = ix.metrics()
        assert set(base_snap["spans"]) == set(MERGE_SPANS + RECOVERY_SPANS)
        assert base_snap["serve"] == {}      # bare index: no serve block
        fe = ServeFrontend(ix)
        fe.client("c").lookup([0.0])
        snap = ix.metrics()
        assert set(snap["spans"]) == \
            set(MERGE_SPANS + RECOVERY_SPANS) | set(SERVE_SPANS)
        for op in ("lookup", "range", "upsert", "delete"):
            assert f"serve.e2e.{op}" in snap["serve"]
        assert snap["serve"]["serve.e2e.lookup"]["count"] >= 1
        assert snap["serve"]["serve.batch.ops"]["count"] >= 1
        assert snap["spans"]["serve.exec"]["count"] >= 1
        fe.close()
    finally:
        ix.close()


# -- engine layer: the concurrency contract -----------------------------------

def _client_program(fe, ci, keys, n, errors, writes_log):
    """One seeded client stream: lookups/ranges over the loaded universe,
    upserts/deletes over a client-private odd key range, with inline
    read-your-acknowledged-writes assertions."""
    try:
        c = fe.client(f"client-{ci}")
        r = np.random.default_rng(1000 + ci)
        base = float(2 * n + 1 + 2_000_000 * ci)     # odd, disjoint, < 2^24
        live: list[tuple[float, int]] = []
        for step in range(24):
            choice = int(r.integers(0, 4))
            if choice == 0:
                q = keys[r.integers(0, n, 8)]
                v, f = c.lookup(q)
                assert f.all(), "loaded even keys are never deleted"
            elif choice == 1:
                lo = keys[r.integers(0, n, 4)]
                ks, vs, cnt = c.range(lo, lo + 64.0)
                assert (cnt >= 1).all()              # lo itself is live
            elif choice == 2:
                k, v = base + 2 * step, ci * 1000 + step
                c.upsert([k], [v])
                live.append((k, v))
                got = c.get(k)                       # read-your-writes
                assert got == v, (ci, step, got, v)
            elif live:
                k, _ = live.pop(int(r.integers(0, len(live))))
                c.delete([k])
                assert c.get(k) is None, (ci, k)
        writes_log[ci] = live
    except BaseException as e:                       # noqa: BLE001
        errors.append((ci, e))


@pytest.mark.parametrize("engine", ENGINES)
def test_multi_client_oracle_equivalence(engine):
    """>= 4 concurrent client streams; the journal's serialization
    replayed on a fresh index must match the served index bit-exactly."""
    n = 4000 if engine == "local" else 1500
    keys = np.arange(0, 2 * n, 2, dtype=np.float64)
    vals = np.arange(n, dtype=np.int64)
    cfg = IndexConfig(engine=engine)
    ix = LearnedIndex.build(keys, vals, config=cfg)
    fe = ServeFrontend(ix, ServeConfig(dwell_s=2e-4))
    errors: list = []
    writes_log: dict = {}
    threads = [threading.Thread(target=_client_program,
                                args=(fe, ci, keys, n, errors, writes_log))
               for ci in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(120.0)
    fe.drain()
    journal = fe.journal_batches()
    stats = fe.stats()
    fe.close()
    assert not errors, errors[:2]
    assert stats["failed_ops"] == 0 and stats["shed_ops"] == 0
    assert stats["n_batches"] >= 1 and journal

    # replay the committed interleaving, oracle-checked batch by batch
    fresh = LearnedIndex.build(keys, vals, config=cfg)
    try:
        rep = WorkloadRunner(fresh).run(journal, name=f"serve-{engine}")
        assert rep.n_ops == stats["completed_ops"]
        k1, v1 = ix.items()
        k2, v2 = fresh.items()
        assert np.array_equal(k1, k2) and np.array_equal(v1, v2), \
            "concurrent run diverged from its own journal's replay"
        # every surviving acknowledged write is in the final content
        for ci, live in writes_log.items():
            for k, v in live:
                i = np.searchsorted(k1, k)
                assert i < len(k1) and k1[i] == k and v1[i] == v, (ci, k)
    finally:
        fresh.close()
        ix.close()


def test_stats_metrics_safe_to_sample_under_load():
    """Satellite: hammer `stats()`/`metrics()`/frontend stats from
    sampler threads while the batcher serves a write-heavy mix."""
    n = 2000
    keys = np.arange(0, 2 * n, 2, dtype=np.float64)
    ix = LearnedIndex.build(keys, config=IndexConfig(
        engine="local", telemetry=True,
        overlay_cap=64))
    fe = ServeFrontend(ix, ServeConfig(dwell_s=1e-4))
    stop = threading.Event()
    errors: list = []

    def sampler():
        try:
            while not stop.is_set():
                json.dumps(ix.metrics())     # full snapshot must be JSON-able
                ix.stats()
                fe.stats()
        except BaseException as e:           # noqa: BLE001
            errors.append(e)

    def writer(ci):
        try:
            c = fe.client(f"w{ci}")
            base = 2 * n + 1 + 100_000 * ci
            for i in range(60):
                c.upsert([float(base + 2 * i)], [i])
                c.lookup(keys[(7 * i) % n: (7 * i) % n + 4])
                if i % 3 == 2:
                    c.delete([float(base + 2 * (i - 1))])
        except BaseException as e:           # noqa: BLE001
            errors.append(e)

    samplers = [threading.Thread(target=sampler) for _ in range(2)]
    writers = [threading.Thread(target=writer, args=(ci,))
               for ci in range(2)]
    for t in samplers + writers:
        t.start()
    for t in writers:
        t.join(120.0)
    stop.set()
    for t in samplers:
        t.join(30.0)
    fe.close()
    ix.close()
    assert not errors, errors[:2]


def test_open_loop_low_rate_completes_everything():
    from repro.workloads.generator import PRESETS, generate_stream
    n = 2000
    keys = np.arange(0, 2 * n, 2, dtype=np.float64)
    ix = LearnedIndex.build(keys, config=IndexConfig(engine="local"))
    fe = ServeFrontend(ix, ServeConfig(dwell_s=1e-4), journal=False)
    try:
        spec = PRESETS["ycsb_a"].scaled(n_ops=400, batch_size=8, seed=3)
        stream = generate_stream(spec, keys)
        rep = open_loop(fe, stream, rate_ops_per_s=2000.0, n_clients=4,
                        timeout_s=60.0)
        assert rep.shed_ops == 0 and rep.failed_ops == 0
        assert rep.done_ops == rep.n_ops
        lat = rep.latency_ms()
        assert lat["lookup"]["count"] > 0
        assert lat["lookup"]["ms_p99"] >= lat["lookup"]["ms_p50"] > 0
        json.dumps(rep.to_json_dict())
    finally:
        fe.close()
        ix.close()


# -- session table under concurrent frontend threads --------------------------

def test_session_table_concurrent_admit_evict():
    st = SessionTable(n_slots=64)
    fe = ServeFrontend(st.index)
    try:
        st.serve_through(fe)
        ids = [float(100 + i) for i in range(40)]
        slots: dict = {}
        errors: list = []

        def admit_some(chunk):
            try:
                for sid in chunk:
                    slots[sid] = st.admit(sid)
            except BaseException as e:       # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=admit_some, args=(ids[i::4],))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(60.0)
        assert not errors, errors[:2]
        # no slot handed out twice (incl. the warm sessions' slots)
        assert len(set(slots.values())) == len(ids)
        got, found = st.lookup_batch(ids)
        assert found.all()
        assert {float(s) for s in got} == {float(s)
                                           for s in slots.values()}

        # same-id contention: exactly one admit wins
        outcomes: list = []

        def race():
            try:
                outcomes.append(st.admit(999.0))
            except KeyError:
                outcomes.append("dup")

        racers = [threading.Thread(target=race) for _ in range(6)]
        for t in racers:
            t.start()
        for t in racers:
            t.join(60.0)
        assert sum(1 for o in outcomes if o != "dup") == 1

        def evict_some(chunk):
            for sid in chunk:
                st.evict(sid)

        threads = [threading.Thread(target=evict_some, args=(ids[i::4],))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(60.0)
        _, found = st.lookup_batch(ids)
        assert not found.any()
    finally:
        fe.close()
        st.index.close()
