"""Training substrate: optimizers converge, grad accumulation is exact,
checkpoint save/restore round-trips (incl. corruption fallback + resharding),
gradient compression preserves convergence."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.ft import checkpoint as CKPT
from repro.models import model as MDL
from repro.parallel import compression as COMP
from repro.train import step as STEP
from repro.train.optim import adafactor, adamw, cosine_schedule


def quad_loss(p):
    return jnp.sum(jnp.square(p["w"] - 3.0)) + jnp.sum(jnp.square(p["b"] + 1))


@pytest.mark.parametrize("opt_fn", [
    lambda: adamw(lr=0.1),
    lambda: adafactor(lr=0.5, schedule=cosine_schedule(0.5, 10, 300)),
], ids=["adamw", "adafactor"])
def test_optimizer_converges_quadratic(opt_fn):
    opt = opt_fn()
    params = dict(w=jnp.zeros((4, 130)), b=jnp.zeros((7,)))
    state = opt.init(params)
    for _ in range(300):
        g = jax.grad(quad_loss)(params)
        params, state, _ = opt.update(g, state, params)
    assert float(quad_loss(params)) < 1e-2


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(100)) < 1e-6
    assert float(lr(55)) < float(lr(20))


def test_grad_accumulation_matches_full_batch():
    cfg = dataclasses.replace(get_config("granite_8b").reduced(),
                              accum_steps=4, remat="none")
    opt = adamw(lr=0.0)          # lr 0: compare grads via metrics only
    params = MDL.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, cfg.vocab)
    labels = jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, cfg.vocab)

    accum_step = STEP.make_train_step(cfg, opt)
    state = dict(params=params, opt=opt.init(params),
                 step=jnp.zeros((), jnp.int32))
    batch_a = dict(tokens=tokens.reshape(4, 2, 16),
                   labels=labels.reshape(4, 2, 16))
    _, m_a = accum_step(state, batch_a)

    cfg1 = dataclasses.replace(cfg, accum_steps=1)
    full_step = STEP.make_train_step(cfg1, opt)
    _, m_f = full_step(state, dict(tokens=tokens, labels=labels))
    np.testing.assert_allclose(float(m_a["loss"]), float(m_f["loss"]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(m_a["grad_norm"]),
                               float(m_f["grad_norm"]), rtol=1e-4)


def test_train_step_reduces_loss():
    cfg = get_config("internvl2_1b").reduced(n_layers=1, vocab=128)
    cfg = dataclasses.replace(cfg, family="dense", frontend="",
                              frontend_seq=0)
    opt = adamw(lr=3e-3)
    state = STEP.init_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(STEP.make_train_step(cfg, opt))
    rng = np.random.default_rng(0)
    # tiny synthetic task: next token = (token + 1) % vocab
    toks = rng.integers(0, cfg.vocab - 1, (4, 32))
    batch = dict(tokens=jnp.asarray(toks, jnp.int32),
                 labels=jnp.asarray((toks + 1) % cfg.vocab, jnp.int32))
    losses = []
    for _ in range(30):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::10]


def test_checkpoint_roundtrip_and_fallback(tmp_path):
    cfg = get_config("granite_8b").reduced()
    opt = adamw()
    state = STEP.init_state(jax.random.PRNGKey(0), cfg, opt)
    d = str(tmp_path / "ckpt")
    CKPT.save(d, 1, state, extra={"data_pos": 123})
    state2 = jax.tree.map(lambda x: x + 1 if x.dtype != jnp.bool_ else x,
                          state)
    CKPT.save(d, 2, state2)
    template = jax.eval_shape(lambda: STEP.init_state(
        jax.random.PRNGKey(0), cfg, opt))
    got, manifest = CKPT.restore(d, template)
    assert manifest["step"] == 2
    np.testing.assert_allclose(
        np.asarray(got["params"]["final_norm"]),
        np.asarray(state2["params"]["final_norm"]))
    # corrupt the newest checkpoint -> falls back to step 1
    import glob
    npz = glob.glob(os.path.join(d, "step_00000002", "*.npz"))[0]
    with open(npz, "wb") as f:
        f.write(b"garbage")
    got1, man1 = CKPT.restore(d, template)
    assert man1["step"] == 1
    assert man1["extra"]["data_pos"] == 123


def test_checkpoint_gc_keeps_last():
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        state = dict(x=jnp.arange(4))
        for s in range(5):
            CKPT.save(d, s, state, keep=2)
        dirs = [p for p in os.listdir(d) if p.startswith("step_")]
        assert len(dirs) == 2


def test_error_feedback_compression_convergence():
    """int8+EF gradient compression must still converge (quadratic)."""
    opt = adamw(lr=0.1)
    params = dict(w=jnp.zeros((8, 130)), b=jnp.zeros((7,)))
    state = opt.init(params)
    residual = COMP.init_residual(params)
    for _ in range(250):
        g = jax.grad(quad_loss)(params)
        g, residual = COMP.ef_compress(g, residual)
        params, state, _ = opt.update(g, state, params)
    assert float(quad_loss(params)) < 0.05


def test_quantize_roundtrip_bounded_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 5, (256,)), jnp.float32)
    q, s = COMP.quantize_int8(x)
    err = np.abs(np.asarray(COMP.dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6
