"""The workload engine + differential oracle (DESIGN.md section 11).

Three layers of defense, cheapest first: distribution samplers are checked
for shape/skew/determinism in isolation; the `SortedOracle` is checked
against a brute-force dict model (the oracle must be above suspicion — it
is the ground truth everything else is diffed against); then the
acceptance grid replays seeded YCSB-style preset streams through ALL THREE
engines with per-batch oracle diffing and asserts zero divergence.  A
fault-injection test proves the diff actually bites.

The differential contract uses the integer-key convention
(tests/test_api_engines.py): integer-valued keys below 2^24 are exact
under the pallas engine's f32 quantization, so every comparison is
bit-exact on every engine — no tolerances.
"""
import numpy as np
import pytest

from repro.api import IndexConfig, LearnedIndex, MaintenanceConfig
from repro.workloads import (PRESETS, SortedOracle, WorkloadDivergence,
                             WorkloadRunner, WorkloadSpec, generate_stream,
                             run_preset, sample_indices, stream_op_counts)
from repro.workloads.distributions import ZetaCache, zipfian_ranks

ENGINES = ("local", "pallas", "sharded")
UNIVERSE = np.arange(0, 6000, 2, dtype=np.float64)    # f32-exact even ints


# ---------------------------------------------------------------------------
# distributions
# ---------------------------------------------------------------------------


def test_zipfian_is_skewed_and_deterministic():
    z = ZetaCache(0.99)
    r1 = zipfian_ranks(np.random.default_rng(3), 10000, 40000, 0.99, z)
    r2 = zipfian_ranks(np.random.default_rng(3), 10000, 40000, 0.99,
                       ZetaCache(0.99))
    np.testing.assert_array_equal(r1, r2)
    assert r1.min() >= 0 and r1.max() < 10000
    # YCSB-grade skew: the 10 hottest ranks draw >20% of accesses
    # (uniform would give 0.1%)
    top = np.sort(np.bincount(r1, minlength=10000))[-10:].sum()
    assert top / len(r1) > 0.20


def test_zeta_cache_incremental_matches_direct():
    z = ZetaCache(0.7)
    assert np.isclose(z(100), np.sum(np.arange(1, 101) ** -0.7))
    # shrink then regrow: prefix array answers any n seen so far
    assert np.isclose(z(10), np.sum(np.arange(1, 11) ** -0.7))
    assert np.isclose(z(250), np.sum(np.arange(1, 251) ** -0.7))


def test_hotspot_and_uniform_shapes():
    rng = np.random.default_rng(0)
    hot = sample_indices(rng, "hotspot", 1000, 20000,
                         hot_frac=0.2, hot_weight=0.8)
    assert 0.75 < (hot < 200).mean() < 0.85
    uni = sample_indices(rng, "uniform", 1000, 20000)
    assert (np.bincount(uni, minlength=1000) > 0).mean() > 0.99


def test_unknown_distribution_rejected():
    with pytest.raises(ValueError, match="unknown distribution"):
        sample_indices(np.random.default_rng(0), "pareto", 10, 5)
    with pytest.raises(ValueError, match="unknown distribution"):
        WorkloadSpec(distribution="pareto")


def test_spec_mix_must_sum_to_one():
    with pytest.raises(ValueError, match="sum to 1"):
        WorkloadSpec(lookup=0.5, upsert=0.2)


# ---------------------------------------------------------------------------
# generator
# ---------------------------------------------------------------------------


def _streams_equal(a, b) -> bool:
    if len(a) != len(b):
        return False
    for x, y in zip(a, b):
        if x.op != y.op:
            return False
        for f in ("keys", "vals", "lo", "hi"):
            u, v = getattr(x, f), getattr(y, f)
            if (u is None) != (v is None):
                return False
            if u is not None and not np.array_equal(u, v):
                return False
    return True


@pytest.mark.parametrize("preset", sorted(PRESETS))
def test_streams_replay_byte_identically(preset):
    spec = PRESETS[preset].scaled(n_ops=800, batch_size=64, seed=5)
    s1 = generate_stream(spec, UNIVERSE)
    s2 = generate_stream(spec, UNIVERSE)
    assert _streams_equal(s1, s2)
    s3 = generate_stream(spec.scaled(seed=6), UNIVERSE)
    assert not _streams_equal(s1, s3)


def test_stream_respects_mix_and_key_contracts():
    spec = PRESETS["dili_paper"].scaled(n_ops=6400, batch_size=64, seed=2)
    batches = generate_stream(spec, UNIVERSE)
    counts = stream_op_counts(batches)
    total = sum(counts.values())
    # delete batches dedupe victims, so the realized count may fall a few
    # ops short of the target — but never overshoot
    assert spec.n_ops * 0.98 <= total <= spec.n_ops
    # batch-granular mixing: fractions converge at the stream scale
    assert counts["lookup"] / total > 0.7
    assert counts["upsert"] > 0 and counts["range"] > 0
    loaded = set(UNIVERSE.tolist())
    live = set(loaded)
    for b in batches:
        if b.op == "upsert":
            new = set(b.keys.tolist()) - live
            # inserts come from the odd-integer pool, never colliding
            assert all(int(k) % 2 == 1 for k in new)
            live |= set(b.keys.tolist())
        elif b.op == "delete":
            # victims are live at generation time, and unique
            assert len(np.unique(b.keys)) == len(b.keys)
            assert set(b.keys.tolist()) <= live
            live -= set(b.keys.tolist())
        elif b.op == "range":
            assert (b.hi > b.lo).all()


def test_latest_distribution_prefers_recent_inserts():
    spec = WorkloadSpec(name="latest_mix", lookup=0.5, upsert=0.5,
                        insert_frac=1.0, distribution="latest",
                        n_ops=4000, batch_size=64, seed=9, miss_frac=0.0)
    batches = generate_stream(spec, UNIVERSE)
    inserted: set = set()
    hits_new = hits_loaded = 0
    for b in batches:
        if b.op == "upsert":
            inserted |= set(b.keys.tolist())
        elif b.op == "lookup" and inserted:
            ks = set(b.keys.tolist())
            hits_new += len(ks & inserted)
            hits_loaded += len(ks - inserted)
    # the loaded set outnumbers inserts ~20:1, yet "latest" lookups must
    # concentrate on the newest keys
    assert hits_new > hits_loaded


def test_shift_preset_moves_insert_distribution():
    """shift_fb_logn: fresh keys before the shift point stay inside the
    phase-1 odd-integer pool; after it they come from the disjoint
    lognormal cluster beyond the loaded range (the fb -> logn drift)."""
    spec = PRESETS["shift_fb_logn"].scaled(n_ops=2000, batch_size=64,
                                           seed=3)
    batches = generate_stream(spec, UNIVERSE)
    loaded = set(UNIVERSE.tolist())
    phase1_hi = UNIVERSE.max() + 2 * spec.n_ops     # phase-1 pool ceiling
    n_b = len(batches)
    early_new, late_new = [], []
    for i, b in enumerate(batches):
        if b.op != "upsert":
            continue
        fresh = [k for k in b.keys.tolist() if k not in loaded]
        loaded |= set(b.keys.tolist())
        (early_new if i < n_b // 2 else late_new).extend(fresh)
    assert early_new and late_new
    assert max(early_new) < phase1_hi               # pre-shift: fb pool
    late = np.asarray(late_new)
    assert (late > phase1_hi).mean() > 0.9          # post-shift: logn pool
    # integer-valued below 2^24: the f32 bit-exactness convention holds
    assert np.all(late == np.rint(late)) and late.max() < 2 ** 24


def test_ttl_storm_waves_and_oldest_victims():
    """ttl_storm: the deterministic wave schedule emits contiguous upsert
    waves then delete storms, and every delete storm expires the OLDEST
    live keys (TTL order), not popular ones."""
    spec = PRESETS["ttl_storm"].scaled(n_ops=1280, batch_size=64, seed=5)
    batches = generate_stream(spec, UNIVERSE)
    ops = [b.op for b in batches]
    # wave apportionment of (0.2, 0.5, 0.3) over wave_len=10
    assert ops[:10] == ["lookup"] * 2 + ["upsert"] * 5 + ["delete"] * 3
    age = list(UNIVERSE.tolist())                   # oldest-first live list
    saw_delete = False
    for b in batches:
        if b.op == "upsert":
            age.extend(k for k in b.keys.tolist() if k not in set(age))
        elif b.op == "delete":
            saw_delete = True
            want = set(np.sort(np.asarray(age[: len(b.keys)])).tolist())
            assert set(b.keys.tolist()) == want     # exactly the oldest
            age = [k for k in age if k not in want]
    assert saw_delete


def test_spec_scenario_field_validation():
    with pytest.raises(ValueError, match="delete_policy"):
        WorkloadSpec(delete_policy="newest")
    with pytest.raises(ValueError, match="shift_frac"):
        WorkloadSpec(shift_frac=1.0)
    with pytest.raises(ValueError, match="wave_len"):
        WorkloadSpec(wave_len=-1)


# ---------------------------------------------------------------------------
# oracle
# ---------------------------------------------------------------------------


def test_oracle_matches_brute_force_dict():
    rng = np.random.default_rng(4)
    keys = np.unique(rng.integers(0, 2000, 300)).astype(np.float64)
    oc = SortedOracle(keys, np.arange(len(keys), dtype=np.int64))
    model = dict(zip(keys.tolist(), range(len(keys))))
    for step in range(30):
        ks = rng.integers(0, 2000, 20).astype(np.float64)
        if step % 3 == 0:
            vs = rng.integers(0, 1 << 30, 20)
            oc.upsert(ks, vs)
            model.update(zip(ks.tolist(), vs.tolist()))
        elif step % 3 == 1:
            oc.delete(ks)
            for k in ks.tolist():
                model.pop(k, None)
        q = rng.integers(0, 2000, 50).astype(np.float64)
        v, f = oc.lookup(q)
        for qi, vi, fi in zip(q.tolist(), v, f):
            assert fi == (qi in model)
            if fi:
                assert vi == model[qi]
    want = np.array(sorted(model), np.float64)
    got_k, got_v = oc.items()
    np.testing.assert_array_equal(got_k, want)
    np.testing.assert_array_equal(got_v, [model[k] for k in want.tolist()])


def test_oracle_range_padding_conventions():
    oc = SortedOracle(np.array([1.0, 3.0, 5.0, 7.0]),
                      np.array([10, 30, 50, 70]))
    ks, vs, cnt = oc.range([2.0, 0.0], [6.0, 100.0], max_hits=3)
    np.testing.assert_array_equal(cnt, [2, 3])            # saturates at 3
    np.testing.assert_array_equal(ks[0], [3.0, 5.0, np.inf])
    np.testing.assert_array_equal(vs[0], [30, 50, -1])
    np.testing.assert_array_equal(ks[1], [1.0, 3.0, 5.0])


# ---------------------------------------------------------------------------
# differential acceptance grid: presets x engines, zero divergence
# ---------------------------------------------------------------------------

# per-engine sizing: the contract is identical; the pallas interpret-mode
# kernel and the mesh collectives just pay more per batch on CPU
GRID_SIZES = {"local": (1500, 64), "pallas": (600, 64), "sharded": (480, 32)}
GRID_PRESETS = ("ycsb_a", "ycsb_e", "dili_paper",
                "shift_fb_logn", "ttl_storm")
# the PR-5 scenario presets replay with the adaptive maintenance pipeline
# on (incremental splice-flatten + drift/tombstone retrains) — the grid is
# what pins its exactness engine-by-engine
MAINT_PRESETS = ("shift_fb_logn", "ttl_storm")


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("preset", GRID_PRESETS)
def test_differential_grid(engine, preset):
    """Replay a seeded preset stream through the engine with per-batch
    oracle diffing (lookup hits AND misses, range contents, write/delete
    visibility, final items()).  strict=True: any divergence raises."""
    n_ops, bs = GRID_SIZES[engine]
    spec = PRESETS[preset].scaled(n_ops=n_ops, batch_size=bs, seed=13)
    ix = LearnedIndex.build(UNIVERSE, config=IndexConfig(
        engine=engine, overlay_cap=512,
        maintenance=(MaintenanceConfig()
                     if preset in MAINT_PRESETS else None)))
    report = WorkloadRunner(ix).run(generate_stream(spec, UNIVERSE),
                                    spec=spec)
    assert report.divergences == []
    assert spec.n_ops * 0.95 <= report.n_ops <= spec.n_ops
    assert report.final_stats["engine"] == engine


def test_write_heavy_mix_exercises_merge_pressure():
    """ycsb_a at a small overlay capacity must drive the overlay ->
    merge -> republish lifecycle (not pile writes up unfolded), and stay
    oracle-exact across the epoch flips."""
    ix = LearnedIndex.build(UNIVERSE, config=IndexConfig(
        engine="local", overlay_cap=64))
    rep = run_preset(ix, PRESETS["ycsb_a"].scaled(n_ops=2000, batch_size=64,
                                                  seed=21))
    assert rep.divergences == []
    assert rep.final_stats["n_merges"] >= 1
    assert rep.final_stats["epoch"] >= 2


class _FaultyIndex:
    """Engine-protocol wrapper that corrupts one lookup lane per batch —
    the runner must catch it (differential harness self-test)."""

    def __init__(self, ix):
        self._ix = ix

    def __getattr__(self, name):
        return getattr(self._ix, name)

    def lookup(self, queries):
        v, f = self._ix.lookup(queries)
        v = np.array(v)
        v[0] += 1                       # silent payload corruption
        return v, f


def test_runner_catches_injected_corruption():
    spec = PRESETS["ycsb_c"].scaled(n_ops=256, batch_size=64, seed=1)
    ix = _FaultyIndex(LearnedIndex.build(UNIVERSE,
                                         config=IndexConfig(engine="local")))
    batches = generate_stream(spec, UNIVERSE)
    report = WorkloadRunner(ix, strict=False).run(batches, spec=spec)
    assert report.divergences            # every batch caught
    with pytest.raises(WorkloadDivergence):
        WorkloadRunner(ix).run(batches, spec=spec)


def test_runner_check_false_is_pure_throughput():
    """check=False: no oracle, no diffs — the perf-sweep mode for key sets
    that are not exactly representable on every engine."""
    ix = LearnedIndex.build(UNIVERSE, config=IndexConfig(engine="local"))
    spec = PRESETS["ycsb_b"].scaled(n_ops=256, batch_size=64, seed=2)
    runner = WorkloadRunner(ix, check=False)
    assert runner.oracle is None
    r = runner.run(generate_stream(spec, UNIVERSE), spec=spec)
    assert r.divergences == [] and r.n_ops == 256 and r.wall_s > 0
    d = r.to_json_dict()
    assert d["ops_per_s"] > 0 and d["n_divergences"] == 0
